"""repro.inkernel: OpSpec -> Pallas fori_loop chain, probe, plan, CLI.

The oracle test is the load-bearing one: for every in-kernel-eligible registry
row, the Pallas chain (interpret mode) must agree elementwise with the
host-level straight-line chain — i.e. moving the measurement inside the
kernel changes *where* the ops run, never *what* they compute.
"""
import json

import jax.numpy as jnp
import pytest

from repro import inkernel
from repro.api import KernelChainProbe, Plan, Session, cli, named_plan
from repro.core import chains
from repro.core.timing import Timer

REG = chains.default_registry()
SUPPORTED = inkernel.supported_specs()


def _spec(name):
    return next(s for s in REG if s.name == name)


# ------------------------------------------------------------------ factory
def test_support_policy():
    names = {s.name for s in SUPPORTED}
    assert "add" in names and "fma.float32" in names and "popc" in names
    # 64-bit carries stay on the dispatch path
    assert "mul64hi" not in names and "add.float64" not in names
    cats = {s.category for s in SUPPORTED}
    for cat in ("int_arith", "logic_shift", "fp32", "fp16", "special_math",
                "int_intrinsic"):
        assert cat in cats, cat


def test_default_tile_dtype_aware():
    assert inkernel.default_tile("float32") == (8, 128)
    assert inkernel.default_tile("int32") == (8, 128)
    assert inkernel.default_tile("bfloat16") == (16, 128)
    assert inkernel.default_tile("float16") == (16, 128)


def test_build_chain_rejects_x64_specs():
    with pytest.raises(ValueError, match="cannot lower in-kernel"):
        inkernel.build_chain(_spec("mul64hi"), 4)
    with pytest.raises(ValueError, match="cannot lower in-kernel"):
        KernelChainProbe(_spec("add.float64"))


@pytest.mark.parametrize("spec", SUPPORTED, ids=lambda s: s.name)
def test_inkernel_chain_matches_host_oracle(spec):
    n = 12
    carry, operands = inkernel.tiles(spec)
    out = inkernel.build_chain(spec, n, interpret=True)(carry, *operands)
    oracle = chains.chain_fn(spec, n)(spec.carry(), *spec.operand_arrays())
    assert out.shape == carry.shape and out.dtype == carry.dtype
    assert jnp.allclose(out, jnp.full(out.shape, oracle, out.dtype),
                        rtol=1e-3, atol=1e-3), spec.name


def test_measure_inkernel_full_returns_measurement():
    m = inkernel.measure_inkernel_full(_spec("add"), lens=(2, 8),
                                       timer=Timer(warmup=0, reps=2))
    assert m.n == 2 and m.mad_ns >= 0.0


# -------------------------------------------------------------------- probe
def test_probe_identity_and_fidelity_suffix():
    spec = _spec("add")
    std = KernelChainProbe(spec)
    assert std.op == "inkernel.add"
    assert std.opt_level == "O3"
    assert std.category == spec.category and std.dtype == spec.dtype
    assert KernelChainProbe(spec, lens=(4, 32)).op == "inkernel.add.l4-32"
    assert KernelChainProbe(spec, shape=(8, 256)).op == "inkernel.add.t8x256"
    assert KernelChainProbe(spec, lens=(4, 32)).logical_key() != std.logical_key()


# --------------------------------------------------------------------- plan
def test_plan_inkernel_pairs_dispatch_probes():
    plan = Plan.inkernel(ops=("add", "fma.float32"))
    ops = [p.op for p in plan]
    assert set(ops) == {"inkernel.add", "inkernel.fma.float32",
                        "add", "fma.float32"}
    solo = Plan.inkernel(ops=("add",), dispatch_pair=False)
    assert [p.op for p in solo] == ["inkernel.add"]


def test_named_plan_inkernel_cross_product():
    plan = named_plan("inkernel")
    keys = [p.logical_key() for p in plan]
    assert len(keys) == len(set(keys))
    cats = {p.category for p in plan}
    assert {"int_arith", "fp32"} <= cats
    # one in-kernel + one dispatch probe per eligible spec
    assert len(plan) == 2 * len(SUPPORTED)
    # and the full plan embeds the same cross-product
    assert "inkernel.add" in {p.op for p in named_plan("full")}


# ------------------------------------------------------- session + caching
def test_session_measures_and_caches_kernel_chain(tmp_path):
    db = tmp_path / "db.json"
    plan = Plan.inkernel(ops=("add",), lens=(2, 8), dispatch_pair=False)
    first = Session(db=str(db), timer=Timer(warmup=0, reps=2)).run(plan)
    assert first.summary().startswith("1 measured")
    rec = first.measured[0].record
    assert rec.op == "inkernel.add.l2-8"
    assert rec.guard == _spec("add").guard
    assert "fori_loop" in rec.notes
    second = Session(db=str(db), timer=Timer(warmup=0, reps=2)).run(plan)
    assert second.summary().startswith("0 measured, 1 cached")


def test_guard_netting_uses_inkernel_baseline(monkeypatch, tmp_path):
    """Guarded in-kernel records net out guard ops against the *in-kernel*
    add baseline, never the dispatch-level one (which on real hardware can
    exceed the whole in-kernel latency and clamp net to 0)."""
    import weakref

    from repro import inkernel as ik
    from repro.api.probes import KernelChainProbe as KCP
    from repro.core.timing import Measurement

    def fake_measure(spec, lens=None, shape=None, timer=None, reps=None,
                     interpret=None):
        ns = 100.0 if spec.name == "add" else 400.0
        return Measurement(ns, 0.0, ns, 2)

    # disable the prepare split so the pipelined path falls back to run(),
    # which is where measure_inkernel_full (the seam under test) is consulted
    monkeypatch.setattr(ik, "prepare_inkernel", lambda *a, **k: None)
    monkeypatch.setattr(ik, "measure_inkernel_full", fake_measure)
    monkeypatch.setattr(KCP, "_baselines", weakref.WeakKeyDictionary())

    def run_one(spec, db):
        return Session(db=str(tmp_path / db), timer=Timer(warmup=0, reps=2)) \
            .run(Plan((KernelChainProbe(spec),))).measured[0].record

    rec = run_one(_spec("mul"), "db1.json")  # guard=1, xor-guarded
    # in-kernel add pair = 100 ns over (1 + guard=1) ops -> baseline 50;
    # an exact 350 proves the dispatch baseline was never consulted
    assert rec.latency_ns == 400.0
    assert rec.net_latency_ns == 350.0
    rec3 = run_one(_spec("mul24"), "db2.json")  # guard=2 (one mask CSE'd)
    assert rec3.net_latency_ns == 300.0  # 400 - 2*50
    rec0 = run_one(_spec("fma.float32"), "db3.json")  # guard=0: no baseline
    assert rec0.net_latency_ns == 400.0


def test_default_lens_single_source_of_truth():
    """The unsuffixed cache identity and the measurement default must agree:
    both resolve to inkernel.INKERNEL_LENS."""
    spec = _spec("add")
    assert KernelChainProbe(spec).lens == inkernel.INKERNEL_LENS
    assert KernelChainProbe(spec).op == "inkernel.add"
    explicit = KernelChainProbe(spec, lens=inkernel.INKERNEL_LENS)
    assert explicit.op == "inkernel.add"  # explicit default = same identity


# ---------------------------------------------------------------------- CLI
CLI_OPS = "inkernel.add,add,inkernel.fma.float32,fma.float32"


def test_cli_inkernel_plan_and_comparison_table(tmp_path, capsys):
    db = tmp_path / "db.json"
    args = ["characterize", "--plan", "inkernel", "--ops", CLI_OPS,
            "--reps", "2", "--warmup", "0", "--db", str(db)]
    rc = cli.main(args + ["--table"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "4 measured, 0 cached, 0 failed" in out
    assert "in-kernel/dispatch" in out  # comparison table rendered
    assert "| int_arith | add |" in out.replace("  ", " ")

    blob = json.loads(db.read_text())
    assert {r["op"] for r in blob["records"]} == set(CLI_OPS.split(","))

    # resume: same command is pure cache hits
    rc = cli.main(args)
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 measured, 4 cached, 0 failed" in out
