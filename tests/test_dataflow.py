"""Tests for repro.audit.dataflow: serialization / residency / signature
certificates, fused-kernel units, custom-call pricing and the zoo lints."""
import math
import time

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.experimental import pallas as pl

from repro.audit import audit_fused, audit_target, fused_registry, kernel_cert
from repro.audit.dataflow import (_residency_cause, audit_alu_kernel,
                                  audit_inkernel_mem, audit_inkernel_op,
                                  fused_unit)
from repro.core.chains import default_registry
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.inkernel.fused import FUSED_KERNELS, FUSED_LENS, build_fused

REGS = {s.name: s for s in default_registry()}

# Per-step countable-op units of the unrolled ALU chains (kernels/alu_chain):
# the signature-exactness ground truth the property test scales against.
ALU_UNITS = {"add": {"add": 1}, "mul": {"multiply": 1},
             "fma": {"add": 1, "multiply": 1}}

ENV = dict(device_kind="TestDev", backend="cpu", jax_version="0.0.test")


def _fused_db(ns=100.0, unit_bytes=2048):
    db = LatencyDB()
    for name in FUSED_KERNELS:
        db.add(LatencyRecord(
            op=f"inkernel.fused.{name}", category="kernel", dtype="float32",
            opt_level="O3", latency_ns=ns, mad_ns=0.0, cycles=0.0, guard=0,
            net_latency_ns=ns, n_samples=3, measured_at=str(time.time()),
            notes=f"pallas fused kernel lens=2-6 unit_bytes={unit_bytes}",
            **ENV))
    return db


# -------------------------------------------------- serialization properties
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=2, max_value=10),
       st.integers(min_value=11, max_value=24),
       st.sampled_from(["add", "mul"]))
def test_fori_chain_serialization_length(n1, n2, spec_name):
    """Property: a fori chain certifies as one serial dependence chain whose
    trip counts are exactly the requested lengths — the slope denominator."""
    v = audit_inkernel_op(REGS[spec_name], "O3", lens=(n1, n2))
    assert v.status == "audited", v
    assert f"trips={n1},{n2}" in v.detail, v


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=12),
       st.sampled_from(sorted(ALU_UNITS)))
def test_alu_chain_signature_exactness(n, alu_op):
    """Property: the unrolled chain's countable multiset is exactly n x the
    per-step unit, and its dependence depth equals that count (serial)."""
    from repro.kernels.alu_chain import alu_chain

    x = jnp.full((8, 128), 1.5, jnp.float32)
    a = jnp.full((8, 128), 0.5, jnp.float32)
    cert = kernel_cert(
        lambda x, a: alu_chain(x, a, n=n, op=alu_op, interpret=True), x, a)
    unit = ALU_UNITS[alu_op]
    assert dict(cert.ops) == {k: n * w for k, w in unit.items()}, cert.ops
    assert cert.chain.kind == "straightline" and cert.chain.serialized
    assert cert.chain.length == n * sum(unit.values()), cert.chain


def test_alu_chain_dtype_sweep():
    """Signature exactness is dtype-independent (the certificate counts
    primitive applications, not lanes)."""
    from repro.kernels.alu_chain import alu_chain

    for dtype in (jnp.float32, jnp.bfloat16):
        x = jnp.full((8, 128), 1.5, dtype)
        a = jnp.full((8, 128), 0.5, dtype)
        cert = kernel_cert(
            lambda x, a: alu_chain(x, a, n=5, op="add", interpret=True), x, a)
        assert dict(cert.ops) == {"add": 5}, (dtype, cert.ops)


# ------------------------------------------------------------- rejections
def test_parallelized_chain_rejected():
    """Regression: a deliberately parallelized body — n independent products
    recombined by a reduction tree's worth of adds — must NOT certify: the
    countable ops outnumber the serial path depth (parallel shortcut)."""
    n = 4

    def parallel(x, a):
        def body(x_ref, a_ref, o_ref):
            xv, av = x_ref[...], a_ref[...]
            acc = xv
            for t in [xv * av for _ in range(n)]:
                acc = acc + t
            o_ref[...] = acc
        return pl.pallas_call(
            body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x, a)

    x = jnp.ones((8, 128), jnp.float32)
    a = jnp.ones((8, 128), jnp.float32)
    cert = kernel_cert(parallel, x, a)
    assert not cert.chain.serialized
    assert cert.chain.cause == "parallel-shortcut", cert.chain


def test_carry_independent_loop_rejected():
    """A fori body that ignores its carry has no measured dependence chain."""
    def independent(x, a):
        def body(x_ref, a_ref, o_ref):
            def step(_i, _c):
                return x_ref[...] * a_ref[...]
            o_ref[...] = jax.lax.fori_loop(0, 6, step, x_ref[...])
        return pl.pallas_call(
            body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x, a)

    x = jnp.ones((8, 128), jnp.float32)
    a = jnp.ones((8, 128), jnp.float32)
    cert = kernel_cert(independent, x, a)
    assert not cert.chain.serialized
    assert cert.chain.cause == "no-dependence", cert.chain


# --------------------------------------------------------------- residency
def test_chase_residency_both_spaces():
    for space in ("vmem", "any"):
        v = audit_inkernel_mem(8192, "O3", space=space)
        assert v.status == "audited", (space, v)


def test_residency_mismatch_detected():
    """An HBM-streamed ring fails the default all-VMEM expectation."""
    import functools

    from repro.core.membench import build_ring
    from repro.kernels.chase import chase

    ring, start = build_ring(8192, 64)
    fn = functools.partial(chase, steps=8, memory_space="any",
                           interpret=True)
    cert = kernel_cert(fn, ring, start)
    cause = _residency_cause(cert)          # expects vmem everywhere
    assert cause.startswith("residency-mismatch(ref0:any!=vmem)"), cause
    assert _residency_cause(cert, {0: "any"}) == ""


# ------------------------------------------------------------ fused kernels
def test_fused_kernels_all_audited():
    for name in FUSED_KERNELS:
        v = audit_fused(name)
        assert v.status == "audited", (name, v)
        assert "unit_bytes=" in v.detail or "bytes" in v.detail or v.detail


def test_fused_unit_signatures():
    reg = fused_registry()
    assert set(reg) == set(FUSED_KERNELS)
    assert reg["rmsnorm"]["bytes"] == 4096
    assert reg["rmsnorm"]["ops"]["rsqrt"] == 1
    assert reg["flash_attention"]["ops"]["dot"] > 0
    assert reg["flash_attention"]["ops"]["exponential"] > 0
    for name, unit in reg.items():
        assert unit["bytes"] > 0, name


def test_fused_signature_linear_across_sizes():
    """The two-size signature delta divides exactly — the property that
    makes a fused kernel measurable by Timer.slope at all."""
    n1, n2 = FUSED_LENS
    unit = fused_unit("rmsnorm", (n1, n2))
    c = {}
    for n in (n1, n2):
        fn, args = build_fused("rmsnorm", n, interpret=True)
        c[n] = kernel_cert(fn, *args)
    for k, u in unit["ops"].items():
        assert c[n2].ops[k] - c[n1].ops[k] == (n2 - n1) * u, k
    assert c[n2].hbm_bytes - c[n1].hbm_bytes == (n2 - n1) * unit["bytes"]


def test_audit_target_fused_and_kernel_rows():
    v = audit_target("inkernel.fused.rmsnorm", "O3")
    assert v.status == "audited", v
    assert v.ok and v.note() == "audit=audited"
    v = audit_target("kernel.alu_chain.fma", "O3")
    assert v.status == "audited", v
    v = audit_target("inkernel.fused.nosuchkernel", "O3")
    assert v.status == "unaudited", v


def test_alu_audit_unknown_op():
    v = audit_alu_kernel("nosuchop", "O3")
    assert v.status == "unaudited" and v.cause == "unknown-kernel-op"


def test_fused_probe_measures():
    """FusedKernelProbe's measurement path: a finite two-size slope with the
    unit-bytes note the estimator's pricing reads back."""
    from repro.core.timing import Timer
    from repro.inkernel import prepare_fused, run_prepared_fused

    # mamba_scan: largest per-unit cost of the four, so the two-size delta
    # clears host-timer noise even at tiny reps on a loaded CI box
    prepared = prepare_fused("mamba_scan", lens=(2, 6), reps=3)
    m = run_prepared_fused(prepared, Timer(warmup=1, reps=3))
    assert math.isfinite(m.median_ns) and m.median_ns > 0


# ------------------------------------------------- estimator custom-call path
FUSED_HLO = """
HloModule fused_site

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128] parameter(0)
  %cc = f32[16,128] custom-call(%p0), custom_call_target="tpu_custom_call", backend_config="mosaic kernel=flash_attention_kernel"
  ROOT %a = f32[16,128] add(%cc, %p0)
}
"""


def test_estimator_prices_resolved_custom_call():
    from repro.core.perfmodel import HloLatencyEstimator

    db = _fused_db(ns=100.0, unit_bytes=2048)
    est = HloLatencyEstimator(db, filters=ENV)
    r = est.estimate(FUSED_HLO)
    # operands+result = 2 x 16*128*4 = 16384 bytes -> 8 units x 100ns
    assert r.by_class["fused:flash_attention"].ns == pytest.approx(800.0)
    assert not any(op.startswith("custom-call")
                   for op, _ in r.unpriced_opcodes)


def test_estimator_reports_unresolved_target_by_name():
    """Satellite: unknown custom-calls surface per target, never lumped."""
    from repro.core.perfmodel import HloLatencyEstimator

    est = HloLatencyEstimator(_fused_db(), filters=ENV)
    hlo = FUSED_HLO.replace("tpu_custom_call", "cudnn$fmha").replace(
        "flash_attention_kernel", "opaque")
    r = est.estimate(hlo)
    assert ("custom-call:cudnn$fmha", 1.0) in r.unpriced_opcodes, \
        r.unpriced_opcodes
    assert r.coverage < 1.0


def test_resolve_custom_call():
    from repro.core.hlo_analysis import resolve_custom_call

    assert resolve_custom_call("flash_decode") == "flash_decode"
    assert resolve_custom_call("tpu_custom_call",
                               'cfg "mamba_scan_fwd"') == "mamba_scan"
    assert resolve_custom_call("cudnn$fmha") is None


# ------------------------------------------------------------------- lints
def test_lint_zoo_resolves_known_custom_call(monkeypatch):
    from repro.audit import lint as lint_mod

    monkeypatch.setattr(lint_mod, "_zoo_hlo", lambda arch: FUSED_HLO)
    assert lint_mod.lint_zoo(archs=["fakearch"]) == []


def test_lint_zoo_accepts_known_library_call(monkeypatch):
    """Documented XLA library targets (TopK, the MoE router's lowering)
    pass the lint but are never priced — no fused row exists for them."""
    from repro.audit import lint as lint_mod

    hlo = FUSED_HLO.replace("tpu_custom_call", "TopK").replace(
        "mosaic kernel=flash_attention_kernel", "")
    monkeypatch.setattr(lint_mod, "_zoo_hlo", lambda arch: hlo)
    assert lint_mod.lint_zoo(archs=["fakearch"]) == []


def test_lint_zoo_rejects_unknown_custom_call(monkeypatch):
    from repro.audit import lint as lint_mod

    bad = FUSED_HLO.replace("flash_attention_kernel", "mystery")
    monkeypatch.setattr(lint_mod, "_zoo_hlo", lambda arch: bad)
    findings = lint_mod.lint_zoo(archs=["fakearch"])
    assert len(findings) == 1, findings
    assert "tpu_custom_call" in findings[0].message


def test_lint_dataflow_clean():
    from repro.audit.lint import lint_dataflow

    assert lint_dataflow() == []


# --------------------------------------------------------------- zoo costing
def test_zoo_cost_sites_and_pricing():
    """Every synthesized TPU-form site of a config prices from fused rows."""
    from benchmarks.zoo_cost import fused_hlo, fused_sites
    from repro.api.probes import serving_tiny_config
    from repro.core.perfmodel import HloLatencyEstimator

    cfg, _rt = serving_tiny_config()
    est = HloLatencyEstimator(_fused_db(), filters=ENV)
    for phase, kernel in (("prefill", "flash_attention"),
                          ("decode", "flash_decode")):
        sites = fused_sites(cfg, phase)
        assert sum(1 for k, *_ in sites if k == kernel) == cfg.n_layers
        r = est.estimate(fused_hlo("tiny", sites))
        assert r.priced_instances == len(sites)
        assert not any(op.startswith("custom-call")
                       for op, _ in r.unpriced_opcodes)


def test_zoo_cost_floor_covers_all_rows():
    """The checked-in floor names all twelve rows and demands full
    custom-call coverage everywhere."""
    import json
    import os

    from repro.configs.registry import all_arch_ids

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "zoo_cost_floor.json")
    with open(path) as f:
        floor = json.load(f)
    expected = set(all_arch_ids()) | {"serving-tiny.prefill",
                                      "serving-tiny.decode"}
    assert set(floor) == expected
    for model, bounds in floor.items():
        assert bounds["custom_call_coverage"] == 1.0, model
        assert 0.0 <= bounds["opcode_coverage"] <= 1.0, model
