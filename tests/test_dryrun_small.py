"""Dry-run machinery on a small 8-device mesh (subprocess), plus pure-python
pieces of launch/cells."""
import pytest

from repro.configs.registry import SHAPES, all_arch_ids, get
from repro.launch import cells
from tests._subproc import run_with_devices


def test_input_specs_all_cells_defined():
    for arch in all_arch_ids():
        spec = get(arch)
        for shape in SHAPES:
            if shape in spec.skips:
                continue
            specs = cells.input_specs(arch, shape)
            assert specs, (arch, shape)
            for k, v in specs.items():
                assert all(d > 0 for d in v.shape), (arch, shape, k)


def test_long500k_skips_are_full_attention_only():
    for arch in all_arch_ids():
        spec = get(arch)
        if arch in ("jamba-v0.1-52b", "xlstm-350m"):
            assert "long_500k" not in spec.skips
        else:
            assert "long_500k" in spec.skips


@pytest.mark.slow
def test_small_mesh_lower_compile_smoke():
    """A reduced config lowers+compiles on a (2 pod, 2 data, 2 model) mesh —
    the multi-pod pattern end-to-end, without the 512-device cost."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs.registry import get
from repro.models import transformer
from repro.models.config import Runtime
from repro.parallel import sharding as shd
from repro import optim
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get("granite-3-8b").smoke
rt = Runtime(remat=True, xent_chunk=16, moe_groups=4)
rules = shd.lm_rules(fsdp=True)
with shd.use_sharding(mesh, rules):
    params = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    psh = shd.param_shardings(params, mesh, rules)
    ocfg = optim.AdamWConfig()
    ost = jax.eval_shape(lambda p: optim.init_state(p, ocfg), params)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    bsh = {k: NamedSharding(mesh, P(("pod", "data"), None)) for k in batch}

    def step(p, s, b):
        (l, m), g = jax.value_and_grad(
            lambda q: transformer.train_loss(q, b, cfg, rt), has_aux=True)(p)
        np_, ns = optim.apply_update(p, g, s, ocfg)
        return np_, ns, l

    from repro.launch.cells import opt_shardings
    osh = opt_shardings(params, ost, mesh, rules)
    compiled = jax.jit(step, in_shardings=(psh, osh, bsh)).lower(
        params, ost, batch).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    txt = compiled.as_text()
    assert "all-reduce" in txt or "reduce-scatter" in txt  # DP gradient sync
print("COMPILED")
""", n_devices=8, timeout=480)
    assert "COMPILED" in out


def test_cache_shardings_divisibility():
    import jax
    import jax.numpy as jnp
    from repro.launch.cells import cache_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shapes = {"l0": {"k": jax.ShapeDtypeStruct((2, 1, 7, 3, 8), jnp.bfloat16)}}
    sh = cache_shardings(shapes, mesh, ("data",))
    # batch=1 and seq=7 not divisible by anything >1 -> fully replicated
    spec = sh["l0"]["k"].spec
    assert all(s is None for s in spec)
