"""Checkpoint manager: atomic roundtrip, retention, crash safety, elastic
restore (property-based roundtrip)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.parallel.sharding import Param


def _tree(seed: int):
    rng = np.random.RandomState(seed)
    return {
        "a": {"w": Param(jnp.asarray(rng.randn(4, 8).astype(np.float32)), ("x", "y")),
              "b": jnp.asarray(rng.randn(8).astype(np.float32))},
        "count": jnp.asarray(seed, jnp.int32),
    }


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_roundtrip_identity(tmp_path_factory, seed):
    d = str(tmp_path_factory.mktemp("ck"))
    mgr = CheckpointManager(d, async_save=False)
    tree = _tree(seed)
    mgr.save(1, tree)
    step, back = mgr.restore(tree)
    assert step == 1
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree(0)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]


def test_crash_mid_save_keeps_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(1))
    # simulate a crash: a stale tmp dir + missing COMMIT must be ignored
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "meta.json").write_text("{}")
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(_tree(1))
    assert step == 1


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _tree(7))
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_restore_resharding(tmp_path):
    """Save unsharded, restore with explicit single-device shardings (the
    n-device path is covered by test_distribution subprocess tests)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree(3)
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, P(*([None] * np.ndim(a)))), tree)
    step, back = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["a"]["w"].value),
                                  np.asarray(tree["a"]["w"].value))
