"""Smoke test: the ``python -m repro characterize`` CLI, in-process.

Runs the quick plan filtered to a tiny registry subset so the whole
measure -> flush -> cache-hit -> force cycle executes in seconds.
"""
import json

from repro.api import cli

ARGS = ["characterize", "--plan", "quick", "--ops", "add,clock_overhead",
        "--reps", "2", "--warmup", "0"]


def test_characterize_quick_smoke(tmp_path, capsys):
    db = tmp_path / "db.json"
    rc = cli.main(ARGS + ["--db", str(db)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "4 measured, 0 cached, 0 failed" in out
    blob = json.loads(db.read_text())
    assert {r["op"] for r in blob["records"]} == {"add", "clock_overhead"}
    assert {r["opt_level"] for r in blob["records"]} == {"O0", "O3"}

    # second run: pure cache hits, zero re-measurements
    rc = cli.main(ARGS + ["--db", str(db)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 measured, 4 cached, 0 failed" in out
    assert "all probes were cache hits" in out

    # --force re-measures
    rc = cli.main(ARGS + ["--db", str(db), "--force"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "4 measured, 0 cached, 0 failed" in out


def test_characterize_table_output(tmp_path, capsys):
    db = tmp_path / "db.json"
    rc = cli.main(ARGS + ["--db", str(db), "--table"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "| category | op | dtype |" in out


def test_bad_flags(tmp_path, capsys):
    rc = cli.main(ARGS + ["--db", str(tmp_path / "db.json"), "--force", "--resume"])
    assert rc == 2
    rc = cli.main(["characterize", "--ops", "no_such_op",
                   "--db", str(tmp_path / "db.json")])
    assert rc == 2
