"""Collective ladders, explicit collectives, and the sharded-serving loop.

Fast tier: ladder geometry/wire-byte conventions, the estimator's collective
pricing term (hand-computed oracles), mesh-shape validation, Session
cache/resume for ``coll.*`` rows, and the sharded-serving CI gate logic.
Slow tier: multi-device numerics in subprocesses with
``--xla_force_host_platform_device_count`` — the quantized-psum
error-feedback regression, collective vs reference matmul across mesh sizes,
pipeline-parallel equivalence, and ladder fan-out merge.
"""
from __future__ import annotations

import os

import pytest

from repro.core import hlo_analysis, perfmodel
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.launch.mesh import make_mesh_for
from repro.parallel import ladders
from tests._subproc import run_with_devices

ENV = {"device_kind": "cpu", "backend": "cpu", "jax_version": "x"}


def _rec(op, ns, cat="collective", dtype="float32", opt="O3", notes=""):
    return LatencyRecord(op=op, category=cat, dtype=dtype, opt_level=opt,
                         latency_ns=ns, mad_ns=0, cycles=ns, guard=0,
                         net_latency_ns=ns, n_samples=5, measured_at="t",
                         notes=notes, **ENV)


# ------------------------------------------------------- ladder geometry
def test_payload_shape_rounds_up_to_devices_multiple():
    # 4096 B at 128 f32 cols -> 8 rows; already a multiple of 4
    assert ladders.payload_shape(4096, 4) == (8, 128)
    # 3 rows nominal, 4 devices -> rounded up to 4
    assert ladders.payload_shape(1536, 4) == (4, 128)
    assert ladders.local_payload_bytes(1536, 4) == 4 * 128 * 4
    # never zero rows
    assert ladders.payload_shape(1, 2) == (2, 128)


def test_step_wire_bytes_matches_ring_factor_conventions():
    local = 4096.0
    # psum -> all-reduce: 2(g-1)/g of the (shape-preserving) result
    assert ladders.step_wire_bytes("psum", local, 4) == \
        pytest.approx(1.5 * local)
    # all_gather result is local*devices, ring factor (g-1)/g
    assert ladders.step_wire_bytes("all_gather", local, 4) == \
        pytest.approx(0.75 * local * 4)
    # reduce_scatter result is local/devices, ring factor g-1
    assert ladders.step_wire_bytes("reduce_scatter", local, 4) == \
        pytest.approx(3 * local / 4)
    # ppermute is a point-to-point hop: exactly the payload
    assert ladders.step_wire_bytes("ppermute", local, 4) == \
        pytest.approx(local)
    # single device: nothing crosses the fabric
    for kind in ladders.LADDER_KINDS:
        assert ladders.step_wire_bytes(kind, local, 1) == 0.0


def test_ladder_kind_mapping_roundtrips():
    for kind, hlo_kind in hlo_analysis.LADDER_TO_COLLECTIVE.items():
        assert hlo_analysis.COLLECTIVE_TO_LADDER[hlo_kind] == kind
        assert hlo_kind in hlo_analysis.COLLECTIVE_KINDS
    assert set(ladders.LADDER_KINDS) == \
        set(hlo_analysis.LADDER_TO_COLLECTIVE)


# ------------------------------------------------------- mesh validation
def test_make_mesh_for_rejects_indivisible_shapes():
    with pytest.raises(ValueError) as exc:
        make_mesh_for(6, model_parallel=4)
    # the error must hand the caller shapes that would work
    assert "(3, 2)" in str(exc.value) and "(1, 6)" in str(exc.value)
    with pytest.raises(ValueError):
        make_mesh_for(4, model_parallel=3)
    with pytest.raises(ValueError):
        make_mesh_for(4, model_parallel=-2)


def test_make_mesh_for_valid_shapes_still_build():
    m = make_mesh_for(1)
    assert dict(zip(m.axis_names, m.devices.shape)) == {"data": 1, "model": 1}


# --------------------------------------------------------- probe naming
def test_collective_probe_row_naming_and_validation():
    from repro.api.probes import CollectiveProbe

    p = CollectiveProbe("psum", 4096, devices=4)
    assert p.op == "coll.psum.d4.4096"
    assert p.opt_level == "O3" and p.category == "collective"
    assert {"coll", "coll.psum", "coll.psum.d4.4096"} <= p.match_names()
    # non-default lens become a fidelity suffix (a different experiment)
    assert CollectiveProbe("psum", 4096, devices=4,
                           lens=(3, 9)).op == "coll.psum.d4.4096.l3-9"
    with pytest.raises(ValueError):
        CollectiveProbe("allreduce", 4096, devices=4)   # unknown kind
    with pytest.raises(ValueError):
        CollectiveProbe("psum", 0, devices=4)


def test_sharded_serving_probe_row_naming():
    from repro.api.probes import ShardedServingCostProbe

    p = ShardedServingCostProbe("prefill", 1, 16, tp=2)
    assert p.op == "serving.tp2.prefill.b1p16"
    assert {"serving", "serving.tp2", "serving.prefill"} <= p.match_names()
    with pytest.raises(ValueError):
        ShardedServingCostProbe("train", 1, 16, tp=2)
    with pytest.raises(ValueError):
        ShardedServingCostProbe("prefill", 1, 16, tp=0)


# ------------------------------------------- estimator collective oracle
AR_HLO = """HloModule m, num_partitions=8

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
}
"""
# parse_collectives: group 4, wire = 2*(3/4) * 64*64*4 = 24576 B


def test_collective_ladder_reads_rows_and_sorts():
    db = LatencyDB()
    db.add(_rec("coll.psum.d4.65536", 100.0,
                notes="kind=psum devices=4 payload_bytes=65536 "
                      "wire_bytes=98304"))
    db.add(_rec("coll.psum.d4.4096", 10.0,
                notes="kind=psum devices=4 payload_bytes=4096 "
                      "wire_bytes=6144"))
    # fidelity-suffixed rows are a different experiment: never in the ladder
    db.add(_rec("coll.psum.d4.4096.l3-9", 999.0))
    ladder = perfmodel.HloLatencyEstimator(db).collective_ladder()
    rungs = ladder["all-reduce"]
    assert [(g.devices, g.wire_bytes, g.ns) for g in rungs] == \
        [(4, 6144.0, 10.0), (4, 98304.0, 100.0)]


def test_estimator_prices_collective_from_covering_rung():
    """24576 wire B priced from the 98304-B rung: 24576/98304 * 100 = 25."""
    db = LatencyDB()
    db.add(_rec("coll.psum.d4.4096", 10.0,
                notes="kind=psum devices=4 wire_bytes=8192"))
    db.add(_rec("coll.psum.d4.65536", 100.0,
                notes="kind=psum devices=4 wire_bytes=98304"))
    r = perfmodel.HloLatencyEstimator(db).estimate(AR_HLO)
    assert r.collective_ns == pytest.approx(25.0)
    assert r.by_class["collective"].ns == pytest.approx(25.0)
    assert r.by_class["collective"].instances == 1.0
    # serial interconnect term: total = max(compute, memory) + collective
    assert r.total_ns == pytest.approx(
        max(r.compute_ns, r.memory_ns) + 25.0)
    assert not [u for u in r.unpriced_opcodes
                if u[0].startswith("collective:")]


def test_estimator_extrapolates_beyond_deepest_rung():
    db = LatencyDB()
    db.add(_rec("coll.psum.d4.4096", 10.0,
                notes="kind=psum devices=4 wire_bytes=6144"))
    r = perfmodel.HloLatencyEstimator(db).estimate(AR_HLO)
    # 24576 B exceeds the only rung (6144 B): linear extrapolation
    assert r.collective_ns == pytest.approx(24576 / 6144 * 10.0)


def test_unpriced_collective_is_never_default_priced():
    """No psum rungs in the DB: the all-reduce must contribute ZERO ns and
    be reported as unpriced — a silently default-priced collective would
    make every sharded prediction look covered when it is not."""
    db = LatencyDB()
    db.add(_rec("coll.ppermute.d4.4096", 10.0,
                notes="kind=ppermute devices=4 wire_bytes=4096"))
    r = perfmodel.HloLatencyEstimator(db, default_ns=5.0).estimate(AR_HLO)
    assert r.collective_ns == 0.0
    assert ("collective:all-reduce", 1.0) in list(r.unpriced_opcodes)
    assert r.by_class["unpriced"].instances >= 1.0
    assert "collective" not in r.by_class


def test_collective_markdown_renders_rungs(tmp_path):
    db = LatencyDB()
    db.add(_rec("coll.psum.d4.4096", 10.0,
                notes="kind=psum devices=4 payload_bytes=4096 "
                      "wire_bytes=6144 audit=audited"))
    md = db.compare_markdown(prefix="coll.")
    assert "coll.psum.d4.4096" in md
    assert "6144" in md and "audited" in md


def test_sharded_servingpoint_round_trip():
    rec = _rec("serving.tp2.prefill.b1p16", 5e5, cat="serving",
               notes="phase=prefill batch=1 prompt=16 tp=2 "
                     "model=serving-tiny predicted_ns=2.5e5 "
                     "compute_ns=1e5 memory_ns=2e5 collective_ns=5e4 "
                     "coll_ops=5 coll_unpriced=0 coverage=0.7 bound=memory")
    pt = perfmodel.servingpoint_from_record(rec)
    assert pt.tp == 2 and pt.phase == "prefill"
    assert pt.collective_ns == pytest.approx(5e4)
    assert pt.coll_unpriced == 0.0
    assert pt.predicted_ns == pytest.approx(2.5e5)


def test_check_sharded_serving_gate_flags_unpriced_collectives():
    import dataclasses

    from benchmarks.check_sharded_serving import check_points

    tol = {"max_abs_log10_ratio": 4.0, "min_coverage": 0.5,
           "max_coll_unpriced": 0}
    good = perfmodel.ServingPoint(
        phase="prefill", batch=1, prompt_len=16, model="serving-tiny",
        predicted_ns=2e5, measured_ns=4e5, compute_ns=1e5, memory_ns=1e5,
        coverage=0.7, tp=2, collective_ns=5e4, coll_unpriced=0.0)
    assert check_points([good], tol) == []
    bad = dataclasses.replace(good, coll_unpriced=3.0)
    msgs = check_points([bad], tol)
    assert len(msgs) == 1 and "3 collective op(s)" in msgs[0]
    uncovered = dataclasses.replace(good, coverage=0.1)
    assert any("coverage" in m for m in check_points([uncovered], tol))


# ------------------------------------------- Session cache/resume (d1)
def test_ladder_rows_cache_and_resume_through_session(tmp_path):
    from repro.api import Plan, Session
    from repro.core.timing import Timer

    db_path = str(tmp_path / "db.json")
    plan = Plan.collectives(kinds=("psum",), payloads=(4096,), devices=1)
    session = Session(db=db_path, timer=Timer(warmup=1, reps=2))
    first = session.run(plan)
    assert len(first.measured) == 1 and not first.failed
    rec = first.measured[0].record
    assert rec.op == "coll.psum.d1.4096" and rec.category == "collective"
    # resume: a fresh Session over the same DB file skips the row
    second = Session(db=db_path, timer=Timer(warmup=1, reps=2)).run(plan)
    assert len(second.cached) == 1 and not second.measured


def test_collectives_plan_dedupes_and_names_rows():
    from repro.api import Plan

    plan = Plan.collectives(kinds=("psum", "ppermute"),
                            payloads=(4096, 65536), devices=4)
    ops = [p.op for p in plan]
    assert len(ops) == len(set(ops)) == 4
    assert (Plan.collectives(kinds=("psum",), payloads=(4096,), devices=4)
            + Plan.collectives(kinds=("psum",), payloads=(4096,),
                               devices=4)).probes.__len__() == 1


# ----------------------------------------------------- multi-device tier
@pytest.mark.slow
def test_quantized_psum_error_feedback_lands_in_owned_rows():
    """The headline regression: after ``psum_scatter(tiled=True)`` device j
    owns rows [j*rows:(j+1)*rows], so its residual must be re-injected
    there. Feeding zero gradients on step 2 makes the output *exactly* the
    mean of the re-injected error maps — under the old block-0 write, blocks
    1..n-1 come back identically zero and this fails."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_for
from repro.parallel import collectives

mesh = make_mesh_for(4, model_parallel=1)
n, rows = 4, 8 // 4
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 256))}

out1, err1 = collectives.quantized_psum_mean(g, mesh, axis="data")
resid = np.asarray(g["w"] - out1["w"])       # true per-block residual map
zero = {"w": jnp.zeros_like(g["w"])}
out2, _ = collectives.quantized_psum_mean(zero, mesh, axis="data", error=err1)
got = np.asarray(out2["w"])
want = resid / n                             # psum-mean of one-owner blocks
scale = float(np.abs(want).max())
assert scale > 0
err_rest = float(np.abs(got[rows:] - want[rows:]).max())
assert err_rest < 0.2 * scale, (err_rest, scale)

# multi-step convergence: with feedback the time-averaged compressed mean
# beats the one-step quantization error; the old code pinned blocks >= 1 at
# exactly the one-step error forever (no correction ever reaches them)
onestep = float(np.abs(resid).max())
err = None
acc = jnp.zeros_like(g["w"])
T = 30
for _ in range(T):
    red, err = collectives.quantized_psum_mean(g, mesh, axis="data",
                                               error=err)
    acc = acc + red["w"]
avg_err = float(jnp.max(jnp.abs(acc / T - g["w"])))
assert avg_err < 0.9 * onestep, (avg_err, onestep)
print("FEEDBACK-OK", err_rest / scale, avg_err / onestep)
""", n_devices=4)
    assert "FEEDBACK-OK" in out


@pytest.mark.slow
def test_collective_matmul_matches_reference_across_mesh_sizes():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_for
from repro.parallel import collectives

x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
w = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
want = np.asarray(collectives.reference_matmul(x, w))
for model in (1, 2, 4):                       # n=1 is the degenerate ring
    mesh = make_mesh_for(8, model_parallel=model)
    y = collectives.collective_matmul(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-4, rtol=1e-4)
print("MATMUL-OK")
""", n_devices=8)
    assert "MATMUL-OK" in out


@pytest.mark.slow
def test_pipeline_forward_matches_reference_with_bubble_oracle():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel import pipeline

s, m, d = 4, 6, 16
mesh = make_mesh((s,), ("pod",))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

params = {"w": jax.random.normal(jax.random.PRNGKey(0), (s, d, d)) * 0.5,
          "b": jax.random.normal(jax.random.PRNGKey(1), (s, d)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(2), (m, 2, d))
got = pipeline.pipeline_forward(stage_fn, params, x, mesh, axis="pod")
want = pipeline.reference_forward(stage_fn, params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=1e-5, rtol=1e-5)
# GPipe fill-drain oracle: (S-1)/(M+S-1), and no bubble with one stage
assert abs(pipeline.bubble_fraction(s, m) - (s - 1) / (m + s - 1)) < 1e-12
assert pipeline.bubble_fraction(1, m) == 0.0
print("PIPELINE-OK")
""", n_devices=4)
    assert "PIPELINE-OK" in out


@pytest.mark.slow
def test_ladder_fan_out_merges_shard_dbs(tmp_path):
    db_path = str(tmp_path / "fan.json")
    out = run_with_devices(f"""
import jax
from repro.api import Plan, Session
from repro.core.timing import Timer

plan = Plan.collectives(kinds=("psum", "ppermute"), payloads=(4096,),
                        devices=2)
session = Session(db={db_path!r}, timer=Timer(warmup=1, reps=2))
result = session.fan_out(plan, devices=jax.local_devices()[:2])
assert len(result.measured) == 2 and not result.failed, result.summary()
ops = sorted(r.op for r in session.db.records())
assert ops == ["coll.ppermute.d2.4096", "coll.psum.d2.4096"], ops

# resume through the merged DB: every row is now a cache hit
again = Session(db={db_path!r},
                timer=Timer(warmup=1, reps=2)).fan_out(
    plan, devices=jax.local_devices()[:2])
assert len(again.cached) == 2 and not again.measured, again.summary()
print("FANOUT-OK")
""", n_devices=2)
    assert "FANOUT-OK" in out
