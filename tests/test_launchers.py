"""Launcher CLIs + paper-suite config + encdec serving consistency."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_suite import suite
from tests._subproc import SRC


def _run_cli(args, timeout=420):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout + out.stderr


def test_paper_suite_config():
    s = suite()
    assert {t.name for t in s.targets} == {"cpu-host", "tpu-v5e"}
    assert "O0" in s.opt_levels and "O3" in s.opt_levels
    assert len(s.categories) == 8            # the paper's 8 categories
    assert max(s.working_sets) > 1 << 24


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    # fresh dir per run: a leftover checkpoint makes the trainer resume at
    # step 3 and run 0 steps
    out = _run_cli(["repro.launch.train", "--arch", "granite-3-8b",
                    "--steps", "3", "--seq-len", "32", "--global-batch", "2",
                    "--checkpoint-dir", str(tmp_path / "ckpt")])
    assert "done: 3 steps" in out


@pytest.mark.slow
def test_serve_cli_smoke():
    out = _run_cli(["repro.launch.serve", "--arch", "yi-9b",
                    "--requests", "2", "--max-new", "4"])
    assert "req0:" in out


def test_encdec_prefill_decode_consistency():
    import dataclasses
    from repro.configs.registry import get
    from repro.models import encdec
    from repro.models.config import Runtime
    cfg = dataclasses.replace(get("seamless-m4t-large-v2").smoke,
                              param_dtype="float32", compute_dtype="float32")
    rt = Runtime(remat=False, xent_chunk=16, moe_groups=1)
    key = jax.random.PRNGKey(0)
    b, s = 2, 17
    params = encdec.init_encdec(key, cfg)
    frames = jax.random.normal(key, (b, 8, cfg.d_model))
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    # gold: teacher-forced full decode, last position
    memory = encdec.encode(params, cfg, rt, frames)
    h, _ = encdec.decode_train(params, cfg, rt, memory, tokens)
    from repro.models import common
    gold = common.top1_logits(h[:, -1], params["embed"].value)
    # prefill s-1 then decode the last token
    _, caches = encdec.prefill(params, cfg, rt, frames, tokens[:, :-1])
    caches = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, 1)] + [(0, 0)] * 2)
        if a.ndim == 6 else a, caches)
    # pad self-attn caches (k/v) along seq; cross caches stay
    def pad(path, a):
        k = path[-1].key
        if k in ("k", "v") and a.shape[2] == s - 1:
            return jnp.pad(a, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
        return a
    caches = jax.tree_util.tree_map_with_path(pad, caches)
    logits, _ = encdec.decode_step(params, caches, tokens[:, -1:], s - 1, cfg, rt)
    np.testing.assert_allclose(np.asarray(gold), np.asarray(logits),
                               atol=2e-4, rtol=2e-4)
