"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,sk,h,kh,d", [
    (1, 128, 128, 4, 4, 64),     # MHA square
    (2, 128, 128, 4, 2, 32),     # GQA
    (1, 64, 192, 6, 3, 16),      # sq != sk (prefix cache)
    (2, 256, 256, 8, 1, 64),     # MQA
])
def test_flash_attention(b, sq, sk, h, kh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kh, d), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 96, 2, 32))
    v = jax.random.normal(ks[2], (2, 96, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,s,h,kh,d", [(2, 256, 8, 2, 64), (3, 128, 4, 4, 32),
                                        (1, 512, 2, 1, 128)])
def test_flash_decode(b, s, h, kh, d):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    kv_len = jnp.asarray([max(s - 13 * i, 1) for i in range(b)], jnp.int32)
    out = ops.flash_decode(q, k, v, kv_len, interpret=True)
    want = ref.ref_decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("rows,d", [(64, 128), (96, 256), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), jnp.float32).astype(dtype)
    out = ops.rmsnorm(x, w, interpret=True)
    want = ref.ref_rmsnorm(x, w)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **tol(dtype))


@pytest.mark.parametrize("op", ["fma", "add", "mul", "rsqrt", "exp"])
def test_alu_chain(op):
    x = jax.random.uniform(KEY, (8, 128), jnp.float32) + 0.5
    a = jnp.full((8, 128), 0.5, jnp.float32)
    out = ops.alu_chain(x, a, n=8, op=op, interpret=True)
    if op == "fma":
        want = ref.ref_alu_chain(x, a, 8)
        np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("n,steps", [(32, 64), (128, 301)])
def test_chase(n, steps):
    rng = np.random.RandomState(3)
    idx = np.arange(n)
    rng.shuffle(idx)
    ring = np.empty(n, np.int32)
    ring[idx[:-1]] = idx[1:]
    ring[idx[-1]] = idx[0]
    out = ops.chase(jnp.asarray(ring), jnp.asarray([int(idx[0])]),
                    steps=steps, interpret=True)
    assert int(out[0]) == ref.ref_chase(ring, int(idx[0]), steps)


@pytest.mark.parametrize("b,s,dm,n,chunk", [(2, 64, 16, 8, 16), (1, 96, 8, 4, 32)])
def test_mamba_scan(b, s, dm, n, chunk):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, dm)) * 0.5
    dt = jax.random.normal(ks[1], (b, s, dm)) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (dm, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    D = jax.random.normal(ks[5], (dm,)) * 0.1
    y = ops.mamba_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    want, _ = ref.ref_selective_scan(x, dt, A, B, C, D)
    np.testing.assert_allclose(y, want, atol=5e-5, rtol=5e-5)
