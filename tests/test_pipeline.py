"""Compile-ahead pipeline, persistent compile cache, journal-delta flush.

The load-bearing invariant: pipelining and caching change *when compiles
happen*, never *what gets measured*. Records from a pipelined run must be
value-identical to a serial run of the same plan — including failure
records — and a resumed sweep with a warm compile cache must compile zero
XLA modules.
"""
import os
import threading

import pytest

from repro.api import Plan, Probe, Session
from repro.core import compile_cache as cc
from repro.core.compile_cache import CompileCache, fidelity_key
from repro.core.latency_db import LatencyDB
from repro.core.timing import Measurement, Timer


class SplitProbe(Probe):
    """Scripted probe with a prepare/run_prepared split: deterministic
    Measurement per op, optional scripted failures, thread-name log."""

    category = "test"

    def __init__(self, op, value, prepare_error=None, run_error=None, log=None):
        self.op = op
        self.opt_level = "O3"
        self.dtype = "float32"
        self.value = value
        self.prepare_error = prepare_error
        self.run_error = run_error
        self.log = log if log is not None else []

    def prepare(self, ctx):
        self.log.append(("prepare", self.op, threading.current_thread().name))
        if self.prepare_error is not None:
            raise self.prepare_error
        return ("prepared", self.op)

    def run_prepared(self, ctx, prepared):
        if prepared is None:
            return self.run(ctx)
        self.log.append(("run", self.op, threading.current_thread().name))
        if self.run_error is not None:
            raise self.run_error
        return self._record(ctx, Measurement(self.value, self.value / 8,
                                             self.value, 5))

    def run(self, ctx):
        self.log.append(("run", self.op, threading.current_thread().name))
        if self.run_error is not None:
            raise self.run_error
        return self._record(ctx, Measurement(self.value, self.value / 8,
                                             self.value, 5))


def _timer():
    # fixed clock_hz: the cycles field must not depend on calibration noise
    return Timer(warmup=0, reps=2, clock_hz=1e9)


def _scripted_plan():
    return Plan((SplitProbe("alpha", 12.0),
                 SplitProbe("bad-prep", 1.0,
                            prepare_error=ValueError("no lowering")),
                 SplitProbe("beta", 34.5),
                 SplitProbe("bad-run", 1.0,
                            run_error=RuntimeError("timed out")),
                 SplitProbe("gamma", 56.25)))


# ----------------------------------------------------------- invariance
def test_pipelined_records_identical_to_serial():
    serial = Session(timer=_timer()).run(_scripted_plan(), pipeline=False)
    piped = Session(timer=_timer()).run(_scripted_plan(), pipeline=True)

    assert [r.status for r in serial.results] == [r.status for r in piped.results]
    assert [r.status for r in piped.results] == \
        ["measured", "failed", "measured", "failed", "measured"]
    for rs, rp in zip(serial.results, piped.results):
        if rs.record is not None:
            for field in ("op", "latency_ns", "mad_ns", "net_latency_ns",
                          "cycles", "n_samples", "guard"):
                assert getattr(rs.record, field) == getattr(rp.record, field), field
        else:
            for field in ("op", "error_type", "message"):
                assert getattr(rs.failure, field) == getattr(rp.failure, field), field


def test_pipeline_compiles_on_worker_thread_times_on_main():
    log = []
    plan = Plan(tuple(SplitProbe(f"p{i}", 10.0 * (i + 1), log=log)
                      for i in range(3)))
    Session(timer=_timer()).run(plan)  # pipelined default
    prep = {t for kind, _, t in log if kind == "prepare"}
    runs = {t for kind, _, t in log if kind == "run"}
    assert prep and all(t.startswith("repro-compile") for t in prep)
    assert runs == {threading.current_thread().name}

    log.clear()
    Session(timer=_timer()).run(plan, pipeline=False)
    assert {t for _, _, t in log} == {threading.current_thread().name}


def test_pipeline_falls_back_to_run_for_plain_probes():
    """Third-party probes that only implement run() work pipelined."""
    runs = {}

    class PlainProbe(Probe):
        category = "test"

        def __init__(self, op):
            self.op, self.opt_level, self.dtype = op, "O3", "float32"

        def run(self, ctx):
            runs[self.op] = runs.get(self.op, 0) + 1
            return self._record(ctx, Measurement(7.0, 0.5, 6.5, 3))

    result = Session(timer=_timer()).run(
        Plan((PlainProbe("a"), PlainProbe("b"))), pipeline=True)
    assert len(result.measured) == 2
    assert runs == {"a": 1, "b": 1}


# -------------------------------------------------------- compile cache
def _require_serializer():
    if cc._serializer() is None:
        pytest.skip("jax.experimental.serialize_executable unavailable")


def test_compile_cache_round_trip_and_counters(tmp_path):
    _require_serializer()
    import jax
    import jax.numpy as jnp

    cache = CompileCache(str(tmp_path / "xc"))
    key = ("cpu", "cpu", "x", "add", "O3", "float32", "chain4")
    x = jnp.arange(4, dtype=jnp.float32)

    def build():
        return jax.jit(lambda v: v + 1).lower(x).compile()

    c1, extra, hit = cache.load_or_compile(key, build, extra=lambda c: "hlo")
    assert not hit and cache.stats.misses == 1 and cache.stats.stores == 1
    assert len(cache) == 1

    # second lookup: deserialized executable, stored extra rides along
    c2, extra2, hit2 = cache.load_or_compile(
        key, lambda: pytest.fail("must not recompile"))
    assert hit2 and extra2 == "hlo" and cache.stats.hits == 1
    assert jnp.allclose(c2(x), x + 1)


def test_compile_cache_eviction_and_corrupt_entries(tmp_path):
    _require_serializer()
    import jax
    import jax.numpy as jnp

    cache = CompileCache(str(tmp_path / "xc"), max_entries=1)
    x = jnp.asarray(1.0, jnp.float32)
    for i in range(2):
        cache.store(("k", str(i)),
                    jax.jit(lambda v: v * (i + 1)).lower(x).compile())
    assert len(cache) == 1 and cache.stats.evictions == 1

    # a torn/foreign entry is a miss plus an error counter, never a crash
    bad_key = ("k", "corrupt")
    with open(cache.entry_path(bad_key), "wb") as f:
        f.write(b"not a pickle")
    assert cache.load(bad_key) is None
    assert cache.stats.errors == 1


def test_fidelity_key_layout():
    env = {"device_kind": "TPU v9", "backend": "tpu", "jax_version": "9.9"}
    key = fidelity_key(env, "add", "O3", "int32", "chain24")
    assert key == ("TPU v9", "tpu", "9.9", "add", "O3", "int32", "chain24")


def test_cache_stats_are_per_run_deltas(tmp_path):
    """summary() reports THIS run's compile work, not cache lifetime totals
    — the warm-run '0 compiled' check must hold in-process too."""
    _require_serializer()
    from repro.api.probes import ClockOverheadProbe

    session = Session(db=str(tmp_path / "db.json"), timer=_timer(),
                      compile_cache=str(tmp_path / "xc"))
    plan = Plan((ClockOverheadProbe("O3"),))
    r1 = session.run(plan)
    assert r1.cache_stats.misses == 1 and r1.cache_stats.hits == 0
    assert "1 compiled" in r1.summary()
    r2 = session.run(plan, force=True)
    assert r2.cache_stats.misses == 0 and r2.cache_stats.hits == 1
    assert "compile cache: 1 hits, 0 compiled" in r2.summary()


def test_resume_after_interrupt_with_warm_compile_cache(tmp_path):
    """Interrupted sweep + re-run with the same cache dir: completed probes
    are DB hits via the journal, remaining probes' executables deserialize,
    and zero XLA modules compile."""
    _require_serializer()
    from repro.api.probes import ClockOverheadProbe

    cache = str(tmp_path / "xc")
    a, c = ClockOverheadProbe("O3"), ClockOverheadProbe("O1")

    # a prior completed sweep filled the executable cache
    r0 = Session(db=str(tmp_path / "db0.json"), timer=_timer(),
                 compile_cache=cache).run(Plan((a, c)))
    assert r0.cache_stats.misses == 2

    # fresh DB, same cache: interrupt lands after A, C never starts
    db = tmp_path / "db.json"
    boom = SplitProbe("boom", 1.0, run_error=KeyboardInterrupt())
    with pytest.raises(KeyboardInterrupt):
        Session(db=str(db), timer=_timer(), compile_cache=cache).run(
            Plan((a, boom, c)), pipeline=False)
    assert os.path.exists(str(db) + ".journal")  # A is durable, uncompacted

    # resume: A cached from the journal, boom (fixed) + C measure, 0 compiles
    r2 = Session(db=str(db), timer=_timer(), compile_cache=cache).run(
        Plan((a, SplitProbe("boom", 1.0), c)))
    assert [r.status for r in r2.results] == ["cached", "measured", "measured"]
    assert r2.cache_stats.misses == 0 and r2.cache_stats.hits == 1
    assert "0 compiled" in r2.summary()
    assert not os.path.exists(str(db) + ".journal")  # compacted on save


# ----------------------------------------------------- adaptive fidelity
def test_adaptive_reps_eff_lands_in_notes():
    adaptive = Session(timer=_timer(), adaptive=True).run(
        Plan((SplitProbe("alpha", 5.0),)))
    assert "reps_eff=5" in adaptive.measured[0].record.notes
    plain = Session(timer=_timer()).run(Plan((SplitProbe("alpha", 5.0),)))
    assert "reps_eff" not in (plain.measured[0].record.notes or "")


# ------------------------------------------------------ delta-only flush
def test_run_issues_exactly_one_whole_file_write(tmp_path, monkeypatch):
    """Per-probe durability is journal appends; dump_json (the whole-file
    O(N) serialization) runs once per run — the final compaction — not once
    per probe. The old behavior was N whole-file rewrites for N probes."""
    from repro.core import latency_db as ldb

    calls = []
    real = ldb.dump_json

    def counting(obj, path):
        calls.append(path)
        return real(obj, path)

    monkeypatch.setattr(ldb, "dump_json", counting)
    db = tmp_path / "db.json"
    plan = Plan(tuple(SplitProbe(f"op{i}", float(i + 1)) for i in range(10)))
    result = Session(db=str(db), timer=_timer()).run(plan)
    assert len(result.measured) == 10
    assert calls == [str(db)]
    assert not os.path.exists(str(db) + ".journal")
    assert len(LatencyDB(str(db))) == 10
