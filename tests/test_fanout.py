"""Multi-device fan-out: device-pinned sessions, Plan.shard, Session.fan_out,
concurrent-flush safety, and the --shard CLI.

Fast tests use fake probes / fake devices in-process (conftest keeps the
process at 1 real device on purpose); end-to-end multi-device coverage runs
in subprocesses with ``--xla_force_host_platform_device_count`` (slow tier).
"""
import json
import threading

import pytest

from repro.api import Plan, Session
from repro.api.plan import _compose_name, named_plan
from repro.core.latency_db import LatencyDB, current_environment
from repro.core.timing import Measurement, Timer
from tests._subproc import run_with_devices


class FakeProbe:
    """Deterministic probe (no jax work) for scheduler-level tests."""

    category = "test"
    dtype = "float32"

    def __init__(self, op, opt_level="O3", runs=None):
        self.op = op
        self.opt_level = opt_level
        self.runs = runs if runs is not None else {}

    def logical_key(self):
        return (self.op, self.opt_level, self.dtype)

    def match_names(self):
        return frozenset((self.op,))

    def key(self, env):
        return (env["device_kind"], env["backend"], env["jax_version"],
                self.opt_level, self.op, self.dtype)

    def run(self, ctx):
        self.runs[self.op] = self.runs.get(self.op, 0) + 1
        from repro.api.probes import Probe

        return Probe._record(self, ctx, Measurement(10.0, 1.0, 9.0, 3))


def _plan(ops, runs=None):
    return Plan(tuple(FakeProbe(op, runs=runs) for op in ops))


# --------------------------------------------------------------- Plan.shard
def test_shard_partitions_disjoint_and_complete():
    plan = named_plan("table2")
    for n in (1, 2, 3, 7):
        shards = plan.shard(n)
        assert len(shards) == n
        keys = [p.logical_key() for s in shards for p in s]
        assert sorted(keys) == sorted(p.logical_key() for p in plan.dedupe())
        assert len(keys) == len(set(keys))  # disjoint
        sizes = [len(s) for s in shards]
        assert max(sizes) - min(sizes) <= 1  # balanced round-robin


def test_shard_more_shards_than_probes_and_bad_n():
    plan = _plan(["a", "b"])
    shards = plan.shard(5)
    assert [len(s) for s in shards] == [1, 1, 0, 0, 0]
    with pytest.raises(ValueError):
        plan.shard(0)


def test_shard_names_mention_parent():
    s = named_plan("quick").shard(2)
    assert s[0].name == "quick[shard 1/2]"
    assert s[1].name == "quick[shard 2/2]"


# --------------------------------------------------- composed-name capping
def test_plan_add_name_is_capped():
    plans = [Plan((FakeProbe(f"op{i}"),), name=f"plan{i}") for i in range(8)]
    total = plans[0]
    for p in plans[1:]:
        total = total + p
    assert total.name == "plan0+plan1+plan2+5more"
    assert len(total) == 8  # probes themselves are never dropped
    # re-adding an already-named component neither grows nor duplicates
    assert (total + plans[0]).name == total.name
    assert _compose_name("a+b", "b+c") == "a+b+c"


# ------------------------------------------------- filter by base-row name
def test_filter_matches_derived_op_names():
    plan = named_plan("inkernel").filter(ops=["add"])
    assert {p.op for p in plan} == {"inkernel.add", "add"}
    # the pre-fix behavior silently produced an empty plan here
    assert len(named_plan("inkernel").filter(ops=["add", "mul"])) == 4


def test_filter_base_row_is_exact_not_prefix():
    # "add" must not sweep in the distinct registry row "add.bfloat16"
    plan = Plan.instructions(opt_levels=("O3",)).filter(ops=["add"])
    assert {p.op for p in plan} == {"add"}


def test_filter_matches_fidelity_suffixed_memory_probe():
    from repro.api.probes import MemoryProbe

    quick = Plan((MemoryProbe(8192, steps=(512, 1536)),))
    assert len(quick.filter(ops=["mem.chase.ws8192"])) == 1
    assert len(quick.filter(ops=["mem.chase.ws8192.s512-1536"])) == 1
    assert len(quick.filter(ops=["mem.chase.ws4096"])) == 0


# --------------------------------------------------------- device pinning
def test_current_environment_derives_from_explicit_device():
    class Dev:
        device_kind = "FakeTPU v9"
        platform = "tpu"

    env = current_environment(Dev())
    assert env["device_kind"] == "FakeTPU v9"
    assert env["backend"] == "tpu"
    # default stays the process-default device
    assert current_environment()["backend"] in ("cpu", "tpu", "gpu")


def test_session_accepts_device_index_and_pins_timer():
    import jax

    session = Session(device=0, timer=Timer(warmup=0, reps=1))
    assert session.device == jax.devices()[0]
    assert session.timer.device == jax.devices()[0]
    assert session.env == current_environment(jax.devices()[0])


def test_session_rejects_timer_pinned_elsewhere():
    """A shared timer pinned to another device would silently override the
    session's pin inside time_callable — refuse the mismatch loudly."""
    import jax

    t = Timer(warmup=0, reps=1)
    Session(device=0, timer=t)          # pins the fresh timer
    assert t.device == jax.devices()[0]
    Session(device=0, timer=t)          # same pin: fine

    class OtherDev:  # stands in for a second device (process only has one)
        device_kind = "cpu"
        platform = "cpu"
        id = 99

    with pytest.raises(ValueError, match="pinned"):
        Session(device=OtherDev(), timer=t)


def test_saved_db_not_owner_only(tmp_path):
    """dump_json's unique temp file must not leak mkstemp's 0600 mode onto
    the flushed DB (umask-derived mode, like a plain open())."""
    import os
    import stat

    db = LatencyDB(str(tmp_path / "db.json"))
    db.save()
    mode = stat.S_IMODE(os.stat(db.path).st_mode)
    umask = os.umask(0)
    os.umask(umask)
    assert mode == (0o666 & ~umask)


def test_baseline_cache_partitioned_by_device():
    pinned = Session(device=0, timer=Timer(warmup=0, reps=1))
    unpinned = Session(timer=Timer(warmup=0, reps=1))
    assert pinned._device_token() is not None
    assert unpinned._device_token() is None
    pinned._baseline[(pinned._device_token(), "O3", True)] = 1.25
    # baseline_ns reads exactly the device-partitioned key...
    assert pinned.baseline_ns("O3") == 1.25
    # ...so the same (opt_level, use_db) under another device token is a miss:
    # a fan-out shard can never read another device's baseline
    assert (unpinned._device_token(), "O3", True) not in pinned._baseline


# ------------------------------------------------------- fan_out scheduler
def test_fan_out_single_device_equals_run(tmp_path):
    runs = {}
    db = str(tmp_path / "db.json")
    session = Session(db=db, timer=Timer(warmup=0, reps=1))
    result = session.fan_out(_plan(["a", "b", "c"], runs=runs),
                             devices=[None])  # unpinned single shard
    assert result.summary().startswith("3 measured")
    assert runs == {"a": 1, "b": 1, "c": 1}
    assert len(LatencyDB(db)) == 3
    # second fan-out: every shard sees the flushed records as cache hits
    again = session.fan_out(_plan(["a", "b", "c"], runs=runs), devices=[None])
    assert len(again.cached) == 3 and runs == {"a": 1, "b": 1, "c": 1}


def test_fan_out_requires_devices():
    with pytest.raises(ValueError):
        Session(timer=Timer(warmup=0, reps=1)).fan_out(_plan(["a"]), devices=[])


def test_fan_out_merges_in_memory_dbs_without_path():
    session = Session(timer=Timer(warmup=0, reps=1))
    result = session.fan_out(_plan(["a", "b", "c", "d"]), devices=[None, None])
    assert len(result.measured) == 4
    assert len(session.db) == 4  # merged despite no disk path


# ------------------------------------- concurrent flushes must not clobber
def test_concurrent_sessions_one_db_path_lose_no_records(tmp_path):
    """Regression for the clobber bug: two sessions interleaving per-probe
    flushes to one path used to each rewrite the whole file, so the last
    writer silently dropped the other's records."""
    db = str(tmp_path / "shared.json")
    plans = (_plan([f"x{i}" for i in range(6)]),
             _plan([f"y{i}" for i in range(6)]))
    sessions = [Session(db=LatencyDB(path=db), timer=Timer(warmup=0, reps=1))
                for _ in plans]
    threads = [threading.Thread(target=s.run, args=(p,))
               for s, p in zip(sessions, plans)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ops = {r.op for r in LatencyDB(db).records()}
    assert ops == {f"x{i}" for i in range(6)} | {f"y{i}" for i in range(6)}


# ----------------------------------------------- end-to-end (2 sim devices)
@pytest.mark.slow
def test_sharded_equals_serial_on_simulated_devices():
    """Acceptance: fan_out of a table2 subset over 2 simulated devices yields
    the same record set as the serial run, merged into one DB."""
    out = run_with_devices("""
import jax
assert len(jax.local_devices()) == 2, jax.local_devices()
from repro.api import Session, named_plan
from repro.core.timing import Timer

# the table2 plan, trimmed to a fast registry subset (same probe types)
plan = named_plan("table2").filter(
    ops=("clock_overhead", "add", "mul", "sqrt", "popc"))
plan = plan.filter(opt_levels=("O0", "O3"))
assert len(plan) == 10, [p.op for p in plan]
serial = Session(timer=Timer(warmup=0, reps=2)).run(plan)
fan = Session(timer=Timer(warmup=0, reps=2))
result = fan.fan_out(plan)
assert not result.failed and not serial.failed
skeys = sorted(r.key() for r in serial.db.records())
fkeys = sorted(r.key() for r in result.db.records())
assert skeys == fkeys, (skeys, fkeys)
print("OK", len(fkeys))
""", n_devices=2)
    assert "OK 10" in out


@pytest.mark.slow
def test_fan_out_pins_each_shard_to_its_device():
    out = run_with_devices("""
import jax
from repro.api import Plan, Session
from repro.core.timing import Timer

devs = jax.local_devices()
session = Session(timer=Timer(warmup=0, reps=1))
seen = []
orig_init = Session.__init__
def spy(self, *a, **kw):
    orig_init(self, *a, **kw)
    if kw.get("device") is not None:
        seen.append((kw["device"].id, self.timer.device.id))
Session.__init__ = spy
session.fan_out(Plan.instructions(ops=("add", "mul"), opt_levels=("O3",)),
                devices=devs)
assert sorted(seen) == [(0, 0), (1, 1)], seen
print("PINNED", len(seen))
""", n_devices=2)
    assert "PINNED 2" in out


@pytest.mark.slow
def test_shard_cli_smoke(tmp_path):
    db = tmp_path / "db.json"
    out = run_with_devices(f"""
from repro.api import cli
args = ["characterize", "--plan", "table2", "--ops", "add,mul",
        "--opt-levels", "O3", "--reps", "2", "--warmup", "0",
        "--db", {str(db)!r}, "--shard", "auto"]
assert cli.main(args) == 0
assert cli.main(args) == 0  # second run: shards resume from the merged DB
""", n_devices=2)
    blob = json.loads(db.read_text())
    assert {r["op"] for r in blob["records"]} == {"add", "mul"}
    assert not blob["failures"]


def test_shard_cli_rejects_garbage(tmp_path, capsys):
    from repro.api import cli

    rc = cli.main(["characterize", "--plan", "quick", "--ops", "add",
                   "--db", str(tmp_path / "db.json"), "--shard", "zero"])
    assert rc == 2
    assert "--shard" in capsys.readouterr().err
