"""Import-only smoke over every file in examples/.

Each example must import cleanly (its module-level code runs; ``main()`` stays
behind the ``__main__`` guard) and expose a ``main`` entry point. This is the
regression lock for examples drifting behind API changes: a renamed or
removed entry point fails here instead of on a user's machine.
"""
import importlib.util
import pathlib
import sys

import pytest

EXAMPLES_DIR = pathlib.Path(__file__).resolve().parent.parent / "examples"
EXAMPLES = sorted(EXAMPLES_DIR.glob("*.py"))


def test_examples_dir_discovered():
    assert len(EXAMPLES) >= 5, EXAMPLES_DIR


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_imports_and_has_main(path):
    spec = importlib.util.spec_from_file_location(f"_example_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.modules.pop(spec.name, None)
    assert callable(getattr(mod, "main", None)), \
        f"{path.name} has no main() entry point"
