"""The op registry: coverage, stability, anti-optimization guarantees."""
import contextlib

import jax
import jax.numpy as jnp
import pytest

from repro.core import chains


REG = chains.default_registry()


def _ctx(spec):
    if spec.requires_x64 or spec.dtype in ("int64", "uint64", "float64"):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def test_all_categories_covered():
    cats = {o.category for o in REG}
    assert cats == set(chains.CATEGORIES)


def test_paper_table_ops_present():
    names = {o.name for o in REG}
    for required in ("add", "mul", "div.s.regular", "div.s.irregular",
                     "div.s.runtime", "rem.s", "abs", "and", "xor", "shl",
                     "cnot", "fma.float32", "div.runtime.float32",
                     "add.float64", "add.bfloat16", "add.cc", "mul64hi",
                     "rcp", "sqrt", "rsqrt", "sin", "cos", "lg2", "ex2",
                     "copysign", "sad", "popc", "clz", "bfe", "bfi", "mul24"):
        assert required in names, required


@pytest.mark.parametrize("spec", REG, ids=lambda s: s.name)
def test_chain_stable_at_512(spec):
    """No NaN/Inf after a 512-op chain (the measurement length)."""
    with _ctx(spec):
        out = chains.chain_fn(spec, 512)(spec.carry(), *spec.operand_arrays())
        if jnp.issubdtype(out.dtype, jnp.floating):
            assert bool(jnp.isfinite(out)), spec.name


@pytest.mark.parametrize("spec", [s for s in REG if s.dtype in
                                  ("int32", "uint32") and s.guard <= 1],
                         ids=lambda s: s.name)
def test_chain_not_collapsed_by_xla(spec):
    """The compiled 256-chain must keep >= 64 real ops (no reassociation
    collapse) — this is the paper's dependent-dummy-op defence, verified on
    the optimized HLO."""
    with _ctx(spec):
        args = (spec.carry(), *spec.operand_arrays())
        txt = jax.jit(chains.chain_fn(spec, 256)).lower(*args).compile().as_text()
    body_ops = sum(txt.count(f" {op}(") for op in
                   ("add", "subtract", "multiply", "divide", "and", "or",
                    "xor", "not", "shift-left", "shift-right-logical",
                    "shift-right-arithmetic", "maximum", "minimum", "abs",
                    "remainder", "compare", "popcnt", "count-leading-zeros",
                    "select"))
    assert body_ops >= 64, f"{spec.name}: chain collapsed to {body_ops} ops"


def test_div_regular_strength_reduced():
    """The compiler turns const-pow2 int division into shifts (paper's
    'regular' divisor observation) but keeps runtime divisors as divides."""
    reg = next(o for o in REG if o.name == "div.s.regular")
    run = next(o for o in REG if o.name == "div.s.runtime")
    t_reg = jax.jit(chains.chain_fn(reg, 64)).lower(
        reg.carry(), *reg.operand_arrays()).compile().as_text()
    t_run = jax.jit(chains.chain_fn(run, 64)).lower(
        run.carry(), *run.operand_arrays()).compile().as_text()
    assert t_run.count(" divide(") >= 32
    assert t_reg.count(" divide(") == 0, "pow-2 divide not strength-reduced"
