"""repro.traffic: traces, continuous-batching scheduler, simulator, metrics.

The scheduler tests run against a scripted executor (deterministic costs and
token streams, no engine) so the batching *policy* — FIFO admission, virtual
clock, eos slot recycling — is pinned independently of model behavior; the
engine-backed SlotPool semantics live in test_serving.py, and the end-to-end
predicted-vs-measured loop in the slow CLI test at the bottom.
"""
import json
import math

import numpy as np
import pytest

from repro.traffic import (ContinuousBatchingScheduler, Request, TraceConfig,
                           generate_trace, load_trace, save_trace, simulate,
                           slo_table, summarize)
from repro.traffic.metrics import request_metrics


# ==================================================================== traces
def test_trace_same_config_replays_identically():
    cfg = TraceConfig(n_requests=16, rate_rps=40.0, seed=5)
    assert generate_trace(cfg) == generate_trace(cfg)


def test_trace_seed_and_rate_change_the_stream():
    base = TraceConfig(n_requests=8, rate_rps=40.0, seed=0)
    a = generate_trace(base)
    import dataclasses

    b = generate_trace(dataclasses.replace(base, seed=1))
    c = generate_trace(dataclasses.replace(base, rate_rps=400.0))
    assert a != b
    assert [r.arrival_ns for r in a] != [r.arrival_ns for r in c]


def test_trace_request_shape():
    cfg = TraceConfig(n_requests=32, rate_rps=100.0, prompt_len=(2, 5),
                      max_new=(3, 6), vocab_size=50)
    trace = generate_trace(cfg)
    assert [r.uid for r in trace] == list(range(32))
    arr = [r.arrival_ns for r in trace]
    assert arr == sorted(arr) and arr[0] > 0
    for r in trace:
        assert 2 <= r.prompt_len <= 5 and 3 <= r.max_new <= 6
        assert all(1 <= t < 50 for t in r.prompt)     # 0 is the pad token


def test_trace_gamma_burstiness_clusters_arrivals():
    kw = dict(n_requests=400, rate_rps=100.0, seed=3, process="gamma")
    smooth = generate_trace(TraceConfig(burstiness_cv=0.25, **kw))
    bursty = generate_trace(TraceConfig(burstiness_cv=4.0, **kw))

    def cv(trace):
        gaps = np.diff([0.0] + [r.arrival_ns for r in trace])
        return float(np.std(gaps) / np.mean(gaps))

    assert cv(smooth) < 0.5 < 2.0 < cv(bursty)


def test_trace_json_round_trip(tmp_path):
    cfg = TraceConfig(n_requests=6, rate_rps=25.0, seed=9)
    trace = generate_trace(cfg)
    path = save_trace(str(tmp_path / "t.json"), trace, cfg)
    assert load_trace(path) == trace
    assert json.load(open(path))["config"]["seed"] == 9


def test_trace_config_validation():
    with pytest.raises(ValueError, match="n_requests"):
        TraceConfig(n_requests=0, rate_rps=1.0)
    with pytest.raises(ValueError, match="rate_rps"):
        TraceConfig(n_requests=1, rate_rps=0.0)
    with pytest.raises(ValueError, match="process"):
        TraceConfig(n_requests=1, rate_rps=1.0, process="uniform")
    with pytest.raises(ValueError, match="prompt_len"):
        TraceConfig(n_requests=1, rate_rps=1.0, prompt_len=(0, 4))
    with pytest.raises(ValueError, match="max_new"):
        TraceConfig(n_requests=1, rate_rps=1.0, max_new=(5, 4))


# ================================================================= scheduler
class ScriptedExecutor:
    """Deterministic executor: fixed admit/step costs, scripted eos tokens.

    ``eos_at[uid] = k`` makes that request's k-th decode token the eos
    (token 99); everything else emits token 7.
    """

    EOS = 99

    def __init__(self, n_slots=2, admit_ns=1000.0, step_ns=500.0,
                 eos_at=None):
        self.n_slots = n_slots
        self.admit_ns, self.step_ns = admit_ns, step_ns
        self.eos_at = eos_at or {}
        self.slot_state = {}        # slot -> [uid, tokens emitted after first]
        self.evictions = []

    def admit(self, slot, req):
        assert slot not in self.slot_state, "admitted into an occupied slot"
        self.slot_state[slot] = [req.uid, 0]
        return 7, self.admit_ns

    def step(self):
        toks = np.full(self.n_slots, 7, np.int32)
        for slot, st in self.slot_state.items():
            st[1] += 1
            if self.eos_at.get(st[0]) == st[1]:
                toks[slot] = self.EOS
        return toks, self.step_ns

    def evict(self, slot):
        self.evictions.append((slot, self.slot_state.pop(slot)[0]))


def _req(uid, arrival_ns, max_new=8, plen=2):
    return Request(uid=uid, arrival_ns=arrival_ns,
                   prompt=tuple(range(1, plen + 1)), max_new=max_new)


def test_scheduler_eos_frees_slot_for_late_request_before_batch_drains():
    """The continuous-batching acceptance test: a request arriving while the
    pool is full must be admitted into the slot freed by an earlier row's
    eos, while the other request is still decoding."""
    ex = ScriptedExecutor(n_slots=2, eos_at={0: 2})
    trace = [_req(0, 0.0), _req(1, 0.0), _req(2, 100.0)]
    res = ContinuousBatchingScheduler(ex, eos_id=ScriptedExecutor.EOS).run(trace)
    by = res.by_uid()
    assert by[0].finish_reason == "eos" and by[0].n_tokens == 3
    assert by[2].slot == by[0].slot                 # recycled, not a new slot
    assert by[2].admitted_ns >= by[0].finish_ns
    assert by[2].first_token_ns < by[1].finish_ns   # before the batch drained
    assert by[1].finish_reason == "max_new" and by[1].n_tokens == 8
    assert res.admissions == 3 and len(res.requests) == 3


def test_scheduler_respects_max_new_budget():
    ex = ScriptedExecutor(n_slots=1)
    res = ContinuousBatchingScheduler(ex).run([_req(0, 0.0, max_new=5)])
    (rr,) = res.requests
    assert rr.n_tokens == 5 and rr.finish_reason == "max_new"
    # first token from prefill + 4 decode steps
    assert res.decode_steps == 4
    assert rr.finish_ns == pytest.approx(1000.0 + 4 * 500.0)


def test_scheduler_single_token_request_never_decodes():
    ex = ScriptedExecutor(n_slots=1)
    res = ContinuousBatchingScheduler(ex).run([_req(0, 0.0, max_new=1)])
    assert res.decode_steps == 0
    assert res.requests[0].n_tokens == 1
    assert ex.evictions == [(0, 0)]


def test_scheduler_queueing_delay_lands_in_ttft():
    """With one slot, the second request waits for the first to finish; its
    TTFT includes that queueing delay, its e2e starts at its arrival."""
    ex = ScriptedExecutor(n_slots=1)
    trace = [_req(0, 0.0, max_new=3), _req(1, 0.0, max_new=3)]
    res = ContinuousBatchingScheduler(ex).run(trace)
    by = res.by_uid()
    first_finish = 1000.0 + 2 * 500.0
    assert by[1].admitted_ns == pytest.approx(first_finish)
    m = request_metrics(by[1])
    assert m.queue_ns == pytest.approx(first_finish)
    assert m.ttft_ns == pytest.approx(first_finish + 1000.0)


def test_scheduler_idle_jumps_to_next_arrival():
    ex = ScriptedExecutor(n_slots=1)
    trace = [_req(0, 0.0, max_new=2), _req(1, 1e9, max_new=2)]
    res = ContinuousBatchingScheduler(ex).run(trace)
    by = res.by_uid()
    assert by[0].finish_ns < 1e9
    assert by[1].admitted_ns == pytest.approx(1e9)  # not before it arrived


def test_scheduler_deterministic_replay():
    ex1 = ScriptedExecutor(n_slots=2, eos_at={1: 3})
    ex2 = ScriptedExecutor(n_slots=2, eos_at={1: 3})
    trace = generate_trace(TraceConfig(n_requests=10, rate_rps=1e6, seed=2))
    eos = ScriptedExecutor.EOS
    r1 = ContinuousBatchingScheduler(ex1, eos_id=eos).run(trace)
    r2 = ContinuousBatchingScheduler(ex2, eos_id=eos).run(trace)
    assert [(r.request.uid, r.slot, r.first_token_ns, r.finish_ns)
            for r in r1.requests] == \
           [(r.request.uid, r.slot, r.first_token_ns, r.finish_ns)
            for r in r2.requests]


# ================================================================= simulator
class _FlatCosts:
    """PredictedCostModel stand-in: constant prefill/decode prices."""

    def __init__(self, n_slots=2, prefill=1000.0, decode=500.0):
        self.n_slots = n_slots
        self._p, self._d = prefill, decode

    def prefill_ns(self, plen):
        return self._p

    def decode_ns(self):
        return self._d


def test_simulate_runs_full_budget_and_replays():
    trace = generate_trace(TraceConfig(n_requests=8, rate_rps=50.0, seed=4))
    a = simulate(trace, _FlatCosts())
    b = simulate(trace, _FlatCosts())
    assert all(rr.finish_reason == "max_new" for rr in a.requests)
    assert [rr.n_tokens for rr in a.requests] == \
           [r.max_new for r in sorted(trace, key=lambda r: r.uid)]
    assert [rr.first_token_ns for rr in a.requests] == \
           [rr.first_token_ns for rr in b.requests]   # deterministic replay


# =================================================================== metrics
def test_request_metrics_definitions():
    rr_trace = [_req(0, 100.0, max_new=3)]
    res = ContinuousBatchingScheduler(ScriptedExecutor(n_slots=1)).run(rr_trace)
    m = request_metrics(res.requests[0])
    # idle pool: the clock jumps to the arrival, so TTFT is pure admit cost
    assert m.ttft_ns == pytest.approx(1000.0)
    assert m.queue_ns == pytest.approx(0.0)
    assert m.tpot_ns == pytest.approx(500.0)          # 2 decode steps / 2 gaps
    assert m.e2e_ns == pytest.approx(1000.0 + 2 * 500.0)
    assert m.n_tokens == 3


def test_request_metrics_single_token_tpot_is_nan():
    res = ContinuousBatchingScheduler(ScriptedExecutor(n_slots=1)).run(
        [_req(0, 0.0, max_new=1)])
    assert math.isnan(request_metrics(res.requests[0]).tpot_ns)


def test_summarize_percentiles_are_actual_samples():
    trace = [_req(i, 0.0, max_new=4) for i in range(7)]
    res = ContinuousBatchingScheduler(ScriptedExecutor(n_slots=2)).run(trace)
    s = summarize(res)
    ttfts = {request_metrics(rr).ttft_ns for rr in res.requests}
    assert set(s.ttft_ns.values()) <= ttfts      # exact-rank, no interpolation
    assert s.n_requests == 7 and s.n_tokens == 28
    assert s.goodput_tok_s == pytest.approx(
        28 / (res.makespan_ns * 1e-9))
    rec = s.as_record()
    assert rec["ttft_p99_ns"] == s.ttft_ns[99.0]


def test_summarize_rejects_empty():
    from repro.traffic.scheduler import ScheduleResult

    with pytest.raises(ValueError):
        summarize(ScheduleResult([], 1, 0.0, 0, 0))


def test_slo_table_renders_both_sides():
    trace = [_req(i, 0.0, max_new=4) for i in range(4)]
    res = ContinuousBatchingScheduler(ScriptedExecutor(n_slots=2)).run(trace)
    s = summarize(res)
    md = slo_table([{"rate_rps": 25.0, "predicted": s, "measured": s},
                    {"rate_rps": 50.0, "predicted": s, "measured": None}])
    lines = md.splitlines()
    assert lines[0].startswith("| rate (req/s) | side |")
    assert sum("predicted" in ln for ln in lines) == 2
    assert sum("measured" in ln for ln in lines) == 1


# ====================================================== slo points (records)
def test_slopoint_round_trip_through_record_notes():
    from repro.core.latency_db import LatencyRecord
    from repro.core.perfmodel import slopoint_from_record

    notes = ("rate=50 n=12 slots=4 seed=0 model=serving-tiny "
             "pred_ttft_p50_ns=100.0 pred_ttft_p99_ns=200.0 "
             "pred_tpot_p50_ns=50.0 pred_tpot_p99_ns=80.0 "
             "pred_e2e_p50_ns=400.0 pred_goodput_tok_s=1000.0 "
             "meas_ttft_p50_ns=1000.0 meas_ttft_p99_ns=2000.0 "
             "meas_tpot_p50_ns=60.0 meas_tpot_p99_ns=90.0 "
             "meas_e2e_p50_ns=4000.0 meas_goodput_tok_s=900.0 "
             "coverage=0.7100")
    rec = LatencyRecord(op="slo.r50", category="slo", dtype="float32",
                        opt_level="O3", latency_ns=1000.0, mad_ns=0.0,
                        cycles=0.0, guard=0, net_latency_ns=1000.0,
                        n_samples=12, measured_at="", notes=notes,
                        device_kind="cpu", backend="cpu", jax_version="0")
    pt = slopoint_from_record(rec)
    assert pt.rate_rps == 50.0 and pt.n_slots == 4 and pt.model == "serving-tiny"
    assert pt.measured["ttft_p50_ns"] == 1000.0
    assert pt.abs_log10_error("ttft_p50_ns") == pytest.approx(1.0)
    assert pt.abs_log10_error("tpot_p50_ns") == pytest.approx(
        abs(math.log10(50.0 / 60.0)))
    assert pt.abs_log10_error("missing_metric") == float("inf")


def test_check_slo_gate(tmp_path, capsys):
    from benchmarks import check_slo
    from repro.core.latency_db import LatencyDB, LatencyRecord

    def rec(rate, pred, meas, coverage=0.7):
        notes = (f"rate={rate} n=6 slots=4 seed=0 model=serving-tiny "
                 f"pred_ttft_p50_ns={pred} meas_ttft_p50_ns={meas} "
                 f"pred_tpot_p50_ns={pred} meas_tpot_p50_ns={meas} "
                 f"coverage={coverage}")
        return LatencyRecord(op=f"slo.r{rate:g}", category="slo",
                             dtype="float32", opt_level="O3", latency_ns=meas,
                             mad_ns=0.0, cycles=0.0, guard=0,
                             net_latency_ns=meas, n_samples=6,
                             measured_at="", notes=notes, device_kind="cpu",
                             backend="cpu", jax_version="0")

    db = LatencyDB(path=str(tmp_path / "db.json"))
    db.add(rec(20, 900.0, 1000.0))
    db.save()
    tol = tmp_path / "tol.json"
    tol.write_text(json.dumps({"max_abs_log10_ratio": 1.0,
                               "min_coverage": 0.5}))
    assert check_slo.main(["--db", db.path, "--tolerance", str(tol)]) == 0
    assert "within tolerance" in capsys.readouterr().out

    db.add(rec(50, 1.0, 1e4))            # 4 decades off -> violation
    db.save()
    assert check_slo.main(["--db", db.path, "--tolerance", str(tol)]) == 1
    assert "VIOLATION" in capsys.readouterr().err

    empty = LatencyDB(path=str(tmp_path / "empty.json"))
    empty.save()
    assert check_slo.main(["--db", empty.path,
                           "--tolerance", str(tol)]) == 2


# ========================================================== end-to-end (slow)
@pytest.mark.slow
def test_serve_slo_cli_end_to_end(tmp_path, capsys):
    """serve-slo sweep through the Session machinery: measured + predicted
    sides populated for every rate, cached on re-run, trace replay path."""
    from repro.api import cli
    from repro.core.latency_db import LatencyDB
    from repro.core.perfmodel import slopoint_from_record

    db = str(tmp_path / "db.json")
    args = ["serve-slo", "--rates", "30,60", "--n-requests", "4",
            "--slots", "2", "--db", db, "--reps", "1", "--warmup", "0"]
    assert cli.main(args) == 0
    out = capsys.readouterr().out
    assert "0 failed" in out and "| predicted |" in out and "| measured |" in out

    points = sorted((slopoint_from_record(r) for r in LatencyDB(db).records()
                     if r.op.startswith("slo.")), key=lambda p: p.rate_rps)
    assert [p.rate_rps for p in points] == [30.0, 60.0]
    for p in points:
        for metric in ("ttft_p50_ns", "ttft_p99_ns", "tpot_p50_ns"):
            assert p.predicted[metric] > 0 and p.measured[metric] > 0

    assert cli.main(args) == 0                     # all cache hits
    assert "cached" in capsys.readouterr().out
