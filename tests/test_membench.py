"""Memory-hierarchy probe: permutation properties + latency sanity."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import membench
from repro.core.timing import Timer


@given(st.integers(min_value=2, max_value=2048), st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_ring_is_single_cycle(n, seed):
    ring = membench._ring_permutation(n, seed)
    seen = set()
    p = 0
    for _ in range(n):
        assert p not in seen
        seen.add(p)
        p = int(ring[p])
    assert p == 0 and len(seen) == n   # one full cycle through every slot


def test_chase_latency_positive_and_grows():
    t = Timer(warmup=1, reps=6)
    small = membench.measure_latency(1 << 13, timer=t, steps=(512, 1536))
    big = membench.measure_latency(1 << 23, timer=t, steps=(512, 1536))
    assert small.latency_ns >= 0
    assert big.latency_ns >= 0
    # on a quiet machine the DRAM-resident chase is slower; on a noisy shared
    # host we only require it not be absurdly faster
    assert big.latency_ns >= 0.2 * small.latency_ns or big.latency_ns >= 1.0


def test_cold_pass_is_preceded_by_shape_only_warm_execution():
    """Regression: the timed cold pass must hit a warm jit cache. The old
    code warmed via ``fn.lower().compile()``, which does NOT populate the
    jit dispatch cache (tracing is cached, compilation is not), so every new
    working-set shape re-compiled *inside* the timed region and
    ``cold_latency_ns`` absorbed ~40x of compile time. The fix is a full
    warm *execution* on a zeroed same-shape ring — shape-only, so the real
    ring's memory stays untouched until the timed first-touch pass."""
    import jax
    import jax.numpy as jnp

    ring, _ = membench.build_ring(4096)
    start = jnp.asarray(0, jnp.int32)
    real = jax.jit(membench.chase_fn(32))
    calls = []

    def spy(r, s):
        calls.append(bool(np.asarray(r).any()))  # False only for the warm ring
        return real(r, s)

    cold = membench._cold_latency_ns(spy, ring, start, 32)
    assert calls == [False, True]  # zeroed warm pass first, then the timed ring
    assert cold >= 0.0
    # and the warm pass really does warm the cache the timed pass hits
    assert real._cache_size() == 1


def test_build_ring_single_cycle_over_live_slots():
    import numpy as np

    ring, start = membench.build_ring(2048, line_bytes=64)
    arr, pad = np.asarray(ring), 64 // 4
    n = 2048 // 64
    p, seen = int(start[0]), set()
    for _ in range(n):
        assert p % pad == 0 and p not in seen
        seen.add(p)
        p = int(arr[p])
    assert p == int(start[0]) and len(seen) == n


def test_detect_levels():
    pts = [membench.MemPoint(1 << (12 + i), lat, lat, 64)
           for i, lat in enumerate([1.0, 1.1, 1.0, 4.0, 4.2, 12.0])]
    levels = membench.detect_levels(pts)
    assert len(levels) == 3
    assert levels[0]["hit_latency_ns"] < levels[-1]["hit_latency_ns"]


def test_bandwidth_positive():
    bw = membench.bandwidth_probe(size_bytes=1 << 22,
                                  timer=Timer(warmup=1, reps=4))
    assert bw > 0.01   # GB/s
