"""Memory-hierarchy probe: permutation properties + latency sanity."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import membench
from repro.core.timing import Timer


@given(st.integers(min_value=2, max_value=2048), st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_ring_is_single_cycle(n, seed):
    ring = membench._ring_permutation(n, seed)
    seen = set()
    p = 0
    for _ in range(n):
        assert p not in seen
        seen.add(p)
        p = int(ring[p])
    assert p == 0 and len(seen) == n   # one full cycle through every slot


def test_chase_latency_positive_and_grows():
    t = Timer(warmup=1, reps=6)
    small = membench.measure_latency(1 << 13, timer=t, steps=(512, 1536))
    big = membench.measure_latency(1 << 23, timer=t, steps=(512, 1536))
    assert small.latency_ns >= 0
    assert big.latency_ns >= 0
    # on a quiet machine the DRAM-resident chase is slower; on a noisy shared
    # host we only require it not be absurdly faster
    assert big.latency_ns >= 0.2 * small.latency_ns or big.latency_ns >= 1.0


def test_detect_levels():
    pts = [membench.MemPoint(1 << (12 + i), lat, lat, 64)
           for i, lat in enumerate([1.0, 1.1, 1.0, 4.0, 4.2, 12.0])]
    levels = membench.detect_levels(pts)
    assert len(levels) == 3
    assert levels[0]["hit_latency_ns"] < levels[-1]["hit_latency_ns"]


def test_bandwidth_positive():
    bw = membench.bandwidth_probe(size_bytes=1 << 22,
                                  timer=Timer(warmup=1, reps=4))
    assert bw > 0.01   # GB/s
