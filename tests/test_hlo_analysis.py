"""Static HLO analyzer: trip-count rollup, collectives, byte conventions."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.core import hlo_analysis


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    txt = jax.jit(lambda x, y: x @ y).lower(a, b).compile().as_text()
    st = hlo_analysis.static_cost(txt)
    assert st.flops == 2 * 128 * 64 * 32


def test_bf16_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    txt = jax.jit(lambda x, y: x @ y).lower(a, a).compile().as_text()
    assert hlo_analysis.static_cost(txt).flops == 2 * 64 ** 3


@pytest.mark.parametrize("length", [4, 32])
def test_scan_trip_count_multiplier(length):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=length)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    st = hlo_analysis.static_cost(compiled.as_text())
    expect = length * 2 * 32 * 64 * 64
    assert expect <= st.flops <= expect * 1.2
    # XLA's own count misses the trip multiplier — that is why we parse.
    from repro.utils import compiled_cost
    assert compiled_cost(compiled).get("flops", 0) < expect or length == 1


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            return lax.scan(inner, c, None, length=3)[0], None
        return lax.scan(outer, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    st = hlo_analysis.static_cost(jax.jit(f).lower(x, w).compile().as_text())
    expect = 15 * 2 * 16 * 32 * 32
    assert expect <= st.flops <= expect * 1.3


def test_ring_factors():
    assert hlo_analysis._ring_factor("all-reduce", 4) == pytest.approx(1.5)
    assert hlo_analysis._ring_factor("all-gather", 8) == pytest.approx(7 / 8)
    assert hlo_analysis._ring_factor("reduce-scatter", 8) == 7.0
    assert hlo_analysis._ring_factor("collective-permute", 2) == 1.0
    assert hlo_analysis._ring_factor("all-reduce", 1) == 0.0


def test_shape_bytes_tuple_with_comments():
    elems, bts = hlo_analysis._shape_info(
        "(s32[], bf16[32,1,4096]{2,1,0}, /*index=5*/f32[48,1024]{1,0})")
    assert elems == 1 + 32 * 4096 + 48 * 1024
    assert bts == 4 + 2 * 32 * 4096 + 4 * 48 * 1024


def test_collective_parse_crafted():
    txt = """HloModule m, num_partitions=8

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
}
"""
    colls = hlo_analysis.parse_collectives(txt)
    assert len(colls) == 1
    c = colls[0]
    assert c.kind == "all-reduce" and c.group_size == 4
    assert c.wire_bytes == pytest.approx(2 * 3 / 4 * 64 * 64 * 4)


def test_op_histogram_nonempty():
    txt = jax.jit(lambda x: jnp.tanh(x) + 1).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    hist = hlo_analysis.op_histogram(txt)
    assert sum(hist.values()) >= 1


def test_dynamic_histogram_scan_multiplier():
    """An op inside a scanned body counts trip_count times dynamically while
    the flat histogram still counts its one op line."""
    def f(x):
        def body(c, _):
            return jnp.tanh(c), None
        return lax.scan(body, x, None, length=6)[0]

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    flat = sum(c for (op, _), c in hlo_analysis.op_histogram(txt).items()
               if op == "tanh")
    dyn = sum(c for (op, _), c in
              hlo_analysis.dynamic_op_histogram(txt).items() if op == "tanh")
    assert flat == 1
    assert dyn == 6.0


def test_dynamic_flops_matches_total():
    """Σ dynamic_flops by opcode == the rolled-up module total."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=4)[0]

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    mc = hlo_analysis.ModuleCost(
        jax.jit(f).lower(x, w).compile().as_text())
    dyn = mc.dynamic_flops()
    assert dyn.get("dot", 0) == pytest.approx(4 * 2 * 16 * 32 * 32)
    assert sum(dyn.values()) == pytest.approx(mc.total().flops)


def test_structural_ops_subset_sanity():
    # structural set must never swallow priceable arithmetic opcodes
    priceable = set(hlo_analysis.HLO_TO_TABLE) | {"dot", "convolution",
                                                  "reduce"}
    assert not (hlo_analysis.STRUCTURAL_OPS & priceable)
