"""Minimal stand-in for ``hypothesis`` when the real package is unavailable.

The test suite's property tests only use a small surface — ``@given`` over a
handful of strategies, plus ``@settings(max_examples=..., deadline=None)``.
This container image does not ship ``hypothesis`` and nothing may be
installed, so ``conftest.py`` registers this module under the ``hypothesis``
name when the real one cannot be imported. When hypothesis *is* installed it
wins and this file is inert.

The stub is deliberately dumb: deterministic seeded-random example generation,
no shrinking, no database. That is enough to exercise the properties.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: random.Random) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict for stub strategy")
        return SearchStrategy(draw)


def integers(min_value: int | None = None, max_value: int | None = None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 - 1 if max_value is None else int(max_value)

    def draw(rng: random.Random) -> int:
        r = rng.random()
        if r < 0.1:
            return lo
        if r < 0.2:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw)


def floats(min_value: float | None = None, max_value: float | None = None,
           allow_nan: bool = True, allow_infinity: bool | None = None,
           width: int = 64) -> SearchStrategy:
    lo = -1e300 if min_value is None else float(min_value)
    hi = 1e300 if max_value is None else float(max_value)

    def draw(rng: random.Random) -> float:
        r = rng.random()
        if r < 0.1:
            return lo
        if r < 0.2:
            return hi
        if r < 0.3 and lo <= 0.0 <= hi:
            return 0.0
        return rng.uniform(lo, hi)

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def none() -> SearchStrategy:
    return just(None)


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)

    def draw(rng: random.Random) -> Any:
        return elements[rng.randrange(len(elements))]

    return SearchStrategy(draw)


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int | None = None, unique: bool = False) -> SearchStrategy:
    cap = min_size + 10 if max_size is None else max_size

    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, cap)
        out: list = []
        tries = 0
        while len(out) < n and tries < 100 * (n + 1):
            v = elements.example(rng)
            tries += 1
            if unique and v in out:
                continue
            out.append(v)
        return out

    return SearchStrategy(draw)


def text(alphabet: str = "abcdefghijklmnopqrstuvwxyz", min_size: int = 0,
         max_size: int | None = None) -> SearchStrategy:
    chars = sampled_from(list(alphabet) or ["a"])
    return lists(chars, min_size=min_size, max_size=10 if max_size is None else max_size
                 ).map("".join)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def builds(target: Callable[..., Any], *args: SearchStrategy,
           **kwargs: SearchStrategy) -> SearchStrategy:
    def draw(rng: random.Random) -> Any:
        return target(*(s.example(rng) for s in args),
                      **{k: s.example(rng) for k, s in kwargs.items()})

    return SearchStrategy(draw)


class settings:
    """Decorator collecting the (few) settings the stub honours."""

    def __init__(self, max_examples: int = 100, deadline: Any = None, **_ignored: Any):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, fn: Callable) -> Callable:
        fn._stub_settings = self  # read at call time by the @given wrapper
        return fn


class _Assumption(Exception):
    pass


def assume(condition: Any) -> bool:
    if not condition:
        raise _Assumption()
    return True


def given(*strategies: SearchStrategy, **kw_strategies: SearchStrategy) -> Callable:
    """Run the test over deterministically-seeded random examples.

    Like hypothesis, positional strategies bind to the *rightmost* parameters
    of the test function; any leading parameters are pytest fixtures, and the
    wrapper's signature is trimmed so pytest only supplies those.
    """

    def decorate(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        params = list(sig.parameters)
        drawn = dict(kw_strategies)
        positional = params[len(params) - len(strategies):] if strategies else []
        drawn.update(zip(positional, strategies))
        fixture_names = [p for p in params if p not in drawn]

        @functools.wraps(fn)
        def wrapper(**fixture_kwargs: Any) -> None:
            cfg = getattr(wrapper, "_stub_settings", None) or settings(max_examples=25)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(max(cfg.max_examples, 1)):
                example = {name: strat.example(rng) for name, strat in drawn.items()}
                try:
                    fn(**fixture_kwargs, **example)
                except _Assumption:
                    continue

        wrapper.__signature__ = inspect.Signature(  # type: ignore[attr-defined]
            [sig.parameters[name] for name in fixture_names])
        return wrapper

    return decorate


class HealthCheck:
    all = staticmethod(lambda: [])
    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"


def install() -> None:
    """Register this stub as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    st = types.ModuleType("hypothesis.strategies")
    for name in ("SearchStrategy", "integers", "floats", "booleans", "just",
                 "none", "sampled_from", "lists", "text", "tuples", "builds"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.__version__ = "0.0.0+repro-stub"
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
