"""Tests for repro.audit: chain integrity, transform classification, lints,
verdict persistence, and the CLI entry point."""
import dataclasses

import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api.cli import main as cli_main
from repro.audit import (ChainVerdict, audit_db, audit_spec, audit_target,
                         classify, path_counts, run_lints)
from repro.audit.chain_check import (GUARDS, _verdict_from_note, base_name,
                                     chain_hlo_text, root_is_constant)
from repro.audit.lint import (lint_guard_identity, lint_table_mapping,
                              lint_zoo)
from repro.core.chains import OpSpec, default_registry
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.utils import parse_kv_notes

REGS = {s.name: s for s in default_registry()}
SHORT = (2, 6)  # keep test compiles cheap; per-step deltas are len-invariant


def _record(op="add", opt_level="O3", notes="", **over):
    base = dict(op=op, category="int_arith", dtype="int32",
                opt_level=opt_level, latency_ns=10.0, mad_ns=0.1, cycles=30.0,
                guard=1, net_latency_ns=5.0, device_kind="TestDev",
                backend="cpu", jax_version="0.0.test", n_samples=3,
                measured_at="2026-08-09T00:00:00", notes=notes)
    base.update(over)
    return LatencyRecord(**base)


# ------------------------------------------------------------ chain checks
def test_exact_count_pass():
    """The canonical pass: add's (add ^ xor) chain audits ok at O3."""
    v = audit_spec(REGS["add"], "O3", lens=SHORT)
    assert v.ok and v.status == "ok", v
    assert v.note() == "audit=ok"


def test_exact_count_pass_guarded_transcendental():
    v = audit_spec(REGS["rsqrt"], "O3", lens=SHORT)
    assert v.ok, v


def test_expected_transform_annotated():
    """div by pow-2 strength-reduces and the audit names the cause."""
    v = audit_spec(REGS["div.s.regular"], "O3", lens=SHORT)
    assert v.ok, v
    assert v.cause == "strength-reduction"
    assert v.note() == "audit=ok audit_transform=strength-reduction"


def test_folded_chain_caught():
    """A chain XLA folds to a literal is flagged with the right cause."""
    # int algebra (float x*0 is NaN-unsafe to fold; int x*0 is not)
    folded = OpSpec(name="add", category="int_arith", dtype="int32",
                    step=lambda x: x * 0 + 1, init=1)
    v = audit_spec(folded, "O3", lens=SHORT)
    assert v.failed, v
    assert v.cause == "folded-to-constant"
    assert v.note() == "audit=transformed:folded-to-constant"


def test_guard_mismatch_caught():
    """Declared guard count inconsistent with the declared guard opcodes."""
    wrong = dataclasses.replace(REGS["add"], guard=3)
    v = audit_spec(wrong, "O3", lens=SHORT)
    assert v.failed and v.cause == "guard-mismatch", v


def test_o0_jaxpr_audit():
    v = audit_spec(REGS["mad"], "O0")
    assert v.ok, v


def test_audit_target_dispatch():
    assert audit_target("clock_overhead", "O0").ok
    v = audit_target("serving.prefill.b2p16", "O3")
    assert v.status == "unaudited" and v.cause == "consumer-row"
    v = audit_target("inkernel.add", "O3")
    assert v.status == "audited", v
    assert audit_target("no.such.op", "O3").cause == "unknown-family"


def test_path_counts_on_real_chain():
    """Every expected op of a compiled chain sits on the carry->root path."""
    spec = REGS["add"]
    n = 6
    text = chain_hlo_text(spec, n, "O3")
    pc = path_counts(text)
    assert pc.get("add") == n and pc.get("xor") == n, pc
    assert not root_is_constant(text)


def test_classify_taxonomy():
    from collections import Counter

    exp = Counter({"divide": 4})
    assert classify(exp, Counter()) == "folded-to-constant"
    assert classify(exp, Counter({"shift-right-logical": 4})) == \
        "strength-reduction"
    assert classify(Counter({"add": 4, "abs": 4}), Counter({"add": 4})) == \
        "algebraic-simplification"
    assert classify(Counter({"add": 4}), Counter({"add": 8})) == \
        "rematerialized"


def test_base_name():
    assert base_name("div.regular.float32") == "div.regular"
    assert base_name("add.bfloat16") == "add"
    assert base_name("add.cc") == "add.cc"
    assert base_name("mul64hi") == "mul64hi"


# ------------------------------------------------------------------- lints
def test_lints_clean_on_repo():
    assert run_lints() == []


def test_lint_catches_unmapped_table_value(monkeypatch):
    from repro.core import hlo_analysis

    monkeypatch.setitem(hlo_analysis.HLO_TO_TABLE, "bogus-op", "no.such.spec")
    findings = lint_table_mapping()
    assert any(f.subject == "bogus-op" and "no.such.spec" in f.message
               for f in findings)


def test_lint_catches_guard_mismatch(monkeypatch):
    monkeypatch.setitem(GUARDS, "popc", ("xor", "xor"))
    findings = lint_guard_identity()
    assert any(f.subject == "popc" for f in findings)


def test_lint_zoo_catches_unmapped_opcode(monkeypatch):
    """An opcode that is neither priced, structural, nor allowlisted fires."""
    from repro.audit import lint as lint_mod

    monkeypatch.setattr(
        lint_mod, "_zoo_hlo",
        lambda arch: ("HloModule m\n\nENTRY %main (p0: f32[4]) -> f32[4] {\n"
                      "  %p0 = f32[4]{0} parameter(0)\n"
                      "  ROOT %r = f32[4]{0} frobnicate(%p0)\n}\n"))
    findings = lint_zoo(archs=["fake-arch"])
    assert any("frobnicate" in f.message for f in findings)


# ------------------------------------------- verdict notes + DB round-trip
def test_verdict_note_roundtrip_through_db():
    db = LatencyDB()
    rec = _record(notes="reps_eff=7")
    db.add(rec)
    v = ChainVerdict("add", "O3", "transformed", cause="folded-to-constant")
    db.annotate(rec.key(), audit=f"{v.status}:{v.cause}")
    back = db.get(rec.key())
    kv = parse_kv_notes(back.notes)
    assert kv["reps_eff"] == "7"  # pre-existing tokens survive
    assert kv["audit"] == "transformed:folded-to-constant"
    parsed = _verdict_from_note(back.op, back.opt_level, back.notes)
    assert parsed.status == v.status and parsed.cause == v.cause
    # re-annotating replaces rather than duplicates
    db.annotate(rec.key(), audit="ok", audit_transform=None)
    assert parse_kv_notes(db.get(rec.key()).notes)["audit"] == "ok"
    assert db.get(rec.key()).notes.count("audit=") == 1


def test_annotate_missing_key_is_noop():
    db = LatencyDB()
    assert db.annotate(("a", "b", "c", "d", "e", "f"), audit="ok") is None


def test_audit_db_skips_foreign_env_and_keeps_existing():
    db = LatencyDB()
    # foreign-env record with a verdict from its measuring environment
    db.add(_record(op="mul", notes="audit=ok"))
    # foreign-env record never audited: reported unaudited, not annotated
    db.add(_record(op="popc"))
    env = {"device_kind": "Other", "backend": "cpu", "jax_version": "9.9"}
    verdicts = audit_db(db, env=env)
    by_op = {v.op: v for v in verdicts}
    assert by_op["mul"].status == "ok"
    assert by_op["popc"].status == "unaudited"
    assert by_op["popc"].cause == "environment-mismatch"
    assert "audit=" not in db.get(_record(op="popc").key()).notes


def test_audit_status_groups_and_markdown():
    db = LatencyDB()
    db.add(_record(op="add", notes="audit=ok"))
    db.add(_record(op="mul", notes="audit=transformed:hoisted"))
    db.add(_record(op="popc"))
    groups = db.audit_status()
    assert {r.op for r in groups["ok"]} == {"add"}
    assert {r.op for r in groups["transformed"]} == {"mul"}
    assert {r.op for r in groups["unaudited"]} == {"popc"}
    md = db.audit_markdown()
    assert "hoisted" in md and "unaudited" in md
    # failed rows surface before ok rows
    assert md.index("transformed") < md.index(" ok ")


# --------------------------------------------------------------------- CLI
def test_cli_strict_exit_code(tmp_path):
    db_path = str(tmp_path / "db.json")
    db = LatencyDB(path=db_path)
    db.add(_record(op="add", notes="audit=transformed:folded-to-constant"))
    db.save()
    # existing verdicts are honoured without re-deriving (foreign env here),
    # so the failed verdict drives the exit code
    assert cli_main(["audit", "--db", db_path, "--strict"]) == 1
    assert cli_main(["audit", "--db", db_path]) == 0


def test_cli_missing_db_is_usage_error(tmp_path):
    assert cli_main(["audit", "--db", str(tmp_path / "nope.json")]) == 2


def test_cli_lint_only_without_db(tmp_path):
    assert cli_main(["audit", "--db", str(tmp_path / "nope.json"),
                     "--lint"]) == 0


def test_cli_attribution_writes_table(tmp_path):
    out = str(tmp_path / "attr.md")
    rc = cli_main(["audit", "--db", str(tmp_path / "nope.json"), "--lint",
                   "--attribution", out, "--attribution-ops", "add,popc"])
    assert rc == 0
    text = open(out).read()
    assert "| `add` |" in text and "| `popc` |" in text
    assert "O0 -> O1 -> O3" in text


def test_session_audit_flag_attaches_notes():
    from repro.api.plan import Plan
    from repro.api.probes import InstructionProbe
    from repro.api.session import Session

    plan = Plan(name="t", probes=(
        InstructionProbe(REGS["add"], "O3"),
        InstructionProbe(REGS["div.s.regular"], "O3")))
    sess = Session(timer=_fast_timer(), audit=True)
    result = sess.run(plan)
    assert not result.failed
    notes = {r.record.op: parse_kv_notes(r.record.notes)
             for r in result.results}
    assert notes["add"]["audit"] == "ok"
    assert notes["div.s.regular"]["audit"] == "ok"
    assert notes["div.s.regular"]["audit_transform"] == "strength-reduction"


def _fast_timer():
    from repro.core.timing import Timer

    return Timer(warmup=0, reps=1)


# ---------------------------------------------- hlo_analysis property tests
_NAME = st.text(alphabet="abcdefgh", min_size=1, max_size=6)
_OPCODE = st.sampled_from(["add", "multiply", "subtract", "xor", "divide",
                           "rsqrt", "shift-left", "popcnt"])
_DTYPE = st.sampled_from(["f32", "s32", "u32", "bf16", "pred"])
_DIMS = st.lists(st.integers(min_value=1, max_value=8), min_size=0,
                 max_size=3)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(_NAME, _OPCODE, _DTYPE, _DIMS), min_size=1,
                max_size=8))
def test_parse_module_roundtrips_fuzzed_op_lines(lines):
    """Synthesized op lines parse back with the same opcodes, names survive
    '%' stripping, and exactly the last op carries the ROOT flag."""
    from repro.core.hlo_analysis import op_histogram, parse_module

    names, body = [], []
    for i, (name, opcode, dtype, dims) in enumerate(lines):
        uname = f"{name}.{i}"  # uniquify: HLO names are unique per comp
        names.append(uname)
        shape = f"{dtype}[{','.join(map(str, dims))}]" + (
            "{0}" if len(dims) == 1 else "")
        operand = f"%{names[i - 1]}" if i else "%p0"
        prefix = "ROOT " if i == len(lines) - 1 else ""
        body.append(f"  {prefix}%{uname} = {shape} {opcode}({operand})")
    text = ("HloModule fuzz\n\n"
            "ENTRY %main (p0: f32[4]) -> f32[4] {\n"
            "  %p0 = f32[4]{0} parameter(0)\n"
            + "\n".join(body) + "\n}\n")
    comps = parse_module(text)
    entry = comps["__entry__"]
    parsed = [op for op in entry.ops if op.opcode != "parameter"]
    assert [op.name for op in parsed] == names
    assert [op.opcode for op in parsed] == [l[1] for l in lines]
    roots = [op for op in entry.ops if op.is_root]
    assert len(roots) == 1 and roots[0].name == names[-1]
    hist = op_histogram(text)
    from collections import Counter

    want = Counter(l[1] for l in lines)
    got = Counter()
    for (opcode, _e), c in hist.items():
        got[opcode] += c
    for opcode, c in want.items():
        assert got[opcode] == c, (opcode, got)


def test_dynamic_histogram_consistent_with_flat_times_trips():
    """dynamic_op_histogram == flat body counts x known_trip_count for a
    compiled fori_loop (the regression the memory-chase audit relies on)."""
    import jax

    from repro.core.hlo_analysis import (_TRIP_RE, dynamic_op_histogram,
                                         op_histogram, parse_module)
    from repro.core.membench import build_ring, chase_fn

    steps = 7
    ring, _ = build_ring(4096)
    start = jnp.asarray(0, jnp.int32)
    text = jax.jit(chase_fn(steps)).lower(ring, start).compile().as_text()
    trips = [int(m) for m in _TRIP_RE.findall(text)]
    if not trips:
        pytest.skip("XLA fully unrolled the loop; nothing to weight")
    assert trips[0] == steps
    dyn = dynamic_op_histogram(text)
    flat = op_histogram(text)
    # the dependent load lives only in the while body: its dynamic count is
    # exactly its flat count x trip count
    for opcode in ("dynamic-slice", "gather"):
        flat_n = sum(c for (o, _e), c in flat.items() if o == opcode)
        dyn_n = sum(c for (o, _e), c in dyn.items() if o == opcode)
        if flat_n:
            assert dyn_n == pytest.approx(flat_n * steps), opcode
            break
    else:
        pytest.fail("no dependent-load opcode found in the chase body")
