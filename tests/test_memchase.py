"""In-kernel memory rows: chase residency policy, probe, plan, session, CLI.

The load-bearing regression here is the residency contract: an over-VMEM ring
must be handed to the kernel with ``memory_space=ANY`` (streaming from HBM),
never BlockSpec-pinned into VMEM — the original ``kernels/chase.py`` pinned
unconditionally, so the Fig. 6 analog silently measured VMEM.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro import inkernel
from repro.api import MemoryChaseProbe, MemoryProbe, Plan, Session, cli, named_plan
from repro.core import membench
from repro.core.latency_db import LatencyDB
from repro.core.timing import Timer
from repro.kernels import chase as chase_mod
from repro.kernels.chase import chase, chase_in_specs, select_memory_space


# ------------------------------------------------------ residency regression
def test_select_memory_space_by_footprint():
    budget = chase_mod.VMEM_BUDGET_BYTES
    assert select_memory_space(budget) == "vmem"
    assert select_memory_space(budget + 1) == "any"
    assert select_memory_space(64) == "vmem"
    # explicit budget override (tests + small-core targets)
    assert select_memory_space(8192, vmem_budget=4096) == "any"
    assert select_memory_space(4096, vmem_budget=4096) == "vmem"


def test_over_vmem_specs_are_not_blockspec_pinned():
    """The bug fix: the 'any' ring spec must carry the ANY memory space and
    no block shape — a shaped BlockSpec is exactly what DMA-pins the ring
    into VMEM and turns the HBM probe into a VMEM one."""
    any_spec = chase_in_specs(512, "any")[0]
    assert any_spec.memory_space == pl.ANY
    assert any_spec.block_shape is None

    vmem_spec = chase_in_specs(512, "vmem")[0]
    assert tuple(vmem_spec.block_shape) == (512,)

    with pytest.raises(ValueError, match="memory_space"):
        chase_in_specs(512, "hbm2")


# ------------------------------------------------------ interpret-mode oracle
def _single_cycle_ring(n, seed=3):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    ring = np.empty(n, np.int32)
    ring[idx[:-1]] = idx[1:]
    ring[idx[-1]] = idx[0]
    return ring, int(idx[0])


@pytest.mark.parametrize("memory_space", ["vmem", "any"])
def test_chase_visits_every_ring_slot(memory_space):
    """Both residencies walk the identical single cycle: after n steps the
    chase is back at the start, and never earlier (so all n slots are hit)."""
    n = 16
    ring, start = _single_cycle_ring(n)
    r, s = jnp.asarray(ring), jnp.asarray([start])
    seen = set()
    for k in range(1, n + 1):
        p = int(chase(r, s, steps=k, interpret=True,
                      memory_space=memory_space)[0])
        assert (p == start) == (k == n)
        seen.add(p)
    assert seen == set(range(n))


@pytest.mark.parametrize("memory_space", ["vmem", "any"])
def test_chase_matches_host_oracle_on_padded_ring(memory_space):
    """The line-padded build_ring drives the kernel exactly like the host
    chase: positions are absolute indices into the padded array."""
    from repro.kernels.ref import ref_chase

    ring, start = membench.build_ring(1024, line_bytes=64)
    for steps in (1, 7, 16):
        out = chase(ring, start, steps=steps, interpret=True,
                    memory_space=memory_space)
        assert int(out[0]) == ref_chase(np.asarray(ring), 0, steps)


def test_build_ring_line_padding():
    ring, start = membench.build_ring(4096, line_bytes=64)
    pad = 64 // 4
    arr = np.asarray(ring)
    assert arr.size == 4096 // 4 and int(start[0]) == 0
    live = arr[::pad]
    assert np.count_nonzero(arr) == np.count_nonzero(live)  # slots only
    assert (live % pad == 0).all()  # values are padded absolute positions


# ------------------------------------------------- slope + probe measurement
def test_measure_chase_full_slope_exact_on_virtual_clock(monkeypatch):
    """fn_by_len(steps) costing intercept + slope*steps must yield exactly
    the per-load slope, with the residency actually used reported back."""
    import repro.core.timing as timing

    now = [0]
    monkeypatch.setattr(timing.time, "perf_counter_ns", lambda: now[0])
    SLOPE, INTERCEPT = 900, 70_000

    def fake_chase(ring, start, *, steps, interpret=None, memory_space=None):
        now[0] += INTERCEPT + SLOPE * steps
        return start

    monkeypatch.setattr(chase_mod, "chase", fake_chase)
    m, space = inkernel.measure_chase_full(
        8192, lens=(16, 48), timer=Timer(warmup=1, reps=3))
    assert m.median_ns == pytest.approx(SLOPE)
    assert m.mad_ns == 0.0
    assert space == "vmem"
    _, forced = inkernel.measure_chase_full(
        8192, lens=(16, 48), timer=Timer(warmup=1, reps=3),
        memory_space="any")
    assert forced == "any"


def test_probe_identity_and_fidelity_suffixes():
    std = MemoryChaseProbe(65536)
    assert std.op == "inkernel.mem.65536"
    assert std.opt_level == "O3" and std.dtype == "int32"
    assert std.category == "memory"
    assert std.lens == tuple(inkernel.CHASE_LENS)
    # non-default steps / line padding / a forced residency are different
    # experiments: each must split the cache identity, never collide with
    # the default-fidelity row
    assert MemoryChaseProbe(65536, lens=(8, 24)).op == "inkernel.mem.65536.l8-24"
    assert MemoryChaseProbe(65536, memory_space="any").op == "inkernel.mem.65536.any"
    assert MemoryChaseProbe(65536, line_bytes=128).op == "inkernel.mem.65536.line128"
    assert MemoryProbe(65536, line_bytes=128).op == "mem.chase.ws65536.line128"
    assert (MemoryChaseProbe(65536, lens=(8, 24)).logical_key()
            != std.logical_key())
    assert (MemoryChaseProbe(65536, line_bytes=128).logical_key()
            != std.logical_key())


def test_match_names_mem_base_row():
    ik = MemoryChaseProbe(8192)
    assert ik.match_names() >= {"inkernel.mem.8192", "mem.chase.ws8192", "mem"}
    host = MemoryProbe(8192)
    assert host.match_names() >= {"mem.chase.ws8192", "mem"}
    # exact-by-construction: neither answers to another working set
    assert "mem.chase.ws4096" not in ik.match_names()


def test_probe_record_persists_working_set_metadata(monkeypatch, tmp_path):
    """Auto-selection above the budget runs the streaming path, and the
    record round-trips per-load latency + working-set metadata."""
    monkeypatch.setattr(chase_mod, "VMEM_BUDGET_BYTES", 4096)
    probe = MemoryChaseProbe(16384, lens=(8, 24), reps=2)
    result = Session(db=str(tmp_path / "db.json"),
                     timer=Timer(warmup=0, reps=2)).run(Plan((probe,)))
    rec = result.measured[0].record
    assert rec.op == "inkernel.mem.16384.l8-24"
    assert "space=any" in rec.notes  # over-budget ring streamed, not pinned
    pt = membench.chasepoint_from_record(rec)
    assert pt.working_set_bytes == 16384
    assert pt.memory_space == "any"
    assert pt.line_bytes == 64
    assert pt.latency_ns == rec.latency_ns


# --------------------------------------------------------------------- plan
def test_plan_memory_inkernel_spans_vmem_boundary():
    plan = Plan.memory_inkernel()
    sizes = sorted(p.working_set_bytes for p in plan
                   if isinstance(p, MemoryChaseProbe))
    spaces = {select_memory_space(ws) for ws in sizes}
    assert spaces == {"vmem", "any"}  # rungs on both sides of the boundary
    # host pairing fills both sides of the comparison table
    host_ws = sorted(p.working_set_bytes for p in plan
                     if isinstance(p, MemoryProbe))
    assert host_ws == sizes
    solo = Plan.memory_inkernel(working_sets=(4096,), host_pair=False)
    assert [p.op for p in solo] == ["inkernel.mem.4096"]


def test_named_plan_memory_inkernel_and_full():
    plan = named_plan("memory-inkernel")
    assert plan.name == "memory-inkernel"
    ops = {p.op for p in plan}
    assert "inkernel.mem.65536" in ops and "mem.chase.ws65536" in ops
    full_ops = {p.op for p in named_plan("full")}
    assert "inkernel.mem.65536" in full_ops  # folded into full
    keys = [p.logical_key() for p in named_plan("full")]
    assert len(keys) == len(set(keys))  # dedupe holds across + composition


def test_plan_filter_mem_base_row_keeps_memory_family():
    plan = named_plan("full").filter(ops=["mem"])
    assert len(plan) > 0
    assert all(p.category == "memory" for p in plan)
    kinds = {type(p) for p in plan}
    assert {MemoryChaseProbe, MemoryProbe} <= kinds
    # the host twin name keeps both sides of one rung, nothing else
    rung = named_plan("memory-inkernel").filter(ops=["mem.chase.ws65536"])
    assert {p.op for p in rung} == {"inkernel.mem.65536", "mem.chase.ws65536"}


# ------------------------------------------------- session cache/resume + DB
def _tiny_plan():
    return Plan((MemoryChaseProbe(4096, lens=(8, 24), reps=2),
                 MemoryChaseProbe(16384, lens=(8, 24), reps=2)))


def test_session_cache_resume_roundtrip(tmp_path):
    db = tmp_path / "db.json"
    first = Session(db=str(db), timer=Timer(warmup=0, reps=2)).run(_tiny_plan())
    assert first.summary().startswith("2 measured")
    assert all("ws=" in r.record.notes for r in first.measured)
    second = Session(db=str(db), timer=Timer(warmup=0, reps=2)).run(_tiny_plan())
    assert second.summary().startswith("0 measured, 2 cached")
    # cached records identical to what was measured (full round-trip)
    assert ([r.record for r in second.cached]
            == [r.record for r in first.measured])


def test_latency_db_merge_over_inkernel_mem_records(tmp_path):
    import dataclasses

    db = tmp_path / "db.json"
    res = Session(db=str(db), timer=Timer(warmup=0, reps=2)).run(_tiny_plan())
    rec = res.measured[0].record
    newer = dataclasses.replace(rec, latency_ns=123.0,
                                measured_at="9999-01-01T00:00:00")
    other = LatencyDB()
    other.add(newer)
    merged = LatencyDB(str(db)).merge(other)
    assert merged.get(rec.key()).latency_ns == 123.0  # newest wins
    older = dataclasses.replace(rec, latency_ns=7.0, measured_at="1970-01-01")
    loser = LatencyDB()
    loser.add(older)
    assert merged.merge(loser).get(rec.key()).latency_ns == 123.0


def test_fan_out_shard_smoke_includes_memory_probes(tmp_path):
    plan = _tiny_plan() + Plan((MemoryProbe(4096, steps=(64, 192)),))
    session = Session(db=str(tmp_path / "db.json"),
                      timer=Timer(warmup=0, reps=2))
    result = session.fan_out(plan, devices=[None, None])
    assert len(result.results) == 3 and not result.failed
    assert {r.record.op for r in result.measured} == {
        "inkernel.mem.4096.l8-24", "inkernel.mem.16384.l8-24",
        "mem.chase.ws4096.s64-192"}
    again = session.fan_out(plan, devices=[None, None])
    assert len(again.cached) == 3  # merged shard DBs resume as cache hits


# ------------------------------------------------------------ compare table
def test_compare_markdown_pairs_host_and_inkernel_rows(tmp_path):
    plan = Plan((MemoryChaseProbe(4096, reps=2),
                 MemoryChaseProbe(4096, lens=(8, 24), reps=2),
                 MemoryProbe(4096)))
    session = Session(db=str(tmp_path / "db.json"),
                      timer=Timer(warmup=0, reps=2))
    session.run(plan)
    md = session.db.compare_markdown()
    row = next((l for l in md.splitlines() if "mem.chase.ws4096" in l), None)
    assert row is not None, md
    assert "memory" in row
    # fidelity-suffixed variants are a different experiment: never paired
    assert "l8-24" not in md


def test_compare_markdown_orders_ladder_numerically(tmp_path):
    import dataclasses

    session = Session(db=LatencyDB(), timer=Timer(warmup=0, reps=2))
    res = session.run(Plan((MemoryChaseProbe(4096, reps=2, lens=(8, 24)),)))
    base = res.measured[0].record
    db = LatencyDB()
    for ws in (65536, 4096, 1048576):
        db.add(dataclasses.replace(base, op=f"inkernel.mem.{ws}"))
        db.add(dataclasses.replace(base, op=f"mem.chase.ws{ws}"))
    md = db.compare_markdown()
    order = [int(l.split("ws")[1].split(" ")[0]) for l in md.splitlines()
             if "mem.chase.ws" in l]
    assert order == [4096, 65536, 1048576]


# ---------------------------------------------------------------------- CLI
CLI_OPS = "inkernel.mem.65536,mem.chase.ws65536,inkernel.mem.262144"


def test_cli_memory_inkernel_plan_and_table(tmp_path, capsys):
    db = tmp_path / "db.json"
    args = ["characterize", "--plan", "memory-inkernel", "--ops", CLI_OPS,
            "--reps", "2", "--warmup", "0", "--db", str(db)]
    rc = cli.main(args + ["--table"])
    out = capsys.readouterr().out
    assert rc == 0
    # 2 in-kernel rungs + the ws65536 host twin (kept via the twin name;
    # filtering by a derived inkernel.* name keeps only that side, like the
    # op-chain rows)
    assert "3 measured, 0 cached, 0 failed" in out
    assert "inkernel.mem.65536" in out
    assert "in-kernel/dispatch" in out  # pairing table rendered

    blob = json.loads(db.read_text())
    ops = {r["op"] for r in blob["records"]}
    assert {"inkernel.mem.65536", "inkernel.mem.262144",
            "mem.chase.ws65536"} == ops
    assert all("ws=" in r["notes"] for r in blob["records"]
               if r["op"].startswith("inkernel.mem."))

    rc = cli.main(args)  # resume: same command is pure cache hits
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 measured, 3 cached, 0 failed" in out
