"""AdamW + int8 quantized state: math vs a reference implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.parallel.sharding import Param


def _ref_adamw(p, g, m, v, t, cfg, lr):
    gnorm = np.sqrt((g ** 2).sum())
    g = g * min(1.0, cfg.grad_clip / max(gnorm, 1e-9))
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    wd = cfg.weight_decay if p.ndim >= 2 else 0.0
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + wd * p), m, v


def test_adamw_matches_reference():
    cfg = optim.AdamWConfig(lr=1e-2)
    rng = np.random.RandomState(0)
    p_np = rng.randn(16, 32).astype(np.float32)
    params = {"w": Param(jnp.asarray(p_np), ("a", "b"))}
    state = optim.init_state(params, cfg)
    m = v = np.zeros_like(p_np)
    ref_p = p_np.copy()
    for t in range(1, 4):
        g_np = rng.randn(16, 32).astype(np.float32) * 0.1
        grads = {"w": Param(jnp.asarray(g_np), ("a", "b"))}
        params, state = optim.apply_update(params, grads, state, cfg)
        ref_p, m, v = _ref_adamw(ref_p, g_np, m, v, t, cfg, cfg.lr)
        np.testing.assert_allclose(params["w"].value, ref_p, atol=1e-5, rtol=1e-5)


@given(st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_quantize_roundtrip_bounded(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(8, 256).astype(np.float32))
    qs = optim.quantize_i8(x)
    back = optim.dequantize_i8(qs, x.shape)
    # error bounded by scale/2 per block
    scale = np.repeat(np.asarray(qs["scale"]), 128, axis=-1).reshape(x.shape)
    assert np.all(np.abs(np.asarray(back - x)) <= scale * 0.51 + 1e-9)


def test_quantize_1d_passthrough():
    x = jnp.ones((64,))
    assert not isinstance(optim.quantize_i8(x), dict)


def test_int8_optimizer_tracks_f32():
    cfg8 = optim.AdamWConfig(lr=1e-2, state_dtype="int8")
    cfg32 = optim.AdamWConfig(lr=1e-2)
    rng = np.random.RandomState(1)
    p0 = rng.randn(32, 128).astype(np.float32)
    pa = {"w": Param(jnp.asarray(p0), ("a", "b"))}
    pb = {"w": Param(jnp.asarray(p0), ("a", "b"))}
    sa = optim.init_state(pa, cfg8)
    sb = optim.init_state(pb, cfg32)
    for t in range(5):
        g = jnp.asarray(rng.randn(32, 128).astype(np.float32) * 0.1)
        pa, sa = optim.apply_update(pa, {"w": Param(g, ("a", "b"))}, sa, cfg8)
        pb, sb = optim.apply_update(pb, {"w": Param(g, ("a", "b"))}, sb, cfg32)
    diff = np.abs(np.asarray(pa["w"].value - pb["w"].value)).max()
    scale = np.abs(np.asarray(pb["w"].value)).max()
    assert diff < 0.05 * scale, f"int8 diverged: {diff} vs {scale}"


def test_cosine_lr_shape():
    import numpy as np
    lrs = [float(optim.cosine_lr(jnp.asarray(s), warmup=10, total=100))
           for s in range(0, 100, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] < lrs[2]
