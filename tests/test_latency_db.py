"""LatencyDB: persistence, queries, report generation (property-based)."""
import dataclasses
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.latency_db import LatencyDB, LatencyRecord, ProbeFailure

rec_st = st.builds(
    LatencyRecord,
    op=st.sampled_from(["add", "mul", "sqrt", "div.s.runtime"]),
    category=st.sampled_from(["int_arith", "fp32"]),
    dtype=st.sampled_from(["int32", "float32"]),
    opt_level=st.sampled_from(["O0", "O1", "O3"]),
    latency_ns=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    mad_ns=st.floats(min_value=0, max_value=1e3, allow_nan=False),
    cycles=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    guard=st.integers(0, 3),
    net_latency_ns=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    device_kind=st.just("cpu"), backend=st.just("cpu"),
    jax_version=st.sampled_from(["0.8.2", "0.9.0"]),
    n_samples=st.integers(1, 100),
    measured_at=st.text(alphabet="0123456789T:-", max_size=20),
    notes=st.just(""),
)


@given(st.lists(rec_st, max_size=30))
@settings(max_examples=25, deadline=None)
def test_roundtrip(tmp_path_factory, recs):
    db = LatencyDB()
    db.extend(recs)
    path = str(tmp_path_factory.mktemp("db") / "lat.json")
    db.save(path)
    db2 = LatencyDB(path)
    assert len(db2) == len(db)
    assert {r.key() for r in db2.records()} == {r.key() for r in db.records()}


@given(st.lists(rec_st, min_size=1, max_size=30))
@settings(max_examples=25, deadline=None)
def test_query_filters(recs):
    db = LatencyDB()
    db.extend(recs)
    for r in db.records():
        got = db.query(op=r.op, opt_level=r.opt_level)
        assert all(g.op == r.op and g.opt_level == r.opt_level for g in got)
        assert any(g.key() == r.key() for g in got)


def test_lookup_and_tables():
    db = LatencyDB()
    for lv, ns in (("O3", 5.0), ("O0", 5000.0)):
        db.add(LatencyRecord(op="add", category="int_arith", dtype="int32",
                             opt_level=lv, latency_ns=ns, mad_ns=0, cycles=ns,
                             guard=1, net_latency_ns=ns / 2, device_kind="cpu",
                             backend="cpu", jax_version="0.8.2", n_samples=10))
    assert db.lookup_ns("add", "O3") == 5.0
    md = db.table_markdown()
    assert "add" in md and "Optimized" in md and "Non-Optimized" in md


fail_st = st.builds(
    ProbeFailure,
    op=st.sampled_from(["boom", "kaput"]),
    dtype=st.sampled_from(["int32", "float32"]),
    opt_level=st.sampled_from(["O0", "O3"]),
    device_kind=st.just("cpu"), backend=st.just("cpu"),
    jax_version=st.just("0.8.2"),
    error_type=st.sampled_from(["ValueError", "RuntimeError"]),
    message=st.text(min_size=1, max_size=30),
    failed_at=st.text(alphabet="0123456789T:-", max_size=20),
)


@given(st.lists(rec_st, max_size=20), st.lists(fail_st, min_size=1, max_size=5))
@settings(max_examples=15, deadline=None)
def test_roundtrip_preserves_failures_and_mad(tmp_path_factory, recs, fails):
    """Records (incl. mad_ns to full precision) and ProbeFailures both
    survive a save/load cycle."""
    db = LatencyDB()
    db.extend(recs)
    for f in fails:
        db.add_failure(f)
    path = str(tmp_path_factory.mktemp("db") / "lat.json")
    db.save(path)
    db2 = LatencyDB(path)
    assert {(r.key(), r.mad_ns, r.latency_ns) for r in db2.records()} == \
        {(r.key(), r.mad_ns, r.latency_ns) for r in db.records()}
    assert {f.key() for f in db2.failures()} == {f.key() for f in db.failures()}


def _filled_db(n=4):
    db = LatencyDB()
    for i in range(n):
        db.add(LatencyRecord(op=f"op{i}", category="int_arith", dtype="int32",
                             opt_level="O3", latency_ns=float(i), mad_ns=0.5,
                             cycles=float(i), guard=0, net_latency_ns=float(i),
                             device_kind="cpu", backend="cpu",
                             jax_version="0.8.2", n_samples=3))
    db.add_failure(ProbeFailure(op="boom", dtype="int32", opt_level="O3",
                                device_kind="cpu", backend="cpu",
                                jax_version="0.8.2", error_type="ValueError",
                                message="bad", failed_at="t"))
    return db


def test_recover_truncated_db(tmp_path):
    """A sweep killed mid-save leaves a truncated file: strict load refuses,
    recover() salvages every complete record."""
    path = tmp_path / "db.json"
    db = _filled_db()
    db.save(str(path))
    text = path.read_text()
    path.write_text(text[:text.find('"op3"')])  # last record cut mid-object
    with pytest.raises(Exception):
        LatencyDB(str(path))
    rec = LatencyDB.recover(str(path))
    assert len(rec) == len(db) - 1
    assert {r.key() for r in rec.records()} < {r.key() for r in db.records()}
    # the recovered DB is bound to the path: a save round-trips strictly again
    rec.save()
    assert len(LatencyDB(str(path))) == len(rec)


def test_recover_skips_partial_objects_without_raising(tmp_path):
    """A decodable dict missing required fields (e.g. a ProbeFailure cut in
    half that still parses) is skipped, never re-raised: recover()'s contract
    is to salvage, not to fail on a second kind of damage."""
    path = tmp_path / "db.json"
    _filled_db(n=2).save(str(path))
    text = path.read_text()
    # corrupt the file AND plant a well-formed-but-incomplete failure object
    path.write_text(text[:text.find('"op1"')] +
                    '{"op": "x", "error_type": "ValueError"} ]')
    rec = LatencyDB.recover(str(path))
    assert len(rec) == 1 and rec.failures() == []


# ----------------------------------------------------------- merge semantics
def _rec(op="add", ns=1.0, measured_at="2026-01-01T00:00:00", device="cpu"):
    return LatencyRecord(op=op, category="int_arith", dtype="int32",
                         opt_level="O3", latency_ns=ns, mad_ns=0.0, cycles=ns,
                         guard=0, net_latency_ns=ns, device_kind=device,
                         backend="cpu", jax_version="0.8.2", n_samples=3,
                         measured_at=measured_at)


def _fail(op="add", failed_at="2026-01-01T00:00:00"):
    return ProbeFailure(op=op, dtype="int32", opt_level="O3",
                        device_kind="cpu", backend="cpu", jax_version="0.8.2",
                        error_type="ValueError", message="bad",
                        failed_at=failed_at)


def test_merge_newest_measured_at_wins():
    old = LatencyDB()
    old.add(_rec(ns=100.0, measured_at="2026-01-01T00:00:00"))
    new = LatencyDB()
    new.add(_rec(ns=5.0, measured_at="2026-06-01T00:00:00"))
    assert old.merge(new).get(_rec().key()).latency_ns == 5.0
    # merging the stale copy back does NOT regress the value
    assert new.merge(old).get(_rec().key()).latency_ns == 5.0
    # equal timestamps keep the current (in-memory) record
    a, b = LatencyDB(), LatencyDB()
    a.add(_rec(ns=1.0))
    b.add(_rec(ns=2.0))
    assert a.merge(b).get(_rec().key()).latency_ns == 1.0


def test_merge_success_supersedes_failure_across_shards():
    failed_shard = LatencyDB()
    failed_shard.add_failure(_fail(failed_at="2026-06-01T00:00:00"))
    ok_shard = LatencyDB()
    ok_shard.add(_rec(measured_at="2026-01-01T00:00:00"))  # older than the failure
    merged = ok_shard.merge(failed_shard)
    assert merged.failures() == []
    assert merged.get(_rec().key()) is not None
    # and in the other direction (failure merged into DB that has the success)
    f2 = LatencyDB()
    f2.add_failure(_fail())
    f2.merge(ok_shard)
    assert f2.failures() == [] and len(f2) == 1


def test_merge_failures_newest_wins():
    a, b = LatencyDB(), LatencyDB()
    a.add_failure(_fail(failed_at="2026-01-01T00:00:00"))
    b.add_failure(_fail(failed_at="2026-06-01T00:00:00"))
    assert a.merge(b).failures()[0].failed_at == "2026-06-01T00:00:00"


def test_merge_multiple_and_disjoint():
    a, b, c = LatencyDB(), LatencyDB(), LatencyDB()
    a.add(_rec("add"))
    b.add(_rec("mul"))
    c.add(_rec("sqrt"))
    assert {r.op for r in a.merge(b, c).records()} == {"add", "mul", "sqrt"}


# ----------------------------------------------------- concurrent-flush safety
def test_save_merges_on_disk_state_no_clobber(tmp_path):
    """Regression for the clobber bug: two DBs flushing to one path used to
    last-writer-wins the whole file; save now read-merges before writing."""
    path = str(tmp_path / "shared.json")
    a, b = LatencyDB(path), LatencyDB(path)
    a.add(_rec("add"))
    b.add(_rec("mul"))
    a.save()
    b.save()  # merges a's flush instead of overwriting it
    ops = {r.op for r in LatencyDB(path).records()}
    assert ops == {"add", "mul"}
    # b learned a's records during its flush (cross-writer resume)
    assert {r.op for r in b.records()} == {"add", "mul"}


def test_save_merge_keeps_newest_on_conflict(tmp_path):
    path = str(tmp_path / "shared.json")
    stale, fresh = LatencyDB(path), LatencyDB(path)
    fresh.add(_rec(ns=5.0, measured_at="2026-06-01T00:00:00"))
    fresh.save()
    stale.add(_rec(ns=100.0, measured_at="2026-01-01T00:00:00"))
    stale.save()
    assert LatencyDB(path).get(_rec().key()).latency_ns == 5.0


def test_save_without_merge_mirrors_memory(tmp_path):
    path = str(tmp_path / "db.json")
    a = LatencyDB(path)
    a.add(_rec("add"))
    a.save()
    b = LatencyDB(path)
    b._records.clear()
    b.add(_rec("mul"))
    b.save(merge_on_disk=False)
    assert {r.op for r in LatencyDB(path).records()} == {"mul"}


def test_atomic_save_crash_leaves_previous_file_intact(tmp_path, monkeypatch):
    """A writer killed mid-save must never leave a truncated file at the DB
    path (the exact damage LatencyDB.recover exists to salvage)."""
    import json as json_mod

    path = str(tmp_path / "db.json")
    db = LatencyDB(path)
    db.add(_rec("add"))
    db.save()
    before = open(path).read()

    crasher = LatencyDB(path)
    crasher.add(_rec("mul"))
    real_dump = json_mod.dump

    def dump_then_die(obj, fp, **kw):
        fp.write('{"records": [{"op": "trunc')  # partial bytes hit the temp file
        raise OSError("disk full")

    monkeypatch.setattr(json_mod, "dump", dump_then_die)
    with pytest.raises(OSError):
        crasher.save()
    monkeypatch.setattr(json_mod, "dump", real_dump)

    assert open(path).read() == before          # previous file untouched
    assert len(LatencyDB(path)) == 1            # and still strictly loadable
    assert not list(tmp_path.glob("*.tmp"))     # no orphaned temp files


def test_compare_markdown_pairs_within_one_environment_only():
    """Regression: dispatch and in-kernel records from different
    device/backend/jax environments must never be paired into a ratio."""
    def rec(op, env, ns):
        return LatencyRecord(op=op, category="int_arith", dtype="int32",
                             opt_level="O3", latency_ns=ns, mad_ns=0.0,
                             cycles=ns, guard=0, net_latency_ns=ns,
                             device_kind=env, backend=env, jax_version="x",
                             n_samples=2)

    db = LatencyDB()
    db.add(rec("add", "cpu", 100.0))
    db.add(rec("inkernel.add", "tpu", 1.0))   # other device: no pair
    assert db.compare_markdown().count("\n") == 1  # header + separator only
    db.add(rec("inkernel.add", "cpu", 50.0))  # same env: pairs
    md = db.compare_markdown()
    assert "| add | int32 | 100.00±0.00 | 50.00±0.00 | 0.500 |" in md


def test_recover_garbage_and_intact_and_missing(tmp_path):
    garbage = tmp_path / "garbage.json"
    garbage.write_text('{"records": [{not json')
    assert len(LatencyDB.recover(str(garbage))) == 0

    intact = tmp_path / "intact.json"
    _filled_db().save(str(intact))
    rec = LatencyDB.recover(str(intact))
    assert len(rec) == 4 and len(rec.failures()) == 1  # identical to strict

    missing = LatencyDB.recover(str(tmp_path / "nope.json"))
    assert len(missing) == 0 and missing.path.endswith("nope.json")


def test_fidelity_keyed_cache_identity_rejects_low_fidelity():
    """Regression lock (PR 1 cache-identity fix): a low-fidelity variant
    persists under a suffixed op name, so the standard probe's key can never
    be satisfied by it — for memory chases and in-kernel chains alike."""
    from repro.api.probes import KernelChainProbe, MemoryProbe
    from repro.core import chains

    env = {"device_kind": "cpu", "backend": "cpu", "jax_version": "x"}
    quick, std = MemoryProbe(8192, steps=(512, 1536)), MemoryProbe(8192)
    db = LatencyDB()
    db.add(LatencyRecord(op=quick.op, category="memory", dtype="int32",
                         opt_level="O3", latency_ns=1.0, mad_ns=0.0, cycles=1.0,
                         guard=0, net_latency_ns=1.0, n_samples=2, **env))
    assert quick.key(env) in db
    assert std.key(env) not in db

    spec = next(o for o in chains.default_registry() if o.name == "add")
    low, full = KernelChainProbe(spec, lens=(2, 8)), KernelChainProbe(spec)
    db.add(LatencyRecord(op=low.op, category=spec.category, dtype=spec.dtype,
                         opt_level="O3", latency_ns=1.0, mad_ns=0.0, cycles=1.0,
                         guard=1, net_latency_ns=1.0, n_samples=2, **env))
    assert low.key(env) in db
    assert full.key(env) not in db


def test_version_diff_table():
    db = LatencyDB()
    for ver, ns in (("9.0", 100.0), ("10.0", 50.0)):
        db.add(LatencyRecord(op="div.s.runtime", category="int_arith",
                             dtype="int32", opt_level="O3", latency_ns=ns,
                             mad_ns=0, cycles=ns, guard=1, net_latency_ns=ns,
                             device_kind="cpu", backend="cpu", jax_version=ver,
                             n_samples=10))
    md = db.diff_markdown("9.0", "10.0")
    assert "div.s.runtime" in md and "-50.0%" in md


# ------------------------------------------------------ journal delta flush
def test_flush_appends_delta_journal_only(tmp_path):
    """flush is the per-probe durability point: one JSONL append per new
    entry, never a whole-file rewrite, and a no-op when nothing is dirty."""
    path = str(tmp_path / "db.json")
    journal = path + ".journal"
    db = LatencyDB(path)
    db.add(_rec("add"))
    db.flush()
    assert not os.path.exists(path)          # no whole-file write
    assert len(open(journal).readlines()) == 1

    db.flush()                               # nothing dirty: nothing appended
    assert len(open(journal).readlines()) == 1

    db.add(_rec("mul"))
    db.add_failure(_fail("boom"))
    db.flush()
    assert len(open(journal).readlines()) == 3  # delta only, not a rewrite

    # a fresh DB replays the journal even though the main file never existed
    again = LatencyDB(path)
    assert {r.op for r in again.records()} == {"add", "mul"}
    assert [f.op for f in again.failures()] == ["boom"]


def test_journal_replays_on_top_of_main_file(tmp_path):
    path = str(tmp_path / "db.json")
    base = LatencyDB(path)
    base.add(_rec("add", ns=1.0))
    base.save()

    cont = LatencyDB(path)                   # resumed sweep
    cont.add(_rec("mul", ns=2.0))
    cont.flush()                             # journal append only

    merged = LatencyDB(path)
    assert {r.op for r in merged.records()} == {"add", "mul"}


def test_save_compacts_journal_and_disk_state(tmp_path):
    path = str(tmp_path / "db.json")
    db = LatencyDB(path)
    db.add(_rec("add"))
    db.flush()
    assert not db._disk_unchanged(path)      # pending journal counts as changed
    db.save()
    assert not os.path.exists(path + ".journal")
    assert db._disk_unchanged(path)          # compacted state is remembered
    assert len(LatencyDB(path)) == 1

    # a new journal from another writer invalidates the remembered state
    other = LatencyDB(path)
    other.add(_rec("mul"))
    other.flush()
    assert not db._disk_unchanged(path)
    db.save()                                # compaction merges the journal
    assert {r.op for r in LatencyDB(path).records()} == {"add", "mul"}


def test_torn_journal_tail_is_skipped(tmp_path):
    """A crash mid-append leaves at most one torn final line; replay takes
    every complete entry and drops the tail instead of refusing to load."""
    path = str(tmp_path / "db.json")
    db = LatencyDB(path)
    db.add(_rec("add"))
    db.add(_rec("mul"))
    db.flush()
    with open(path + ".journal", "a") as f:
        f.write('{"r": {"op": "sqrt", "cate')  # torn mid-append

    replayed = LatencyDB(path)
    assert {r.op for r in replayed.records()} == {"add", "mul"}
    # journal entries are already durable: a flush must not re-append them
    replayed.flush()
    assert sum(1 for line in open(path + ".journal") if line.strip()) == 3


def test_flushed_entries_not_dirty_after_reload(tmp_path):
    """Round-trip dirtiness: flush clears it, load/replay never re-marks it,
    so a resumed session's first flush appends nothing."""
    path = str(tmp_path / "db.json")
    db = LatencyDB(path)
    db.add(_rec("add"))
    db.flush()
    db.save()

    resumed = LatencyDB(path)
    assert not resumed._dirty_records and not resumed._dirty_failures
    resumed.flush()
    assert not os.path.exists(path + ".journal")
