"""LatencyDB: persistence, queries, report generation (property-based)."""
import dataclasses

from hypothesis import given, settings, strategies as st

from repro.core.latency_db import LatencyDB, LatencyRecord

rec_st = st.builds(
    LatencyRecord,
    op=st.sampled_from(["add", "mul", "sqrt", "div.s.runtime"]),
    category=st.sampled_from(["int_arith", "fp32"]),
    dtype=st.sampled_from(["int32", "float32"]),
    opt_level=st.sampled_from(["O0", "O1", "O3"]),
    latency_ns=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    mad_ns=st.floats(min_value=0, max_value=1e3, allow_nan=False),
    cycles=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    guard=st.integers(0, 3),
    net_latency_ns=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    device_kind=st.just("cpu"), backend=st.just("cpu"),
    jax_version=st.sampled_from(["0.8.2", "0.9.0"]),
    n_samples=st.integers(1, 100),
    measured_at=st.text(alphabet="0123456789T:-", max_size=20),
    notes=st.just(""),
)


@given(st.lists(rec_st, max_size=30))
@settings(max_examples=25, deadline=None)
def test_roundtrip(tmp_path_factory, recs):
    db = LatencyDB()
    db.extend(recs)
    path = str(tmp_path_factory.mktemp("db") / "lat.json")
    db.save(path)
    db2 = LatencyDB(path)
    assert len(db2) == len(db)
    assert {r.key() for r in db2.records()} == {r.key() for r in db.records()}


@given(st.lists(rec_st, min_size=1, max_size=30))
@settings(max_examples=25, deadline=None)
def test_query_filters(recs):
    db = LatencyDB()
    db.extend(recs)
    for r in db.records():
        got = db.query(op=r.op, opt_level=r.opt_level)
        assert all(g.op == r.op and g.opt_level == r.opt_level for g in got)
        assert any(g.key() == r.key() for g in got)


def test_lookup_and_tables():
    db = LatencyDB()
    for lv, ns in (("O3", 5.0), ("O0", 5000.0)):
        db.add(LatencyRecord(op="add", category="int_arith", dtype="int32",
                             opt_level=lv, latency_ns=ns, mad_ns=0, cycles=ns,
                             guard=1, net_latency_ns=ns / 2, device_kind="cpu",
                             backend="cpu", jax_version="0.8.2", n_samples=10))
    assert db.lookup_ns("add", "O3") == 5.0
    md = db.table_markdown()
    assert "add" in md and "Optimized" in md and "Non-Optimized" in md


def test_version_diff_table():
    db = LatencyDB()
    for ver, ns in (("9.0", 100.0), ("10.0", 50.0)):
        db.add(LatencyRecord(op="div.s.runtime", category="int_arith",
                             dtype="int32", opt_level="O3", latency_ns=ns,
                             mad_ns=0, cycles=ns, guard=1, net_latency_ns=ns,
                             device_kind="cpu", backend="cpu", jax_version=ver,
                             n_samples=10))
    md = db.diff_markdown("9.0", "10.0")
    assert "div.s.runtime" in md and "-50.0%" in md
