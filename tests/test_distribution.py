"""Distribution layer tests on 8 fake devices (subprocess: the main pytest
process must keep seeing 1 device)."""
import pytest

from tests._subproc import run_with_devices


def test_sharding_rules_resolve_and_fallback():
    import jax
    from repro.parallel import sharding as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = shd.lm_rules()
    spec = rules.resolve(("embed", "heads"), (64, 40), mesh)
    assert spec is not None  # trivial mesh: everything resolves


@pytest.mark.slow
def test_sharded_train_step_runs():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.config import ModelConfig, Runtime
from repro.models import transformer
from repro.parallel import sharding as shd
from repro import optim
from repro.launch.mesh import make_mesh_for

cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")
rt = Runtime(remat=False, xent_chunk=16, moe_groups=4)
mesh = make_mesh_for(8, model_parallel=2)
rules = shd.lm_rules(fsdp=True, fsdp_axes=("data",))
with shd.use_sharding(mesh, rules):
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    psh = shd.param_shardings(params, mesh, rules)
    params = jax.tree_util.tree_map(
        lambda v, s: jax.device_put(v, s), shd.unbox(params),
        jax.tree_util.tree_map(lambda x: x, psh))
    params = shd.rebox(params, shd.boxed_axes(transformer.init_lm(jax.random.PRNGKey(0), cfg)))
    ocfg = optim.AdamWConfig(lr=1e-3)
    state = optim.init_state(params, ocfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    batch = jax.device_put(batch, bsh)

    def step(p, s, b):
        (l, m), g = jax.value_and_grad(
            lambda q: transformer.train_loss(q, b, cfg, rt), has_aux=True)(p)
        np_, ns = optim.apply_update(p, g, s, ocfg)
        return np_, ns, l

    p2, s2, loss = jax.jit(step)(params, state, batch)
    assert jnp.isfinite(loss), loss
    # loss must be identical to the single-device value
    print("LOSS", float(loss))
""", n_devices=8)
    assert "LOSS" in out


@pytest.mark.slow
def test_sharded_loss_matches_unsharded():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.config import ModelConfig, Runtime
from repro.models import transformer
from repro.parallel import sharding as shd
from repro.launch.mesh import make_mesh_for

cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")
rt = Runtime(remat=False, xent_chunk=16, moe_groups=1)
params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
batch = {"tokens": tokens, "labels": tokens}
l_ref, _ = jax.jit(lambda p, b: transformer.train_loss(p, b, cfg, rt))(params, batch)

mesh = make_mesh_for(8, model_parallel=2)
rules = shd.lm_rules()
with shd.use_sharding(mesh, rules):
    psh = shd.param_shardings(params, mesh, rules)
    l_sh, _ = jax.jit(lambda p, b: transformer.train_loss(p, b, cfg, rt),
                      in_shardings=(psh, {k: NamedSharding(mesh, P("data", None))
                                          for k in batch}))(params, batch)
diff = abs(float(l_ref) - float(l_sh))
assert diff < 1e-4, (float(l_ref), float(l_sh))
print("MATCH", diff)
""", n_devices=8)
    assert "MATCH" in out


@pytest.mark.slow
def test_quantized_psum_and_collective_matmul():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import collectives
from repro.launch.mesh import make_mesh_for

mesh = make_mesh_for(8, model_parallel=4)
# quantized psum_mean vs exact mean
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 256))}
red, err = collectives.quantized_psum_mean(g, mesh, axis="data")
# every shard contributed the same full array (replicated in_specs P()) ->
# mean == original, up to int8 error
d = float(jnp.max(jnp.abs(red["w"] - g["w"])))
scale = float(jnp.max(jnp.abs(g["w"])))
assert d < 0.02 * scale, (d, scale)

# collective matmul == dense matmul, and no all-gather in HLO
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
w = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
y = collectives.collective_matmul(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4, rtol=1e-4)
txt = jax.jit(lambda a, b: collectives.collective_matmul(a, b, mesh, axis="model")
              ).lower(x, w).compile().as_text()
assert "all-gather" not in txt, "collective matmul must not all-gather"
assert "collective-permute" in txt
print("OK")
""", n_devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_parallel_matches_reference():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import pipeline
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pod",))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

s, d = 4, 16
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (s, d, d)) * 0.5,
          "b": jax.random.normal(jax.random.PRNGKey(1), (s, d)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(2), (6, 3, d))   # 6 microbatches
y = pipeline.pipeline_forward(stage_fn, params, x, mesh, axis="pod")
want = pipeline.reference_forward(stage_fn, params, x)
np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5, rtol=1e-5)
assert abs(pipeline.bubble_fraction(4, 6) - 3/9) < 1e-9
print("OK")
""", n_devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_across_meshes():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh_for

d = tempfile.mkdtemp()
mesh_a = make_mesh_for(8, model_parallel=2)      # 4x2
tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", "model")))}
mgr = CheckpointManager(d, async_save=False)
mgr.save(1, tree)
# restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 2x4)
mesh_b = make_mesh_for(8, model_parallel=4)
sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
step, back = mgr.restore(tree, shardings=sh)
np.testing.assert_array_equal(np.asarray(back["w"]), np.arange(64.0).reshape(8, 8))
assert back["w"].sharding.mesh.shape == mesh_b.shape
print("OK")
""", n_devices=8)
    assert "OK" in out
