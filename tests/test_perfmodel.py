"""Roofline model + HLO latency estimator.

The estimator tests are *oracles*: hand-written HLO modules against a
synthetic LatencyDB where the exact expected nanoseconds are computed by hand
from the documented pricing rules — trip-count rollup, lane amortization, the
matmul fma-equivalent term, the chase-ladder memory term, and the coverage
fraction. A change to any pricing rule must show up here as a changed
constant, never as a silently different total.
"""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.core import chains, hlo_analysis, perfmodel
from repro.core.latency_db import LatencyDB, LatencyRecord


def _roof(flops, bts, hlo=""):
    return perfmodel.Roofline().analyze(
        arch="a", shape="s", mesh="m", chips=256,
        cost={"flops": flops, "bytes accessed": bts}, hlo_text=hlo,
        model_flops=flops * 256 * 0.5)


def _rec(op, ns, cat="fp32", dtype="float32", opt="O3", notes="", env=None):
    env = env or {"device_kind": "cpu", "backend": "cpu", "jax_version": "x"}
    return LatencyRecord(op=op, category=cat, dtype=dtype, opt_level=opt,
                         latency_ns=ns, mad_ns=0, cycles=ns, guard=0,
                         net_latency_ns=ns, n_samples=5, measured_at="t",
                         notes=notes, **env)


def test_dominant_term():
    r = _roof(197e12 * 0.01, 819e9 * 0.001)
    assert r.dominant == "compute"
    r = _roof(197e12 * 0.001, 819e9 * 0.01)
    assert r.dominant == "memory"


def test_terms_math():
    r = _roof(flops=197e12, bts=819e9)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_knee():
    assert perfmodel.TPU_V5E.arithmetic_intensity_knee == pytest.approx(
        197e12 / 819e9)


def test_markdown_row_shape():
    r = _roof(1e12, 1e10)
    row = perfmodel.Roofline.markdown_row(r)
    assert len(row) == len(perfmodel.Roofline.MD_HEADERS)


# =========================================================== estimator oracles
# Hand-written modules: every shape/count below is chosen so the expected ns
# is computable on paper. lanes=8, THROUGHPUT_FACTOR=0.25 throughout.

ELEMWISE_HLO = """
HloModule elemwise

ENTRY %main (a: f32[256], b: f32[256]) -> f32[256] {
  %a = f32[256] parameter(0)
  %b = f32[256] parameter(1)
  ROOT %s = f32[256] add(f32[256] %a, f32[256] %b)
}
"""

WHILE_HLO = """
HloModule rollup

%body (p0: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p0 = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8]) %p0), index=0
  %x = f32[8] get-tuple-element((s32[], f32[8]) %p0), index=1
  %t = f32[8] tanh(f32[8] %x)
  %one = s32[] constant(1)
  %ni = s32[] add(s32[] %i, s32[] %one)
  ROOT %r = (s32[], f32[8]) tuple(s32[] %ni, f32[8] %t)
}

%cond (p1: (s32[], f32[8])) -> pred[] {
  %p1 = (s32[], f32[8]) parameter(0)
  %ii = s32[] get-tuple-element((s32[], f32[8]) %p1), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %ii, s32[] %n), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8]) tuple(s32[] %z, f32[8] %a)
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8] get-tuple-element((s32[], f32[8]) %w), index=1
}
"""

DOT_HLO = """
HloModule matmul

ENTRY %main (a: f32[4,8], b: f32[8,16]) -> f32[4,16] {
  %a = f32[4,8] parameter(0)
  %b = f32[8,16] parameter(1)
  ROOT %d = f32[4,16] dot(f32[4,8] %a, f32[8,16] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

MIXED_HLO = """
HloModule mixed

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %t = f32[8] tanh(f32[8] %a)
  ROOT %f = f32[8] floor(f32[8] %t)
}
"""


def test_dynamic_histogram_rolls_trip_counts():
    flat = hlo_analysis.op_histogram(WHILE_HLO)
    dyn = hlo_analysis.dynamic_op_histogram(WHILE_HLO)
    assert flat[("tanh", 8)] == 1
    assert dyn[("tanh", 8)] == 5.0          # while body x known_trip_count
    assert dyn[("add", 1)] == 5.0


def test_oracle_lane_amortization():
    """One top-level f32[256] add: 1 issue + 255 amortized elements.

    expected = lat + (256-1)/8 * 0.25 * lat = 2 + 255/32 * 2 = 17.9375
    """
    db = LatencyDB()
    db.add(_rec("add.float32", 2.0))
    r = perfmodel.HloLatencyEstimator(db).estimate(ELEMWISE_HLO)
    assert r.compute_ns == pytest.approx(2.0 + (255 / 8) * 0.25 * 2.0)
    assert r.compute_ns == pytest.approx(17.9375)
    assert r.coverage == 1.0
    assert r.memory_ns == 0.0               # no ladder in the DB
    assert r.total_ns == r.compute_ns


def test_oracle_trip_count_rollup():
    """While body priced x n=5: 5 tanh(f32[8]) + 5 add(s32[]).

    tanh: 5 * (10 + 7/8*0.25*10) = 5 * 12.1875 = 60.9375
    add (via add.float32 row): 5 * 2 = 10       => 70.9375 total
    """
    db = LatencyDB()
    db.add(_rec("tanh", 10.0, cat="special_math"))
    db.add(_rec("add.float32", 2.0))
    r = perfmodel.HloLatencyEstimator(db).estimate(WHILE_HLO)
    assert r.compute_ns == pytest.approx(70.9375)
    assert r.coverage == 1.0
    assert r.priced_instances == 10.0
    # the special_math and fp32 classes split exactly
    assert r.by_class["special_math"].ns == pytest.approx(60.9375)
    assert r.by_class["special_math"].instances == 5.0
    assert r.by_class["fp32"].ns == pytest.approx(10.0)


def test_oracle_matmul_fma_pricing():
    """dot[4,16]x[8 contracting]: 1024 flops = 512 fma-equivalents.

    expected = 1*4 + (512-1)/8 * 0.25 * 4 = 4 + 63.875 = 67.875
    """
    db = LatencyDB()
    db.add(_rec("fma.float32", 4.0))
    r = perfmodel.HloLatencyEstimator(db).estimate(DOT_HLO)
    assert r.compute_ns == pytest.approx(4.0 + (511 / 8) * 0.25 * 4.0)
    assert r.by_class["matmul"].instances == 1.0
    assert r.by_class["matmul"].elements == pytest.approx(512.0)
    assert r.coverage == 1.0


def test_oracle_memory_term():
    """f32[256] add at top level: 3*1024 HBM bytes.

    ladder rung ws4096 @ 6.4ns/64B line -> 0.1 ns/B; mem_streams=8
    memory_ns = 3072 * 0.1 / 8 = 38.4, which exceeds compute (17.9375).
    """
    db = LatencyDB()
    db.add(_rec("add.float32", 2.0))
    db.add(_rec("mem.chase.ws4096", 6.4, cat="memory", dtype="int32",
                notes="cold_ns=1 stride=64"))
    r = perfmodel.HloLatencyEstimator(db).estimate(ELEMWISE_HLO)
    assert r.bytes_accessed == 3072.0
    assert r.memory_ns == pytest.approx(38.4)
    assert r.compute_ns == pytest.approx(17.9375)
    assert r.total_ns == pytest.approx(38.4)
    assert r.bound == "memory"


def test_memory_ladder_rung_selection_and_inkernel_preference():
    db = LatencyDB()
    db.add(_rec("mem.chase.ws4096", 4.0, cat="memory", dtype="int32",
                notes="stride=64"))
    db.add(_rec("mem.chase.ws1048576", 40.0, cat="memory", dtype="int32",
                notes="stride=64"))
    # in-kernel twin at the small rung wins over the host row
    db.add(_rec("inkernel.mem.4096", 2.0, cat="memory", dtype="int32",
                notes="ws=4096 line=64 space=vmem"))
    # fidelity-suffixed rows are different experiments: never in the ladder
    db.add(_rec("inkernel.mem.4096.vmem", 99.0, cat="memory", dtype="int32",
                notes="ws=4096 line=64 space=vmem"))
    est = perfmodel.HloLatencyEstimator(db)
    ladder = est.memory_ladder()
    assert [(g.working_set_bytes, g.ns_per_line, g.source) for g in ladder] \
        == [(4096, 2.0, "inkernel"), (1048576, 40.0, "host")]
    # footprint 3072 fits the 4 KiB rung: 3072 * (2/64) / 8
    assert est._memory_ns(3072) == pytest.approx(12.0)
    # footprint beyond the deepest rung falls back to it: ns/B = 40/64
    assert est._memory_ns(1 << 21) == pytest.approx((1 << 21) * (40 / 64) / 8)


def test_oracle_coverage_fraction():
    """tanh is measured; floor has no table mapping -> default-priced.

    coverage = 1 priced / 2 countable; floor contributes
    default_ns-priced ns and shows up in unpriced_opcodes.
    """
    db = LatencyDB()
    db.add(_rec("tanh", 10.0, cat="special_math"))
    est = perfmodel.HloLatencyEstimator(db, default_ns=5.0)
    r = est.estimate(MIXED_HLO)
    assert r.coverage == pytest.approx(0.5)
    assert r.priced_instances == 1.0 and r.unpriced_instances == 1.0
    assert dict(r.unpriced_opcodes) == {"floor": 1.0}
    per_op = 7 / 8 * 0.25                   # amortized tail factor at 8 elems
    assert r.compute_ns == pytest.approx(10 * (1 + per_op) + 5 * (1 + per_op))
    assert r.by_class["unpriced"].ns == pytest.approx(5 * (1 + per_op))


def test_mapped_but_unmeasured_counts_as_unpriced():
    """A mapped opcode with no DB row prices at default_ns and lowers
    coverage — the "silently skipping" failure mode, inverted."""
    est = perfmodel.HloLatencyEstimator(LatencyDB(), default_ns=3.0)
    r = est.estimate(ELEMWISE_HLO)
    assert r.coverage == 0.0
    assert dict(r.unpriced_opcodes) == {"add": 1.0}
    assert r.compute_ns == pytest.approx(3.0 * (1 + (255 / 8) * 0.25))


CUSTOM_CALL_HLO = """
HloModule opaque

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %t = f32[8] tanh(f32[8] %a)
  ROOT %k = f32[8] custom-call(f32[8] %t), custom_call_target="my_kernel"
}
"""


def test_custom_call_counts_as_unpriced():
    """An opaque library/Pallas kernel must lower coverage, not vanish —
    its (often dominant) cost is unknowable from the tables. Unresolved
    targets are reported by name, never lumped into one bucket."""
    db = LatencyDB()
    db.add(_rec("tanh", 10.0, cat="special_math"))
    r = perfmodel.HloLatencyEstimator(db).estimate(CUSTOM_CALL_HLO)
    assert r.coverage == pytest.approx(0.5)
    assert dict(r.unpriced_opcodes) == {"custom-call:my_kernel": 1.0}


def test_structural_ops_do_not_count():
    """parameter/tuple/gte never enter the coverage denominator."""
    db = LatencyDB()
    db.add(_rec("tanh", 10.0, cat="special_math"))
    db.add(_rec("add.float32", 2.0))
    r = perfmodel.HloLatencyEstimator(db).estimate(WHILE_HLO)
    # only tanh x5 and add x5 are countable in the whole module
    assert r.priced_instances + r.unpriced_instances == 10.0


def test_estimate_ns_attaches_report():
    db = LatencyDB()
    db.add(_rec("add.float32", 2.0))
    ns = perfmodel.HloLatencyEstimator(db).estimate_ns(ELEMWISE_HLO)
    assert isinstance(ns, float) and ns > 0
    assert ns.report.coverage == 1.0        # the satellite fix: no bare float
    assert float(ns) == ns.report.total_ns
    assert "coverage" in ns.report.summary()


def test_estimator_env_filters():
    """Rows from another device fingerprint must not price this module."""
    other = {"device_kind": "tpu", "backend": "tpu", "jax_version": "y"}
    db = LatencyDB()
    db.add(_rec("add.float32", 100.0, env=other))
    db.add(_rec("add.float32", 2.0))
    est = perfmodel.HloLatencyEstimator(
        db, filters={"device_kind": "cpu", "backend": "cpu",
                     "jax_version": "x"})
    r = est.estimate(ELEMWISE_HLO)
    assert r.compute_ns == pytest.approx(17.9375)   # priced from the cpu row
    est_tpu = perfmodel.HloLatencyEstimator(
        db, filters={"device_kind": "tpu", "backend": "tpu",
                     "jax_version": "y"})
    assert est_tpu.estimate(ELEMWISE_HLO).compute_ns > 100.0


def test_estimator_on_real_lowered_module():
    """End to end on a real jit-lowered scan: trip counts make the scanned
    tanh 8x the single-iteration price."""
    db = LatencyDB()
    db.add(_rec("tanh", 20.0, cat="special_math"))
    db.add(_rec("fma.float32", 2.0))

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=8)[0]

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    r = perfmodel.HloLatencyEstimator(db).estimate(txt)
    assert r.by_class["special_math"].instances == 8.0
    assert r.by_class["matmul"].instances == 8.0
    # 8 x (20 + 511/8*0.25*20) tanh alone
    assert r.by_class["special_math"].ns == pytest.approx(8 * 20 * (1 + 511 / 32))
    assert r.total_ns > 0 and 0 < r.coverage <= 1.0


# ==================================================== registry <-> table map
def test_hlo_table_mapping_resolves_to_registry_rows():
    """Every HLO_TO_TABLE value must price against a row some plan emits:
    a registry OpSpec name (directly or via the base-row fallback) or a
    memory-probe row — the estimator can never consult a phantom table."""
    names = {o.name for o in chains.default_registry()}
    for opcode, table_op in hlo_analysis.HLO_TO_TABLE.items():
        base = table_op.split(".")[0]
        resolves = (table_op in names or base in names
                    or perfmodel._MEM_ROW_RE.match(table_op))
        assert resolves, f"{opcode!r} -> {table_op!r} matches no emitted row"


def test_hlo_table_mapping_rows_are_measured_rows():
    """Sharper form: with a DB holding one row per registry op, every mapping
    value resolves to a *measured* latency (covered=True), so coverage can
    reach 1.0 on a fully characterized DB."""
    db = LatencyDB()
    for o in chains.default_registry():
        db.add(_rec(o.name, 1.0, cat=o.category, dtype=o.dtype))
    est = perfmodel.HloLatencyEstimator(db)
    for table_op in set(hlo_analysis.HLO_TO_TABLE.values()):
        lat, covered = est._table_latency(table_op)
        assert covered, f"{table_op!r} fell back to default_ns"


def test_table_category_classification():
    assert perfmodel._table_category("add.float32") == "fp32"
    assert perfmodel._table_category("tanh") == "special_math"
    assert perfmodel._table_category("sub") == "int_arith"
    assert perfmodel._table_category("no.such.row") == "uncategorized"


# ============================================================= serving points
def test_servingpoint_round_trip():
    rec = _rec("serving.prefill.b2p64", 1000.0, cat="serving",
               notes="phase=prefill batch=2 prompt=64 model=serving-tiny "
                     "predicted_ns=500.000 compute_ns=400.000 "
                     "memory_ns=500.000 coverage=0.8000 bound=memory")
    pt = perfmodel.servingpoint_from_record(rec)
    assert pt.phase == "prefill" and pt.batch == 2 and pt.prompt_len == 64
    assert pt.measured_ns == 1000.0 and pt.predicted_ns == 500.0
    assert pt.ratio == pytest.approx(0.5)
    assert pt.abs_log10_error == pytest.approx(0.30103, abs=1e-4)
    assert pt.coverage == pytest.approx(0.8)
    assert pt.model == "serving-tiny"


def test_servingpoint_degenerate_error_is_inf():
    rec = _rec("serving.decode.b1p16", 0.0, cat="serving",
               notes="phase=decode batch=1 prompt=16 predicted_ns=5.0 "
                     "coverage=0")
    pt = perfmodel.servingpoint_from_record(rec)
    assert pt.abs_log10_error == float("inf")
