"""Roofline model + HLO latency estimator."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import perfmodel
from repro.core.latency_db import LatencyDB, LatencyRecord


def _roof(flops, bts, hlo=""):
    return perfmodel.Roofline().analyze(
        arch="a", shape="s", mesh="m", chips=256,
        cost={"flops": flops, "bytes accessed": bts}, hlo_text=hlo,
        model_flops=flops * 256 * 0.5)


def test_dominant_term():
    r = _roof(197e12 * 0.01, 819e9 * 0.001)
    assert r.dominant == "compute"
    r = _roof(197e12 * 0.001, 819e9 * 0.01)
    assert r.dominant == "memory"


def test_terms_math():
    r = _roof(flops=197e12, bts=819e9)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_knee():
    assert perfmodel.TPU_V5E.arithmetic_intensity_knee == pytest.approx(
        197e12 / 819e9)


def test_hlo_latency_estimator():
    db = LatencyDB()
    db.add(LatencyRecord(op="tanh", category="special_math", dtype="float32",
                         opt_level="O3", latency_ns=20.0, mad_ns=0, cycles=20,
                         guard=0, net_latency_ns=20, device_kind="cpu",
                         backend="cpu", jax_version="x", n_samples=5))
    txt = jax.jit(lambda x: jnp.tanh(x)).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    est = perfmodel.HloLatencyEstimator(db)
    assert est.estimate_ns(txt) > 0


def test_markdown_row_shape():
    r = _roof(1e12, 1e10)
    row = perfmodel.Roofline.markdown_row(r)
    assert len(row) == len(perfmodel.Roofline.MD_HEADERS)
