"""Property-based stability + guard-accounting invariants, every registry row.

The measurement is only valid if (a) a chain of any length up to the measured
256 stays finite and dtype-stable — otherwise the timed region contains
NaN-path work the paper's numbers never see — and (b) ``OpSpec.guard``
honestly counts the extra anti-optimization ops inside ``step``, because
reporting subtracts ``guard x add-baseline`` and an overcounted guard would
push net latencies negative. Runs through the in-repo hypothesis stub when
the real package is absent (tests/_hypothesis_stub.py).
"""
import contextlib

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import chains

REG = chains.default_registry()


def _ctx(spec):
    if spec.requires_x64 or spec.dtype in ("int64", "uint64", "float64"):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


@pytest.mark.parametrize("spec", REG, ids=lambda s: s.name)
@given(n=st.integers(min_value=1, max_value=256))
@settings(max_examples=5, deadline=None)
def test_chain_stable_at_any_length(spec, n):
    """Finite, non-NaN, dtype-invariant carry for every chain length."""
    with _ctx(spec):
        out = chains.chain_fn(spec, n)(spec.carry(), *spec.operand_arrays())
        arr = jnp.asarray(out)
        assert arr.dtype == jnp.dtype(spec.dtype), \
            f"{spec.name}: carry dtype drifted to {arr.dtype} at n={n}"
        if jnp.issubdtype(arr.dtype, jnp.floating):
            assert bool(jnp.isfinite(arr)), f"{spec.name} diverged at n={n}"


@given(spec=st.sampled_from(REG))
@settings(max_examples=25, deadline=None)
def test_operands_match_carry_dtype(spec):
    """Operand tiles are built in the carry dtype: a silent upcast would add
    convert ops inside the timed chain."""
    with _ctx(spec):
        carry = spec.carry()
        for o in spec.operand_arrays():
            assert o.dtype == carry.dtype, spec.name


@pytest.mark.parametrize("spec", REG, ids=lambda s: s.name)
def test_guard_accounting_consistent(spec):
    """``guard`` counts extra ops *inside* step, so the step's jaxpr must
    contain at least 1 + guard primitives (measured op + guards), and guard
    stays in the small range the add-baseline subtraction assumes."""
    assert 0 <= spec.guard <= 3, spec.name
    with _ctx(spec):
        jaxpr = jax.make_jaxpr(spec.step)(spec.carry(), *spec.operand_arrays())
    assert len(jaxpr.eqns) >= 1 + spec.guard, \
        f"{spec.name}: step has {len(jaxpr.eqns)} primitives but claims " \
        f"guard={spec.guard} extras on top of the measured op"


def test_registry_names_unique_and_categorized():
    names = [s.name for s in REG]
    assert len(names) == len(set(names))
    assert {s.category for s in REG} == set(chains.CATEGORIES)
