"""Unit + property tests for the paper's timing model (core/timing, measure)."""
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import chains, measure
from repro.core.timing import (AdaptiveFidelity, Measurement, NoisySlopeError,
                               Timer, _summarize)


def test_summarize_median_mad():
    m = _summarize([10.0, 20.0, 30.0])
    assert m.median_ns == 20.0
    assert m.mad_ns == 10.0
    assert m.min_ns == 10.0


@given(st.lists(st.floats(min_value=0.0, max_value=1e9), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_summarize_properties(samples):
    m = _summarize(samples)
    assert min(samples) == m.min_ns
    assert min(samples) <= m.median_ns <= max(samples)
    assert m.mad_ns >= 0.0


def test_measurement_subtraction():
    a = Measurement(100.0, 2.0, 90.0, 10)
    b = Measurement(40.0, 1.0, 35.0, 10)
    d = a - b
    assert d.median_ns == 60.0
    assert d.min_ns == 55.0


def test_slope_cancels_constant_overhead():
    """Synthetic callables with known per-op cost + constant overhead."""
    import time

    def fn_by_len(n):
        def fn():
            t_end = time.perf_counter_ns() + 1000 * n + 50_000  # 1us/op + 50us fixed
            while time.perf_counter_ns() < t_end:
                pass
        return fn

    t = Timer(warmup=0, reps=3)
    est = t.slope(fn_by_len, 8, 64)
    assert 500 < est.median_ns < 2000, est  # ~1000 ns/op, overhead cancelled


def test_clock_overhead_positive():
    t = Timer(warmup=1, reps=5)
    ov = measure.clock_overhead(t, opt_levels=("O3",))
    assert ov["O3"] > 0


def test_measure_op_returns_finite():
    spec = next(o for o in chains.default_registry() if o.name == "fma.float32")
    ns = measure.measure_op(spec, "O3", Timer(warmup=1, reps=8))
    assert ns >= 0.0 and ns < 1e6


def test_calibrated_clock_sane():
    t = Timer()
    hz = t.calibrate_clock_hz()
    assert 1e8 <= hz <= 5e9


# ------------------------------------------------------ Measurement algebra
def test_measurement_sub_mad_quadrature():
    """Independent-noise subtraction: MADs combine in quadrature, medians and
    mins subtract, n takes the weaker side."""
    d = Measurement(100.0, 3.0, 90.0, 10) - Measurement(40.0, 4.0, 35.0, 8)
    assert d.median_ns == 60.0
    assert d.mad_ns == pytest.approx(5.0)  # sqrt(3^2 + 4^2)
    assert d.min_ns == 55.0
    assert d.n == 8


def test_measurement_scaled_scales_dispersion_not_n():
    s = Measurement(100.0, 8.0, 90.0, 10).scaled(0.25)
    assert (s.median_ns, s.mad_ns, s.min_ns, s.n) == (25.0, 2.0, 22.5, 10)


def test_single_sample_mad_is_zero():
    m = _summarize([42.0])
    assert (m.median_ns, m.mad_ns, m.min_ns, m.n) == (42.0, 0.0, 42.0, 1)


def test_slope_exact_on_synthetic_linear_cost(monkeypatch):
    """Virtual clock: fn_by_len(n) costs exactly intercept + slope*n ns, so
    Timer.slope must recover the slope exactly (intercept cancelled, MAD 0)."""
    import repro.core.timing as timing

    now = [0]
    monkeypatch.setattr(timing.time, "perf_counter_ns", lambda: now[0])
    SLOPE, INTERCEPT = 700, 50_000

    def fn_by_len(n):
        def fn():
            now[0] += INTERCEPT + SLOPE * n
        return fn

    est = Timer(warmup=1, reps=4).slope(fn_by_len, 8, 64)
    assert est.median_ns == pytest.approx(SLOPE)
    assert est.min_ns == pytest.approx(SLOPE)
    assert est.mad_ns == 0.0
    assert est.n == 4


# -------------------------------------------------- noisy-slope detection
def _virtual_clock(monkeypatch):
    import repro.core.timing as timing

    now = [0]
    monkeypatch.setattr(timing.time, "perf_counter_ns", lambda: now[0])
    return now


def test_slope_raises_noisy_after_widened_retry(monkeypatch):
    """A clock with zero n-dependence (pure overhead) must never produce a
    latency row: the old behavior silently persisted slope <= 0."""
    now = _virtual_clock(monkeypatch)

    def fn_by_len(n):  # cost independent of chain length
        return lambda: now.__setitem__(0, now[0] + 50_000)

    with pytest.raises(NoisySlopeError, match="widened retry"):
        Timer(warmup=0, reps=3).slope(fn_by_len, 8, 64)


def test_slope_retry_disabled_when_lens_capped(monkeypatch):
    now = _virtual_clock(monkeypatch)

    def fn_by_len(n):
        return lambda: now.__setitem__(0, now[0] + 50_000)

    with pytest.raises(NoisySlopeError) as ei:
        # retry_lens == original lens: the caller's max_chain left no room
        Timer(warmup=0, reps=3).slope(fn_by_len, 8, 64, retry_lens=(8, 64))
    assert "widened retry" not in str(ei.value)


def test_slope_retry_recovers_at_widened_spread(monkeypatch):
    """Noise floor hides the signal at (8, 64); the single widened retry at
    (8, 232) resolves it — scripted via a step-cost virtual clock."""
    now = _virtual_clock(monkeypatch)

    def fn_by_len(n):
        cost = 50_000 if n < 100 else 1000 * n
        return lambda: now.__setitem__(0, now[0] + cost)

    est = Timer(warmup=0, reps=3).slope(fn_by_len, 8, 64)
    assert est.median_ns == pytest.approx((1000 * 232 - 50_000) / (232 - 8))


def test_retry_lens_for_caps_at_max_chain():
    import dataclasses

    spec = next(o for o in chains.default_registry() if o.name == "add")
    wide = dataclasses.replace(spec, max_chain=None)
    assert measure.retry_lens_for(wide, 8, 64) == (8, 232)
    capped = dataclasses.replace(spec, max_chain=100)
    assert measure.retry_lens_for(capped, 8, 64) == (8, 100)
    # no room to widen at all: returns the original pair (retry disabled)
    tight = dataclasses.replace(spec, max_chain=64)
    assert measure.retry_lens_for(tight, 8, 64) == (8, 64)


# ----------------------------------------------------- adaptive fidelity
def test_adaptive_convergence_rule():
    af = AdaptiveFidelity(rel_mad=0.05, min_reps=4)
    assert not af.converged([100.0] * 3)          # below min_reps
    assert af.converged([100.0] * 4)              # MAD 0 <= 5% of median
    assert not af.converged([100.0, 200.0, 50.0, 400.0])
    assert not af.converged([0.0] * 8)            # zero median never converges


def test_adaptive_banks_then_spends_reps(monkeypatch):
    now = _virtual_clock(monkeypatch)
    t = Timer(warmup=0, reps=10, adaptive=AdaptiveFidelity(min_reps=4))

    # quiet: constant cost converges at min_reps, 6 reps banked
    quiet = t.time_callable(lambda: now.__setitem__(0, now[0] + 1000))
    assert quiet.n == 4 and t._rep_bank == 6

    # noisy: steadily drifting cost keeps MAD/median ~0.5, never converges
    state = [0]

    def noisy():
        state[0] += 1
        now[0] += 1000 * state[0]

    loud = t.time_callable(noisy)
    assert loud.n == 16  # nominal 10 + all 6 banked
    assert t._rep_bank == 0


def test_adaptive_off_keeps_fixed_reps(monkeypatch):
    now = _virtual_clock(monkeypatch)
    t = Timer(warmup=0, reps=10)
    m = t.time_callable(lambda: now.__setitem__(0, now[0] + 1000))
    assert m.n == 10


# ------------------------------------------- null-cache device invalidation
def test_null_cache_invalidated_on_pin_change():
    import jax

    dev = jax.devices()[0]
    builds = []

    def make_null():
        builds.append(1)
        return lambda: None

    t = Timer(warmup=0, reps=1)
    t.calibrate_null(make_null, key="k")
    t.calibrate_null(make_null, key="k")
    assert len(builds) == 1  # unpinned calibration cached

    t.device = dev  # pin: the unpinned-era entry is now untrustworthy
    t.calibrate_null(make_null, key="k")
    assert len(builds) == 2  # re-measured, keyed under the concrete device

    t.device = None  # unpin: device-keyed entry stays valid
    t.calibrate_null(make_null, key="k")
    assert len(builds) == 3  # but the unpinned slot must re-measure

    t.device = dev  # re-pin same device: concrete-keyed calibration survives
    t.calibrate_null(make_null, key="k")
    assert len(builds) == 3
