"""Model math: attention impl equivalence, rope/mrope, moe routing, ssm/xlstm
recurrent vs chunked equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import blocks, common, ssm, xlstm
from repro.models.config import ModelConfig, Runtime
from repro.parallel.sharding import unbox

KEY = jax.random.PRNGKey(3)


# ----------------------------------------------------------------- attention
@pytest.mark.parametrize("sq,sk,block", [(64, 64, 16), (32, 96, 32), (128, 128, 128)])
def test_blockwise_matches_plain(sq, sk, block):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, 4, 32))
    k = jax.random.normal(ks[1], (2, sk, 2, 32))
    v = jax.random.normal(ks[2], (2, sk, 2, 32))
    a = common.plain_attention(q, k, v, causal=True, q_offset=sk - sq)
    b = common.blockwise_attention(q, k, v, causal=True, q_offset=sk - sq,
                                   block_k=block)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_decode_attention_matches_plain_lastrow():
    ks = jax.random.split(KEY, 3)
    s = 64
    q = jax.random.normal(ks[0], (2, s, 4, 32))
    k = jax.random.normal(ks[1], (2, s, 2, 32))
    v = jax.random.normal(ks[2], (2, s, 2, 32))
    full = common.plain_attention(q, k, v, causal=True)
    dec = common.decode_attention(q[:, -1], k, v, kv_len=s)
    np.testing.assert_allclose(full[:, -1], dec, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------- rope
def test_rope_relative_position_invariance():
    """RoPE: <q_i, k_j> depends only on i-j."""
    d = 32
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, d))
    def dot_at(i, j):
        qi = common.apply_rope(q, jnp.asarray([[i]]), 1e4)
        kj = common.apply_rope(k, jnp.asarray([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-3)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), abs=1e-4)


def test_mrope_equals_rope_when_streams_equal():
    """With t==h==w positions, M-RoPE must reduce to 1-D RoPE."""
    d = 32
    x = jax.random.normal(KEY, (2, 8, 3, d))
    pos1 = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos1[None], (3, 2, 8))
    a = common.apply_rope(x, pos1, 1e4)
    b = common.apply_mrope(x, pos3, (4, 6, 6), 1e4)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------- moe
def test_moe_dispatch_slots_unique_and_capacity():
    idx = jnp.asarray([[0, 0, 0, 1, 1, 2, 3, 3]])
    slot = blocks._dispatch_indices(idx, n_experts=4, capacity=2)
    slots = np.asarray(slot)[0]
    kept = slots[slots < 8]
    assert len(set(kept.tolist())) == len(kept)          # unique slots
    assert (slots[:2] == [0, 1]).all()                   # first two of e0 kept
    assert slots[2] == 8                                 # third dropped


def test_moe_fully_routes_with_high_capacity():
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      period=(("attn", "moe"),), n_experts=4, top_k=2,
                      capacity_factor=8.0, param_dtype="float32",
                      compute_dtype="float32")
    p = blocks.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 32))
    rt = Runtime(moe_groups=1)
    out, aux = blocks.moe_apply(p, x, cfg, rt)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux))
    # top-2 output == weighted sum of the two chosen experts, computed densely
    h = common.rmsnorm(x, p["norm"].value)
    logits = jnp.einsum("bsd,de->bse", h, p["router"].value)
    gates = jax.nn.softmax(logits, -1)
    w, e = jax.lax.top_k(gates, 2)
    w = w / w.sum(-1, keepdims=True)
    def expert(i, xin):
        g = jax.nn.silu(xin @ p["wg"].value[i]) * (xin @ p["wu"].value[i])
        return g @ p["wd"].value[i]
    dense = jnp.stack([expert(i, h) for i in range(4)], axis=2)  # [B,S,E,D]
    want = jnp.einsum("bsk,bskd->bsd", w,
                      jnp.take_along_axis(dense, e[..., None], axis=2))
    np.testing.assert_allclose(np.asarray(out - x), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------- ssm
def test_mamba_chunked_equals_reference_scan():
    from repro.kernels import ref as kref
    b, s, di, n = 2, 32, 8, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.5
    dt_raw = jax.random.normal(ks[1], (b, s, di)) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cc = jax.random.normal(ks[4], (b, s, n)) * 0.5
    dt = jax.nn.softplus(dt_raw)
    da = jnp.exp(dt[..., None] * a[None, None])
    y, hf = ssm._chunk_scan(dt, a, bb, cc, x, chunk=8)
    # sequential oracle
    want, href = kref.ref_selective_scan(x, dt_raw, a, bb, cc,
                                         jnp.zeros((di,)))
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hf, href, atol=1e-4, rtol=1e-4)


def test_mamba_train_decode_state_consistency():
    cfg = ModelConfig(name="m", family="hybrid", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      period=(("mamba", "none"),), ssm_state=4, ssm_conv=4,
                      ssm_expand=2, param_dtype="float32", compute_dtype="float32")
    p = ssm.init_mamba(KEY, cfg)
    rt = Runtime(mamba_chunk=4)
    x = jax.random.normal(KEY, (1, 12, 16)) * 0.5
    y_full, cache = ssm.mamba_train(p, x, cfg, rt)
    # replay last token with decode from the cache of the first 11
    y_pre, cache_pre = ssm.mamba_train(p, x[:, :11], cfg, rt)
    y_dec, _ = ssm.mamba_decode(p, x[:, 11:12], cache_pre, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 11]), atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------- xlstm
def test_mlstm_chunked_equals_recurrent():
    """Chunked training path vs the exact stabilized decode recurrence."""
    b, s, nh, dh = 1, 16, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, nh, dh)) * 0.3
    k = jax.random.normal(ks[1], (b, s, nh, dh)) * 0.3
    v = jax.random.normal(ks[2], (b, s, nh, dh)) * 0.5
    ig = jax.random.normal(ks[3], (b, s, nh)) * 0.5 - 1.0
    fg = jax.random.normal(ks[4], (b, s, nh)) * 0.5 + 2.0
    h_chunk, _ = xlstm._mlstm_chunked(q, k, v, ig, fg, chunk=4)
    # recurrent oracle (unstabilized, f32, same normalizer)
    logf = jax.nn.log_sigmoid(fg)
    c = jnp.zeros((b, nh, dh, dh))
    n = jnp.zeros((b, nh, dh))
    outs = []
    scale = dh ** -0.5
    for t in range(s):
        f_t = jnp.exp(logf[:, t])[..., None]
        i_t = jnp.exp(ig[:, t])[..., None]
        c = f_t[..., None] * c + i_t[..., None] * k[:, t][..., None] * v[:, t][..., None, :]
        n = f_t * n + i_t * k[:, t]
        num = jnp.einsum("bhd,bhde->bhe", q[:, t] * scale, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, t] * scale, n)), 1.0)
        outs.append(num / den[..., None])
    want = jnp.stack(outs, 1).reshape(b, s, nh * dh)
    np.testing.assert_allclose(h_chunk, want, atol=1e-4, rtol=1e-4)


def test_slstm_decode_matches_train():
    cfg = ModelConfig(name="x", family="ssm", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                      period=(("slstm", "none"),), param_dtype="float32",
                      compute_dtype="float32")
    p = xlstm.init_slstm(KEY, cfg)
    rt = Runtime()
    x = jax.random.normal(KEY, (2, 9, 16)) * 0.5
    y_full, _ = xlstm.slstm_train(p, x, cfg, rt)
    _, cache = xlstm.slstm_train(p, x[:, :8], cfg, rt)
    y_dec, _ = xlstm.slstm_decode(p, x[:, 8:9], cache, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 8]),
                               atol=1e-5, rtol=1e-5)
