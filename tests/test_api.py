"""repro.api: plan dedupe, cache-hit skip, resume, structured failures, MAD."""
import pytest

from repro.api import Plan, Probe, Session
from repro.api.probes import InstructionProbe
from repro.core import chains, measure
from repro.core.latency_db import LatencyDB
from repro.core.timing import Measurement, Timer


class CountingProbe(Probe):
    """Deterministic fake probe: counts runs, optionally raises."""

    category = "test"

    def __init__(self, op, error=None, runs=None):
        self.op = op
        self.opt_level = "O3"
        self.dtype = "float32"
        self.error = error
        self.runs = runs if runs is not None else {}

    def run(self, ctx):
        self.runs[self.op] = self.runs.get(self.op, 0) + 1
        if self.error is not None:
            raise self.error
        return self._record(ctx, Measurement(10.0, 1.5, 9.0, 4))


def _session(path=None):
    return Session(db=str(path) if path else None, timer=Timer(warmup=0, reps=2))


# ------------------------------------------------------------------- plans
def test_plan_dedupe_and_add():
    p = Plan.instructions(ops=("add", "mul"), opt_levels=("O0", "O3"))
    assert len(p) == 4
    # same cross-product again: union is unchanged
    assert len(p + Plan.instructions(ops=("add", "mul"), opt_levels=("O0", "O3"))) == 4
    # duplicated probes inside one plan collapse too
    dup = Plan(tuple(p.probes) * 3).dedupe()
    assert [q.logical_key() for q in dup] == [q.logical_key() for q in p]


def test_plan_filter():
    p = Plan.instructions(ops=("add", "mul", "sqrt"), opt_levels=("O0", "O3"))
    assert {q.op for q in p.filter(ops=["add"])} == {"add"}
    assert {q.opt_level for q in p.filter(opt_levels=["O3"])} == {"O3"}
    assert len(p.filter(ops=["add"], opt_levels=["O3"])) == 1


def test_plan_cross_product_dtypes_categories():
    full = Plan.instructions(opt_levels=("O3",))
    fp32 = Plan.instructions(opt_levels=("O3",), dtypes=("float32",))
    assert 0 < len(fp32) < len(full)
    assert all(q.dtype == "float32" for q in fp32)
    special = Plan.instructions(opt_levels=("O3",), categories=("special_math",))
    assert {q.category for q in special} == {"special_math"}


def test_probe_identity_includes_measurement_params():
    """Non-default fidelity params are part of the cache key: a short-chase
    quick point must never satisfy a lookup for the standard sweep."""
    from repro.api.probes import KernelProbe, MemoryProbe
    from repro.core import membench
    std, quick = MemoryProbe(8192), MemoryProbe(8192, steps=(512, 1536))
    assert std.op == "mem.chase.ws8192"
    assert std.logical_key() != quick.logical_key()
    assert KernelProbe("fma").op == "kernel.alu_chain.fma"
    assert KernelProbe("fma", lens=(4, 32)).logical_key() != \
        KernelProbe("fma").logical_key()

    # the MemPoint round-trip still parses the working set with a suffix
    result = _session().run(Plan((quick,)))
    pt = membench.mempoint_from_record(result.measured[0].record)
    assert pt.working_set_bytes == 8192


def test_named_plans():
    from repro.api import named_plan
    for name in ("quick", "table2", "memory", "full"):
        plan = named_plan(name)
        assert len(plan) > 0
        keys = [p.logical_key() for p in plan]
        assert len(keys) == len(set(keys))
    with pytest.raises(ValueError):
        named_plan("nope")


# ------------------------------------------------------------------ caching
def test_cache_hit_skips_execution(tmp_path):
    runs = {}
    plan = Plan((CountingProbe("a", runs=runs), CountingProbe("b", runs=runs)))
    db = tmp_path / "db.json"

    first = _session(db).run(plan)
    assert len(first.measured) == 2 and not first.cached
    assert runs == {"a": 1, "b": 1}

    # fresh session, same DB file: zero probes execute
    second = _session(db).run(plan)
    assert len(second.cached) == 2 and not second.measured and not second.failed
    assert runs == {"a": 1, "b": 1}
    assert [r.record.op for r in second.cached] == ["a", "b"]


def test_force_remeasures(tmp_path):
    runs = {}
    plan = Plan((CountingProbe("a", runs=runs),))
    db = tmp_path / "db.json"
    _session(db).run(plan)
    result = _session(db).run(plan, force=True)
    assert len(result.measured) == 1
    assert runs == {"a": 2}


def test_resume_after_interrupt(tmp_path):
    """KeyboardInterrupt mid-plan: completed probes are on disk and resume."""
    runs = {}
    db = tmp_path / "db.json"
    plan = Plan((CountingProbe("a", runs=runs),
                 CountingProbe("b", error=KeyboardInterrupt(), runs=runs),
                 CountingProbe("c", runs=runs)))
    with pytest.raises(KeyboardInterrupt):
        _session(db).run(plan)
    assert runs == {"a": 1, "b": 1}  # c never started

    # re-run with the failure gone: a is a cache hit, only b and c execute
    plan2 = Plan((CountingProbe("a", runs=runs), CountingProbe("b", runs=runs),
                  CountingProbe("c", runs=runs)))
    result = _session(db).run(plan2)
    assert [r.status for r in result.results] == ["cached", "measured", "measured"]
    assert runs == {"a": 1, "b": 2, "c": 1}


# ----------------------------------------------------------------- failures
def test_structured_failure_recorded_and_persisted(tmp_path):
    db_path = tmp_path / "db.json"
    plan = Plan((CountingProbe("ok"), CountingProbe("boom", error=ValueError("bad operand"))))
    result = _session(db_path).run(plan)
    assert len(result.measured) == 1 and len(result.failed) == 1
    failure = result.failed[0].failure
    assert failure.op == "boom"
    assert failure.error_type == "ValueError"
    assert "bad operand" in failure.message
    assert failure.failed_at

    # persisted to disk alongside the records
    reloaded = LatencyDB(str(db_path))
    assert [f.op for f in reloaded.failures()] == ["boom"]
    assert len(reloaded) == 1

    # a later success supersedes the failure
    fixed = _session(db_path).run(Plan((CountingProbe("boom"),)))
    assert len(fixed.measured) == 1
    assert LatencyDB(str(db_path)).failures() == []


def test_failure_does_not_abort_plan():
    runs = {}
    plan = Plan((CountingProbe("x", error=RuntimeError("die"), runs=runs),
                 CountingProbe("y", runs=runs)))
    result = _session().run(plan)
    assert [r.status for r in result.results] == ["failed", "measured"]
    assert runs == {"x": 1, "y": 1}


# ---------------------------------------------------------------------- MAD
def test_instruction_probe_propagates_mad(monkeypatch):
    # disable the prepare split so the pipelined path falls back to run(),
    # which is where measure_op_full (the seam under test) is consulted
    monkeypatch.setattr(measure, "prepare_op", lambda *a, **k: None)
    monkeypatch.setattr(measure, "measure_op_full",
                        lambda spec, lv, timer: Measurement(100.0, 7.5, 90.0, 12))
    spec = next(o for o in chains.default_registry() if o.name == "fma.float32")
    result = _session().run(Plan((InstructionProbe(spec, "O3"),)))
    rec = result.measured[0].record
    assert rec.mad_ns == 7.5
    assert rec.latency_ns == 100.0
    assert rec.n_samples == 12


def test_table_markdown_surfaces_mad():
    from repro.core.latency_db import LatencyRecord
    db = LatencyDB()
    db.add(LatencyRecord(op="add", category="int_arith", dtype="int32",
                         opt_level="O3", latency_ns=5.0, mad_ns=1.25, cycles=5.0,
                         guard=1, net_latency_ns=2.5, device_kind="cpu",
                         backend="cpu", jax_version="x", n_samples=10))
    assert "±1.2" in db.table_markdown()


# ------------------------------------------------------------ integration
def test_session_end_to_end_real_probe(tmp_path):
    """One real instruction probe through the whole stack (fast settings)."""
    spec = next(o for o in chains.default_registry() if o.name == "fma.float32")
    session = Session(db=str(tmp_path / "db.json"), timer=Timer(warmup=1, reps=3))
    result = session.run(Plan((InstructionProbe(spec, "O3"),)))
    assert result.summary().startswith("1 measured")
    rec = result.measured[0].record
    assert rec.latency_ns >= 0.0 and rec.mad_ns >= 0.0
    assert rec.key() in session.db
    # and the cache hit on re-run
    assert len(Session(db=str(tmp_path / "db.json")).run(
        Plan((InstructionProbe(spec, "O3"),))).cached) == 1
