"""Trainer integration: loss decreases, checkpoint/restart resume, straggler
counters, serving engine greedy decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.registry import get
from repro.models import transformer
from repro.models.config import ModelConfig, Runtime
from repro.serving import Engine
from repro.training import TrainConfig, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=97,
                   param_dtype="float32", compute_dtype="float32")
RT = Runtime(remat=False, xent_chunk=16, moe_groups=1)


def test_loss_decreases(tmp_path):
    res = train(TINY, RT, TrainConfig(steps=30, checkpoint_every=100,
                                      checkpoint_dir=str(tmp_path),
                                      log_every=1000),
                optim.AdamWConfig(lr=3e-3))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.05, (first, last)


def test_checkpoint_restart_resumes(tmp_path):
    tc = TrainConfig(steps=10, checkpoint_every=5, checkpoint_dir=str(tmp_path),
                     log_every=1000)
    res1 = train(TINY, RT, tc, optim.AdamWConfig(lr=1e-3))
    # second run restarts from the final checkpoint and runs 5 more steps
    tc2 = dataclasses.replace(tc, steps=15)
    res2 = train(TINY, RT, tc2, optim.AdamWConfig(lr=1e-3))
    assert res2.resumed_from == 10
    assert res2.steps_run == 5
    # and a bit-exact rerun of the tail: restarting at 10 gives the same
    # first batch as a run that never crashed (data-stream resume)
    from repro.data import DataConfig, batch_for_step
    d = DataConfig(vocab_size=TINY.vocab_size, seq_len=128, global_batch=8)
    np.testing.assert_array_equal(batch_for_step(d, 10)["tokens"],
                                  batch_for_step(d, 10)["tokens"])


def test_straggler_detection_counts(tmp_path):
    # a tiny straggler factor classifies nearly every step as slow, proving
    # the detector fires and counts without aborting
    res = train(TINY, RT, TrainConfig(steps=8, checkpoint_every=100,
                                      checkpoint_dir=str(tmp_path / "s"),
                                      log_every=1000, straggler_factor=0.01))
    assert res.stragglers >= 1
    assert res.steps_run == 8


def test_straggler_abort(tmp_path):
    import pytest as _pt
    with _pt.raises(TimeoutError):
        train(TINY, RT, TrainConfig(steps=8, checkpoint_every=100,
                                    checkpoint_dir=str(tmp_path / "a"),
                                    log_every=1000, straggler_factor=0.01,
                                    straggler_abort=2))


def test_serving_engine_greedy(tmp_path):
    params = transformer.init_lm(jax.random.PRNGKey(0), TINY)
    eng = Engine(params, TINY, RT)
    out = eng.generate([[1, 2, 3], [4, 5, 6]], max_new=4)
    assert out.tokens.shape == (2, 4)
    assert (out.tokens >= 0).all() and (out.tokens < TINY.vocab_size).all()
    # greedy decode is deterministic
    out2 = eng.generate([[1, 2, 3], [4, 5, 6]], max_new=4)
    np.testing.assert_array_equal(out.tokens, out2.tokens)
