"""Shared-utility contracts: exact-rank percentiles + record-notes parsing.

These two helpers sit under every SLO number the traffic subsystem reports
(``percentiles``) and every structured record the probes persist
(``parse_kv_notes``), so their edge cases are locked down here rather than
implicitly by their consumers.
"""
import pytest

from repro.utils import parse_kv_notes, percentiles


# ============================================================== percentiles
def test_percentiles_exact_rank_small_n():
    # nearest-rank: ceil(p/100 * n) - 1 into the sorted samples
    xs = [10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    got = percentiles(xs, (50, 90, 99))
    assert got[50] == 50       # ceil(5) = rank 5
    assert got[90] == 90       # ceil(9) = rank 9
    assert got[99] == 100      # ceil(9.9) = rank 10


def test_percentiles_every_value_is_a_sample():
    xs = [3.0, 1.0, 4.0, 1.5]
    got = percentiles(xs, (25, 50, 75, 99))
    assert set(got.values()) <= set(xs)    # never interpolated


def test_percentiles_single_sample():
    got = percentiles([42.0], (0, 50, 99, 100))
    assert all(v == 42.0 for v in got.values())


def test_percentiles_p0_is_min_p100_is_max():
    xs = [5, 9, 2, 7]
    got = percentiles(xs, (0, 100))
    assert got[0] == 2 and got[100] == 9


def test_percentiles_unsorted_input():
    assert percentiles([9, 1, 5], (50,))[50] == 5


def test_percentiles_p99_small_n_is_max_not_invented():
    # with n=4, p99 must be the max sample, not a midpoint average
    assert percentiles([1, 2, 3, 4], (99,))[99] == 4


def test_percentiles_rejects_empty_and_out_of_range():
    with pytest.raises(ValueError):
        percentiles([], (50,))
    with pytest.raises(ValueError):
        percentiles([1.0], (101,))
    with pytest.raises(ValueError):
        percentiles([1.0], (-1,))


# ============================================================ parse_kv_notes
def test_parse_kv_basic():
    assert parse_kv_notes("ws=8192 line=64 space=vmem") == {
        "ws": "8192", "line": "64", "space": "vmem"}


def test_parse_kv_value_containing_equals():
    # only the FIRST '=' splits: rhs keeps embedded '=' verbatim
    # (slo.<rate> notes carry e.g. filter expressions and key=value tails)
    kv = parse_kv_notes("expr=a=b rate=5")
    assert kv == {"expr": "a=b", "rate": "5"}


def test_parse_kv_empty_value_kept():
    kv = parse_kv_notes("model= coverage=0.5")
    assert kv["model"] == "" and kv["coverage"] == "0.5"


def test_parse_kv_duplicate_keys_last_wins():
    assert parse_kv_notes("k=1 k=2 k=3") == {"k": "3"}


def test_parse_kv_ignores_free_text_and_bare_equals():
    # free-text fragments without '=' are skipped; a bare '=' has an empty
    # key and is dropped (empty keys are unaddressable)
    kv = parse_kv_notes("pallas chase = ws=4096 (interpret)")
    assert kv == {"ws": "4096"}


def test_parse_kv_empty_string():
    assert parse_kv_notes("") == {}
