import warnings

try:  # this image has no hypothesis and installs are forbidden; gate on a stub
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    _hypothesis_stub.install()

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    # jax < 0.5 compat: tests pass axis_types=(AxisType.Auto, ...) which this
    # jaxlib predates; Auto was the implicit (only) behavior, so dropping the
    # kwarg preserves semantics.
    import enum
    import functools

    class _AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType
    _orig_make_mesh = jax.make_mesh

    @functools.wraps(_orig_make_mesh)
    def _make_mesh(*args, axis_types=None, **kwargs):
        return _orig_make_mesh(*args, **kwargs)

    jax.make_mesh = _make_mesh

warnings.filterwarnings("ignore")
# NOTE: no XLA_FLAGS here on purpose — smoke tests/benches must see 1 device.
# Multi-device tests spawn subprocesses (tests/_subproc.py).


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
