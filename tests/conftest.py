import warnings

import jax
import pytest

warnings.filterwarnings("ignore")
# NOTE: no XLA_FLAGS here on purpose — smoke tests/benches must see 1 device.
# Multi-device tests spawn subprocesses (tests/_subproc.py).


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
