"""Data pipeline: determinism, host-shard disjointness, exact resume."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, SyntheticLoader, batch_for_step


@given(st.integers(0, 100), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_deterministic(step, seed):
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=seed)
    a = batch_for_step(cfg, step)
    b = batch_for_step(cfg, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4)
    b = batch_for_step(cfg, 0)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    # consecutive windows share the stream: label[t] == token[t+1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_shards_differ():
    base = dict(vocab_size=101, seq_len=8, global_batch=8, n_hosts=4)
    batches = [batch_for_step(DataConfig(host_id=h, **base), 5) for h in range(4)]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i]["tokens"], batches[j]["tokens"])


def test_loader_resume_exact():
    cfg = DataConfig(vocab_size=101, seq_len=8, global_batch=2)
    l1 = SyntheticLoader(cfg, start_step=0)
    seq1 = [next(l1) for _ in range(5)]
    l1.close()
    l2 = SyntheticLoader(cfg, start_step=3)
    resumed = next(l2)
    l2.close()
    np.testing.assert_array_equal(seq1[3]["tokens"], resumed["tokens"])


def test_steps_differ():
    cfg = DataConfig(vocab_size=101, seq_len=8, global_batch=2)
    a = batch_for_step(cfg, 0)
    b = batch_for_step(cfg, 1)
    assert not np.array_equal(a["tokens"], b["tokens"])
