"""Per-architecture smoke tests (mandated): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs.
Also: prefill+decode == full forward (f32, greedy logits)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_arch_ids, get
from repro.models import common, encdec, transformer
from repro.models.config import ModelConfig, Runtime

RT = Runtime(moe_groups=2, mamba_chunk=8, mlstm_chunk=8, xent_chunk=16,
             remat=False)
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _inputs(cfg: ModelConfig):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_encoder_layers:
        batch["frames"] = jax.random.normal(KEY, (B, S // 4, cfg.d_model))
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_train_step(arch):
    cfg = get(arch).smoke
    batch = _inputs(cfg)
    if cfg.n_encoder_layers:
        params = encdec.init_encdec(KEY, cfg)
        loss, metrics = jax.jit(
            lambda p, b: encdec.train_loss(p, b, cfg, RT))(params, batch)
    else:
        params = transformer.init_lm(KEY, cfg)
        loss, metrics = jax.jit(
            lambda p, b: transformer.train_loss(p, b, cfg, RT))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_forward_shapes(arch):
    cfg = get(arch).smoke
    batch = _inputs(cfg)
    if cfg.n_encoder_layers:
        params = encdec.init_encdec(KEY, cfg)
        mem = encdec.encode(params, cfg, RT, batch["frames"])
        assert mem.shape == (B, S // 4, cfg.d_model)
        h, _ = encdec.decode_train(params, cfg, RT, mem, batch["tokens"])
    else:
        params = transformer.init_lm(KEY, cfg)
        h, _, _ = transformer.forward(params, cfg, RT, tokens=batch["tokens"],
                                      positions=batch.get("positions"))
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b",
                                  "xlstm-350m", "llama4-scout-17b-a16e",
                                  "qwen2-vl-2b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy serving equivalence at f32 (bf16 archs cast up for the check)."""
    import dataclasses
    cfg = dataclasses.replace(get(arch).smoke, param_dtype="float32",
                              compute_dtype="float32", capacity_factor=8.0)
    params = transformer.init_lm(KEY, cfg)
    s = 33
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    pos = None
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, B, s)).astype(jnp.int32)
    h, _, _ = transformer.forward(params, cfg, RT, tokens=tokens, positions=pos)
    gold = common.top1_logits(h[:, -1], (params.get("lm_head") or params["embed"]).value)
    _, caches = transformer.prefill(
        params, cfg, RT, tokens=tokens[:, :-1],
        positions=None if pos is None else pos[:, :, :-1])
    caches = transformer.pad_cache(caches, cfg, s)
    dpos = None if pos is None else pos[:, :, -1:]
    logits, _ = transformer.decode_step(params, caches, tokens[:, -1:], s - 1,
                                        cfg, RT, positions=dpos)
    np.testing.assert_allclose(np.asarray(gold), np.asarray(logits),
                               atol=2e-4, rtol=2e-4)


def test_param_count_analytic_close_to_actual():
    """cfg.param_count() (used for 6ND) within 6%% of the real tree."""
    from repro.utils import tree_params
    for arch in ("granite-3-8b", "xlstm-350m"):
        cfg = get(arch).smoke
        params = transformer.init_lm(KEY, cfg)
        actual = tree_params(params)
        analytic = cfg.param_count()[0]
        assert abs(actual - analytic) / actual < 0.06, (arch, actual, analytic)
