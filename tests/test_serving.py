"""Serving path: Engine batching behavior + ServingCostProbe characterization.

Engine tests lock the static-batch semantics down (ragged right-padding with
per-row last-token sampling, finished-rows-keep-decoding waste-slot masking,
seeded sampling determinism); probe tests run the predicted-vs-measured cells
through the Session machinery (caching, resume, table, CLI).
"""
import json

import jax
import numpy as np
import pytest

from repro.api import Plan, ServingCostProbe, Session, serving_tiny_config
from repro.api import cli
from repro.core import perfmodel
from repro.core.latency_db import LatencyDB
from repro.models import transformer
from repro.serving import Engine

CFG, RT = serving_tiny_config()


@pytest.fixture(scope="module")
def engine():
    params = transformer.init_lm(jax.random.PRNGKey(0), CFG)
    return Engine(params, CFG, RT)


# ================================================================== engine
def test_ragged_prompts_right_padded_first_token_exact(engine):
    """A short row in a ragged batch must sample its first token from its own
    last prompt token (causal attention makes the padded tail invisible to
    it), i.e. match the same prompt run alone."""
    long, short = [5, 6, 7, 8, 9, 10], [11, 12]
    batched = engine.generate([long, short], max_new=1)
    alone_short = engine.generate([short], max_new=1)
    alone_long = engine.generate([long], max_new=1)
    assert batched.tokens[1, 0] == alone_short.tokens[0, 0]
    assert batched.tokens[0, 0] == alone_long.tokens[0, 0]
    np.testing.assert_array_equal(batched.prompt_lens, [6, 2])


def test_waste_slot_masking(engine):
    """Once a row emits eos it keeps decoding (static batch), but everything
    after its eos is masked out of the result."""
    free = engine.generate([[1, 2, 3], [4, 5, 6]], max_new=6)
    eos = int(free.tokens[0, 1])        # a token row 0 actually emits
    r = engine.generate([[1, 2, 3], [4, 5, 6]], max_new=6, eos_id=eos)
    assert r.finished_steps is not None
    s0 = r.finished_steps[0]
    assert 0 <= s0 <= 1                 # row 0 finished at (or before) step 1
    assert int(r.tokens[0, s0]) == eos
    assert (r.tokens[0, s0 + 1:] == eos).all()      # waste slots masked
    # unfinished rows are untouched up to the steps actually run
    if r.finished_steps[1] < 0:
        np.testing.assert_array_equal(r.tokens[1, :r.steps],
                                      free.tokens[1, :r.steps])


def test_all_rows_finished_stops_early(engine):
    free = engine.generate([[1, 2, 3]], max_new=8)
    eos = int(free.tokens[0, 0])        # first emitted token ends the row
    r = engine.generate([[1, 2, 3]], max_new=8, eos_id=eos)
    assert r.finished_steps[0] == 0
    assert r.steps < 8                  # no point burning 7 waste steps
    assert (r.tokens[0, 1:] == eos).all()


def test_no_eos_keeps_legacy_shape(engine):
    r = engine.generate([[1, 2, 3], [4, 5]], max_new=4)
    assert r.tokens.shape == (2, 4)
    assert r.steps == 4
    assert r.finished_steps is None


def test_temperature_sampling_seed_determinism(engine):
    a = engine.generate([[1, 2, 3]], max_new=6, temperature=0.8, seed=7)
    b = engine.generate([[1, 2, 3]], max_new=6, temperature=0.8, seed=7)
    np.testing.assert_array_equal(a.tokens, b.tokens)   # same seed, same draw
    others = [engine.generate([[1, 2, 3]], max_new=6, temperature=0.8, seed=s)
              for s in range(1, 5)]
    assert any((o.tokens != a.tokens).any() for o in others), \
        "4 different seeds all reproduced seed 7's sample"


def test_greedy_ignores_seed(engine):
    a = engine.generate([[1, 2, 3]], max_new=4, temperature=0.0, seed=0)
    b = engine.generate([[1, 2, 3]], max_new=4, temperature=0.0, seed=123)
    np.testing.assert_array_equal(a.tokens, b.tokens)


# ============================================================ lowering hooks
def test_lower_decode_is_not_donating(engine):
    lowered, args = engine.lower_decode(1, 8)
    compiled = lowered.compile()
    compiled(*args)
    compiled(*args)                     # donated cache would fail here
    assert '"known_trip_count"' in compiled.as_text()


# ================================================================== probe
def _run_cell(db_path, phase="prefill", batch=1, prompt=8, **kw):
    session = Session(db=str(db_path))
    plan = Plan((ServingCostProbe(phase, batch, prompt, reps=2, **kw),),
                name="cell")
    return session, session.run(plan)


def test_probe_records_predicted_and_measured(tmp_path):
    session, result = _run_cell(tmp_path / "db.json")
    assert result.summary().startswith("1 measured")
    (rec,) = result.records()
    assert rec.op == "serving.prefill.b1p8"
    assert rec.category == "serving" and rec.opt_level == "O3"
    pt = perfmodel.servingpoint_from_record(rec)
    assert pt.phase == "prefill" and pt.batch == 1 and pt.prompt_len == 8
    assert pt.predicted_ns > 0 and pt.measured_ns > 0
    assert 0.0 <= pt.coverage <= 1.0
    assert pt.model == CFG.name


def test_probe_decode_cell_and_cache_resume(tmp_path):
    db = tmp_path / "db.json"
    _, first = _run_cell(db, phase="decode", prompt=8)
    assert first.summary().startswith("1 measured")
    _, again = _run_cell(db, phase="decode", prompt=8)
    assert again.summary().startswith("0 measured, 1 cached")


def test_probe_prices_from_measured_rows(tmp_path):
    """With the dep rows in the DB, the cell's coverage must beat an empty
    DB's 0.0 — the plan-order contract of Plan.serving(with_deps=True)."""
    db = tmp_path / "db.json"
    session = Session(db=str(db))
    plan = (Plan.instructions(ops=("add", "mul", "fma.float32", "add.float32",
                                   "mul.float32", "sub.float32", "max.float32",
                                   "rsqrt", "tanh"),
                              opt_levels=("O3",))
            + Plan((ServingCostProbe("decode", 1, 8, reps=1),), name="cell"))
    result = session.run(plan)
    assert not result.failed
    rec = next(r.record for r in result.results
               if r.record is not None and r.record.op.startswith("serving."))
    assert perfmodel.servingpoint_from_record(rec).coverage > 0.0


def test_nondefault_model_is_a_different_cache_identity():
    import dataclasses

    other = dataclasses.replace(CFG, name="other-model")
    a = ServingCostProbe("prefill", 1, 8)
    b = ServingCostProbe("prefill", 1, 8, cfg=other, rt=RT)
    assert a.op == "serving.prefill.b1p8"
    assert b.op == "serving.prefill.b1p8.other-model"
    assert a.logical_key() != b.logical_key()
    # a non-default decode cache size is a different HLO -> different identity
    c = ServingCostProbe("decode", 1, 8, max_len=4096)
    assert c.op == "serving.decode.b1p8.c4096"
    assert c.logical_key() != ServingCostProbe("decode", 1, 8).logical_key()


def test_match_names_families():
    p = ServingCostProbe("decode", 2, 64)
    assert {"serving", "serving.decode", "serving.decode.b2p64"} \
        <= p.match_names()
    plan = Plan.serving(with_deps=False)
    assert len(plan.filter(ops=["serving"])) == len(plan)
    decode_only = plan.filter(ops=["serving.decode"])
    assert len(decode_only) == len(plan) // 2
    assert all(p.phase == "decode" for p in decode_only)


def test_plan_serving_deps_feed_the_estimator_ladder():
    """Regression: the plan's memory dep rungs must be rows the estimator's
    memory_ladder() actually reads — a fidelity-suffixed rung (quick's
    512-1536 steps) is excluded as a different experiment, and a ladder the
    estimator can't read silently prices every module's memory term at 0."""
    mem_ops = [p.op for p in Plan.serving()
               if type(p).__name__ == "MemoryProbe"]
    assert mem_ops, "serving plan lost its memory deps"
    for op in mem_ops:
        assert perfmodel._MEM_ROW_RE.match(op), \
            f"dep rung {op!r} is invisible to memory_ladder()"


def test_plan_serving_dep_ordering():
    """Dependencies (instruction + memory rows) come before the serving
    cells — plan order is Session execution order."""
    plan = Plan.serving()
    kinds = [type(p).__name__ for p in plan]
    first_serving = kinds.index("ServingCostProbe")
    assert "InstructionProbe" in kinds[:first_serving]
    assert "MemoryProbe" in kinds[:first_serving]
    assert all(k == "ServingCostProbe" for k in kinds[first_serving:])


def test_full_plan_contains_serving_cells():
    from repro.api import named_plan

    ops = {p.op for p in named_plan("full")}
    assert "serving.prefill.b1p16" in ops
    assert "serving.decode.b2p64" in ops


def test_bad_phase_rejected():
    with pytest.raises(ValueError, match="phase"):
        ServingCostProbe("train", 1, 8)


# ================================================================== table
def test_serving_markdown_table(tmp_path):
    session, _ = _run_cell(tmp_path / "db.json")
    md = session.db.compare_markdown(prefix="serving.")
    lines = md.splitlines()
    assert lines[0].startswith("| cell | phase | batch | prompt |")
    assert any("serving.prefill.b1p8" in l for l in lines[2:])
    assert "| prefill | 1 | 8 |" in md
    # the inkernel pairing stays untouched by serving rows
    assert "serving" not in session.db.compare_markdown()


def test_serving_table_orders_cells_numerically(tmp_path):
    db = LatencyDB()
    for prompt in (16, 128, 4):
        import tests.test_perfmodel as tp

        db.add(tp._rec(f"serving.decode.b1p{prompt}", 100.0, cat="serving",
                       notes=f"phase=decode batch=1 prompt={prompt} "
                             f"predicted_ns=50.0 coverage=1.0"))
    md = db.compare_markdown(prefix="serving.")
    rows = [l for l in md.splitlines() if "serving.decode" in l]
    assert [r.split("|")[1].strip() for r in rows] == [
        "serving.decode.b1p4", "serving.decode.b1p16",
        "serving.decode.b1p128"]


# ============================================================ tolerance gate
def _point(phase="prefill", batch=1, prompt=16, pred=100.0, meas=1000.0,
           cov=0.9):
    return perfmodel.ServingPoint(phase=phase, batch=batch, prompt_len=prompt,
                                  measured_ns=meas, predicted_ns=pred,
                                  compute_ns=pred, memory_ns=0.0,
                                  coverage=cov)


def test_check_points_tolerance_logic():
    from benchmarks.check_serving import check_points

    tol = {"max_abs_log10_ratio": 2.0, "min_coverage": 0.5}
    # 1 decade under, coverage fine -> clean
    assert check_points([_point()], tol) == []
    # 3 decades off -> error violation
    v = check_points([_point(pred=1.0, meas=1000.0)], tol)
    assert len(v) == 1 and "|log10(pred/meas)|" in v[0]
    # degenerate zero prediction -> inf error, still caught
    assert check_points([_point(pred=0.0)], tol)
    # low coverage -> coverage violation
    v = check_points([_point(cov=0.1)], tol)
    assert len(v) == 1 and "coverage" in v[0]


def test_check_serving_main(tmp_path, capsys):
    from benchmarks import check_serving
    import tests.test_perfmodel as tp

    db = LatencyDB(path=str(tmp_path / "db.json"))
    db.add(tp._rec("serving.decode.b1p16", 1000.0, cat="serving",
                   notes="phase=decode batch=1 prompt=16 predicted_ns=500.0 "
                         "coverage=0.9"))
    db.save()
    tol = tmp_path / "tol.json"
    tol.write_text(json.dumps({"max_abs_log10_ratio": 1.0,
                               "min_coverage": 0.5}))
    assert check_serving.main(["--db", db.path, "--tolerance", str(tol)]) == 0
    out = capsys.readouterr().out
    assert "within tolerance" in out
    # tighten the band below the cell's 0.3 decades -> violation
    tol.write_text(json.dumps({"max_abs_log10_ratio": 0.1,
                               "min_coverage": 0.5}))
    assert check_serving.main(["--db", db.path, "--tolerance", str(tol)]) == 1
    # a DB with no serving rows is a usage error, not a silent pass
    empty = LatencyDB(path=str(tmp_path / "empty.json"))
    empty.add(tp._rec("add", 1.0))
    empty.save()
    assert check_serving.main(["--db", empty.path,
                               "--tolerance", str(tol)]) == 2


# ==================================================================== CLI
def test_cli_serving_plan_smoke(tmp_path, capsys):
    db = tmp_path / "db.json"
    args = ["characterize", "--plan", "serving",
            "--ops", "serving.prefill.b1p16,add,fma.float32",
            "--reps", "1", "--warmup", "0", "--db", str(db)]
    rc = cli.main(args + ["--table"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "3 measured, 0 cached, 0 failed" in out
    assert "== serving predicted vs measured" in out
    assert "serving.prefill.b1p16" in out
    blob = json.loads(db.read_text())
    ops = {r["op"] for r in blob["records"]}
    assert ops == {"add", "fma.float32", "serving.prefill.b1p16"}

    rc = cli.main(args)
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 measured, 3 cached, 0 failed" in out


# ===================================================== lower_decode default
def test_lower_decode_cache_defaults_to_engine_max_len():
    """Regression: the default cache size used to be ``prompt_len + 32``,
    which priced a smaller KV scan than the serving loop actually decodes
    against. It must be the engine's configured capacity."""
    params = transformer.init_lm(jax.random.PRNGKey(0), CFG)
    eng = Engine(params, CFG, RT, max_len=48)
    _, args = eng.lower_decode(1, 8)
    cache_lens = {a.shape[2] for a in jax.tree_util.tree_leaves(args[1])}
    assert cache_lens == {48}
    _, args = eng.lower_decode(1, 8, 40)        # explicit override still wins
    assert {a.shape[2] for a in jax.tree_util.tree_leaves(args[1])} == {40}


def test_decode_cell_notes_priced_cache_size(tmp_path):
    """A decode cell's record must say which cache size it priced (the KV
    scan length dominates the cost); prefill cells record cache=0."""
    from repro.utils import parse_kv_notes

    _, result = _run_cell(tmp_path / "db.json", phase="decode", prompt=8)
    (rec,) = result.records()
    assert parse_kv_notes(rec.notes)["cache"] == "512"    # Engine default
    _, result = _run_cell(tmp_path / "db2.json", phase="prefill", prompt=8)
    (rec,) = result.records()
    assert parse_kv_notes(rec.notes)["cache"] == "0"


# ============================================================ slot-level API
@pytest.fixture(scope="module")
def pool_engine():
    params = transformer.init_lm(jax.random.PRNGKey(0), CFG)
    return Engine(params, CFG, RT, max_len=32)


def test_slot_pool_matches_static_generate(pool_engine):
    """Two concurrently admitted slots must each reproduce their prompt's
    solo static-generate output exactly — per-slot cache-row isolation and
    per-slot positions leave no cross-talk."""
    from repro.serving import SlotPool

    pool = pool_engine.slots(2)
    assert isinstance(pool, SlotPool)
    p0, p1 = [5, 6, 7, 8], [11, 12]
    toks0, toks1 = [pool.admit(0, p0, max_new=4)], []
    toks1.append(pool.admit(1, p1, max_new=4))
    for _ in range(3):
        out = pool.step()
        toks0.append(int(out[0]))
        toks1.append(int(out[1]))
    np.testing.assert_array_equal(
        toks0, pool_engine.generate([p0], max_new=4).tokens[0])
    np.testing.assert_array_equal(
        toks1, pool_engine.generate([p1], max_new=4).tokens[0])


def test_slot_pool_recycled_slot_matches_solo_run(pool_engine):
    """evict + admit mid-flight: the recycled slot's new request must decode
    exactly as if it ran alone (stale KV from the previous tenant is masked
    and overwritten), while the other slot keeps its own stream."""
    pool = pool_engine.slots(2)
    pool.admit(0, [5, 6, 7], max_new=2)
    keep = [pool.admit(1, [9, 10, 11, 12], max_new=6)]
    keep.append(int(pool.step()[1]))
    pool.evict(0)
    assert pool.free_slots() == [0] and pool.active_slots() == [1]
    fresh = [pool.admit(0, [21, 22, 23], max_new=3)]
    for _ in range(2):
        out = pool.step()
        fresh.append(int(out[0]))
        keep.append(int(out[1]))
    np.testing.assert_array_equal(
        fresh, pool_engine.generate([[21, 22, 23]], max_new=3).tokens[0])
    np.testing.assert_array_equal(
        keep, pool_engine.generate([[9, 10, 11, 12]], max_new=4).tokens[0])


def test_slot_pool_admit_validation(pool_engine):
    pool = pool_engine.slots(1)
    pool.admit(0, [1, 2], max_new=2)
    with pytest.raises(ValueError, match="occupied"):
        pool.admit(0, [3, 4])
    pool.evict(0)
    with pytest.raises(ValueError, match="empty prompt"):
        pool.admit(0, [])
    with pytest.raises(ValueError, match="max_len"):
        pool.admit(0, [1] * 30, max_new=8)
    with pytest.raises(ValueError, match="no active slot"):
        pool.step()


def test_slot_pool_sampling_is_slot_independent(pool_engine):
    """temperature>0 streams key on (seed, uid, n_generated), so a request
    samples the same path whichever slot it lands in."""
    out = {}
    for slot in (0, 1):
        pool = pool_engine.slots(2, max_len=16)
        pool.temperature, pool.seed = 0.8, 7
        toks = [pool.admit(slot, [3, 4, 5], uid=42, max_new=4)]
        for _ in range(3):
            toks.append(int(pool.step()[slot]))
        out[slot] = toks
    assert out[0] == out[1]
