"""``repro.api`` — the single entry point for all characterization.

The paper's tool is one pipeline: sweep every instruction and memory level,
subtract the clock overhead, publish one table per device. This package is
that pipeline as an API:

* :class:`Probe` — one measurement with a stable cache identity
  (instruction / memory / clock-overhead / Pallas-kernel implementations).
* :class:`Plan` — a declarative, deduplicated cross-product of probes.
* :class:`Session` — owns the Timer, environment fingerprint and
  LatencyDB-backed cache; executes plans incrementally (cache hits skipped,
  partial results flushed after every probe, errors recorded as structured
  failures). Pin one with ``Session(device=...)``, or shard a plan across
  every local device with :meth:`Session.fan_out` (one pinned session per
  device, per-shard DBs merged — see docs/fanout.md).
* :class:`ResultSet` — per-probe outcomes plus report helpers.

CLI: ``python -m repro characterize --plan
quick|table2|memory|inkernel|memory-inkernel|fused|serving|collectives|
serving-sharded|slo|full [--shard auto|N]`` and ``python -m repro serve-slo --rates 20,50,100``
(predicted-vs-measured serving SLO sweep, docs/traffic.md).
The legacy entry points (``measure.run_suite``, ``measure.clock_overhead``,
``membench.sweep``) are deprecation shims over this package.
"""
from repro.api.plan import (PLAN_NAMES, QUICK_OPS, SERVING_CELLS, SLO_RATES,
                            Plan, named_plan)
from repro.api.probes import (ClockOverheadProbe, CollectiveProbe,
                              FusedKernelProbe, InstructionProbe,
                              KernelChainProbe, KernelProbe, MemoryChaseProbe,
                              MemoryProbe, Probe, ProbeContext,
                              ServingCostProbe, ShardedServingCostProbe,
                              SloProbe, serving_tiny_config)
from repro.api.session import ProbeResult, ResultSet, Session

__all__ = [
    "PLAN_NAMES", "QUICK_OPS", "SERVING_CELLS", "SLO_RATES", "Plan",
    "named_plan",
    "ClockOverheadProbe", "CollectiveProbe", "FusedKernelProbe",
    "InstructionProbe",
    "KernelChainProbe", "KernelProbe", "MemoryChaseProbe", "MemoryProbe",
    "Probe",
    "ProbeContext", "ProbeResult", "ResultSet", "Session",
    "ServingCostProbe", "ShardedServingCostProbe", "SloProbe",
    "serving_tiny_config",
]
