"""Declarative measurement plans: cross-products of probes, with dedupe.

A :class:`Plan` is just an ordered, duplicate-free tuple of probes. Builders
produce the paper's sweeps (instructions x opt levels, the memory-hierarchy
ladder, clock overhead per level), ``+`` composes plans, and ``filter`` trims
them — so "the full paper reproduction" is one Plan expression, and CI's
quick pass is the same expression with a keep-set applied.

Named plans (``quick`` / ``table2`` / ``memory`` / ``inkernel`` /
``memory-inkernel`` / ``fused`` / ``serving`` / ``collectives`` /
``serving-sharded`` / ``slo`` / ``full``) back the
``python -m repro characterize --plan`` CLI.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

from repro.core import chains
from repro.core.chains import OpSpec
from repro.core.optlevels import OPT_LEVELS

from repro.api.probes import (ClockOverheadProbe, CollectiveProbe,
                              FusedKernelProbe, InstructionProbe,
                              KernelChainProbe, KernelProbe, MemoryChaseProbe,
                              MemoryProbe, Probe, ServingCostProbe,
                              ShardedServingCostProbe, SloProbe)

# The CLI/CI keep-set: one representative per interesting latency class,
# including the divisor-taxonomy splits the paper highlights.
QUICK_OPS = ("add", "mul", "mad", "div.s.regular", "div.s.irregular",
             "div.s.runtime", "fma.float32", "div.runtime.float32", "sqrt",
             "rsqrt", "sin", "ex2", "popc", "clz", "add.bfloat16")

PLAN_NAMES = ("quick", "table2", "memory", "inkernel", "memory-inkernel",
              "fused", "serving", "collectives", "serving-sharded", "slo",
              "full")

# Representative (batch, prompt_len) serving cells: a single-sequence short
# prompt and a batched longer one — enough to expose both phases' scaling
# while staying CI-cheap on the tiny default model.
SERVING_CELLS = ((1, 16), (2, 64))

# Default arrival-rate sweep for the SLO plan: below, around and above the
# tiny engine's typical saturation point, so the throughput-vs-latency curve
# has a flat region and a queueing knee.
SLO_RATES = (20.0, 50.0, 100.0)


@dataclasses.dataclass(frozen=True)
class Plan:
    probes: tuple[Probe, ...] = ()
    name: str = "plan"

    # ------------------------------------------------------------- algebra
    def __add__(self, other: "Plan") -> "Plan":
        return Plan(_dedupe(self.probes + other.probes),
                    name=_compose_name(self.name, other.name))

    def __len__(self) -> int:
        return len(self.probes)

    def __iter__(self) -> Iterator[Probe]:
        return iter(self.probes)

    def dedupe(self) -> "Plan":
        return dataclasses.replace(self, probes=_dedupe(self.probes))

    def filter(self, ops: Iterable[str] | None = None,
               opt_levels: Iterable[str] | None = None,
               categories: Iterable[str] | None = None) -> "Plan":
        """Keep only probes matching every given axis (None = keep all).

        The op axis matches any of a probe's :meth:`Probe.match_names` — the
        full derived name *or* its base row — so ``ops=["add"]`` keeps an
        inkernel plan's ``inkernel.add`` and ``ops=["mem.chase.ws8192"]``
        keeps the fidelity-suffixed ``mem.chase.ws8192.s512-1536``.
        """
        ops = set(ops) if ops is not None else None
        opt_levels = set(opt_levels) if opt_levels is not None else None
        categories = set(categories) if categories is not None else None
        kept = tuple(
            p for p in self.probes
            if (ops is None or not ops.isdisjoint(p.match_names()))
            and (opt_levels is None or p.opt_level in opt_levels)
            and (categories is None or p.category in categories))
        return dataclasses.replace(self, probes=kept)

    def shard(self, n: int) -> "list[Plan]":
        """Partition into ``n`` balanced sub-plans for multi-device fan-out.

        Probes are dealt round-robin (``probes[i::n]``) so expensive probe
        families — the long memory ladder, the O0 rows — spread across shards
        instead of landing on one device. The shards are disjoint, cover the
        deduped plan exactly, and keep the parent's relative order; running
        them all equals running the plan serially (same record set). Empty
        shards are returned when ``n > len(plan)`` so the caller's zip with
        a device list stays aligned.
        """
        if n < 1:
            raise ValueError(f"shard count must be >= 1, got {n}")
        probes = _dedupe(self.probes)
        return [Plan(probes[i::n], name=f"{self.name}[shard {i + 1}/{n}]")
                for i in range(n)]

    # ------------------------------------------------------------ builders
    @staticmethod
    def instructions(registry: Sequence[OpSpec] | None = None,
                     opt_levels: Sequence[str] = ("O0", "O3"),
                     ops: Iterable[str] | None = None,
                     dtypes: Iterable[str] | None = None,
                     categories: Iterable[str] | None = None) -> "Plan":
        """Registry x opt-level cross-product (paper Table II)."""
        registry = list(registry if registry is not None
                        else chains.default_registry())
        if ops is not None:
            keep = set(ops)
            registry = [o for o in registry if o.name in keep]
        if dtypes is not None:
            keep = set(dtypes)
            registry = [o for o in registry if o.dtype in keep]
        if categories is not None:
            keep = set(categories)
            registry = [o for o in registry if o.category in keep]
        probes = tuple(InstructionProbe(spec, lv)
                       for spec in registry for lv in opt_levels)
        return Plan(_dedupe(probes), name="instructions")

    @staticmethod
    def clock_overhead(opt_levels: Sequence[str] = OPT_LEVELS) -> "Plan":
        return Plan(tuple(ClockOverheadProbe(lv) for lv in opt_levels),
                    name="clock_overhead")

    @staticmethod
    def memory(working_sets: Sequence[int] | None = None,
               steps: tuple[int, int] = (2048, 6144)) -> "Plan":
        """Pointer-chase ladder over working-set sizes (paper Fig. 6)."""
        if working_sets is None:
            working_sets = [1 << k for k in range(12, 26)]  # 4 KiB .. 32 MiB
        return Plan(tuple(MemoryProbe(ws, steps=steps) for ws in working_sets),
                    name="memory")

    @staticmethod
    def kernels(kernel_ops: Sequence[str] = ("fma",),
                lens: tuple[int, int] = (8, 64)) -> "Plan":
        return Plan(tuple(KernelProbe(op, lens=lens) for op in kernel_ops),
                    name="kernels")

    @staticmethod
    def memory_inkernel(working_sets: Sequence[int] | None = None,
                        lens: tuple[int, int] | None = None,
                        host_pair: bool = True,
                        host_steps: tuple[int, int] = (2048, 6144)) -> "Plan":
        """In-kernel chase ladder over working-set sizes spanning the
        VMEM/HBM boundary (paper Table IV below it, Fig. 6 above it), paired
        by default with the host-level chase at the same sizes so one run
        fills both sides of the host-vs-in-kernel comparison table.

        The default ladder brackets ``kernels.chase.VMEM_BUDGET_BYTES``:
        four rungs resident below it, the budget itself, and two rungs above
        that stream with ``memory_space=ANY``.
        """
        if working_sets is None:
            from repro.kernels.chase import VMEM_BUDGET_BYTES as budget

            working_sets = [budget >> 8, budget >> 6, budget >> 4,
                            budget >> 2, budget, budget << 1, budget << 2]
        probes: list[Probe] = [MemoryChaseProbe(ws, lens=lens)
                               for ws in working_sets]
        if host_pair:
            probes += [MemoryProbe(ws, steps=host_steps)
                       for ws in working_sets]
        return Plan(_dedupe(tuple(probes)), name="memory-inkernel")

    @staticmethod
    def serving(cells: Sequence[tuple[int, int]] = SERVING_CELLS,
                phases: Sequence[str] = ("prefill", "decode"),
                cfg=None, rt=None, with_deps: bool = True) -> "Plan":
        """Serving-path characterization: one :class:`ServingCostProbe` per
        ``(batch, prompt_len)`` cell and phase, preceded (by default) by the
        instruction rows and memory rungs the estimator prices against —
        plan order is execution order, so by the time a serving cell runs,
        its pricing inputs are in the DB and the prediction is
        measurement-backed instead of ``default_ns``-backed.
        """
        probes: list[Probe] = []
        if with_deps:
            probes += list(Plan.instructions(ops=QUICK_OPS,
                                             opt_levels=("O3",)))
            # default-fidelity rungs: a step-suffixed row (quick's 512-1536)
            # is a different experiment that memory_ladder() rightly ignores,
            # and a ladder the estimator can't read prices nothing
            probes += list(Plan.memory((1 << 13, 1 << 17, 1 << 21)))
        probes += [ServingCostProbe(phase, b, p, cfg=cfg, rt=rt)
                   for b, p in cells for phase in phases]
        return Plan(_dedupe(tuple(probes)), name="serving")

    @staticmethod
    def collectives(kinds: Sequence[str] | None = None,
                    payloads: Sequence[int] | None = None,
                    devices: int | None = None,
                    lens: tuple[int, int] | None = None) -> "Plan":
        """Collective dependent-chain ladder (paper's chain method on the
        interconnect): one :class:`CollectiveProbe` per ``kind x payload``
        rung over ``devices`` mesh participants. These are the
        ``coll.<kind>.d<N>.<bytes>`` rows the estimator's collective term
        prices sharded HLO from."""
        from repro.parallel import ladders

        kinds = tuple(kinds if kinds is not None else ladders.LADDER_KINDS)
        payloads = tuple(payloads if payloads is not None
                         else ladders.DEFAULT_PAYLOADS)
        return Plan(tuple(CollectiveProbe(k, p, devices=devices, lens=lens)
                          for k in kinds for p in payloads),
                    name="collectives")

    @staticmethod
    def serving_sharded(cells: Sequence[tuple[int, int]] = ((1, 16),),
                        phases: Sequence[str] = ("prefill", "decode"),
                        tp: int | None = None, cfg=None, rt=None,
                        with_deps: bool = True) -> "Plan":
        """Tensor-parallel serving characterization: one
        :class:`ShardedServingCostProbe` per cell and phase under a
        ``tp``-way model mesh, preceded (by default) by the estimator's
        pricing inputs — instruction rows, memory rungs, AND the collective
        ladder at the *same* device count, so the sharded prediction's
        collective term is measurement-backed, never default-priced.
        ``tp=None`` resolves to 2 when the backend has >= 2 devices.
        """
        if tp is None:
            import jax

            tp = 2 if jax.device_count() >= 2 else 1
        probes: list[Probe] = []
        if with_deps:
            probes += list(Plan.instructions(ops=QUICK_OPS,
                                             opt_levels=("O3",)))
            probes += list(Plan.memory((1 << 13, 1 << 17, 1 << 21)))
            if tp > 1:
                probes += list(Plan.collectives(devices=tp))
        probes += [ShardedServingCostProbe(phase, b, p, tp=tp, cfg=cfg, rt=rt)
                   for b, p in cells for phase in phases]
        return Plan(_dedupe(tuple(probes)), name="serving-sharded")

    @staticmethod
    def slo(rates: Sequence[float] = SLO_RATES, n_requests: int = 12,
            n_slots: int = 4, seed: int = 0, cfg=None, rt=None,
            with_deps: bool = True) -> "Plan":
        """Serving-SLO sweep: one :class:`SloProbe` per arrival rate —
        predicted-vs-measured TTFT/TPOT percentiles over the same seeded
        trace — preceded (by default) by the estimator's pricing inputs,
        exactly like :meth:`serving`: plan order is execution order, so each
        SLO point's simulator is measurement-backed.
        """
        probes: list[Probe] = []
        if with_deps:
            probes += list(Plan.instructions(ops=QUICK_OPS,
                                             opt_levels=("O3",)))
            probes += list(Plan.memory((1 << 13, 1 << 17, 1 << 21)))
        probes += [SloProbe(r, n_requests=n_requests, n_slots=n_slots,
                            seed=seed, cfg=cfg, rt=rt) for r in rates]
        return Plan(_dedupe(tuple(probes)), name="slo")

    @staticmethod
    def representative(steps: tuple[int, int] = (512, 1536)) -> "Plan":
        """The 20-probe benchmark plan ``bench_characterize_speed`` times.

        One representative per latency class at O3 (the 15 ``QUICK_OPS``),
        three memory-ladder rungs, the O3 clock-overhead row and one Pallas
        kernel — compile-heavy enough that the pipeline/compile-cache
        speedup is visible, small enough for CI. Kept as a named builder so
        the bench, the invariance tests and the docs all time the *same*
        plan.
        """
        return dataclasses.replace(
            Plan.instructions(ops=QUICK_OPS, opt_levels=("O3",))
            + Plan.memory((1 << 13, 1 << 17, 1 << 21), steps=steps)
            + Plan.clock_overhead(("O3",))
            + Plan.kernels(("fma",)),
            name="representative")

    @staticmethod
    def fused(names: Sequence[str] | None = None,
              lens: tuple[int, int] | None = None) -> "Plan":
        """One :class:`FusedKernelProbe` per in-repo fused Pallas kernel
        (flash_attention / flash_decode / mamba_scan / rmsnorm): the
        ``inkernel.fused.<name>`` rows the estimator prices zoo-model
        custom-calls from (see ``results/model_zoo_cost.md``)."""
        from repro import inkernel as ik

        names = tuple(names if names is not None else ik.FUSED_KERNELS)
        return Plan(tuple(FusedKernelProbe(n, lens=lens) for n in names),
                    name="fused")

    @staticmethod
    def inkernel(registry: Sequence[OpSpec] | None = None,
                 ops: Iterable[str] | None = None,
                 categories: Iterable[str] | None = None,
                 lens: tuple[int, int] | None = None,
                 dispatch_pair: bool = True) -> "Plan":
        """In-kernel Pallas chain per eligible registry spec (paper's
        in-pipeline method), paired by default with the same spec's
        dispatch-level O3 probe so one run fills both sides of the
        dispatch-vs-in-kernel comparison table."""
        from repro import inkernel as ik

        specs = ik.supported_specs(registry, ops=ops, categories=categories)
        probes: list[Probe] = [KernelChainProbe(s, lens=lens) for s in specs]
        if dispatch_pair:
            probes += [InstructionProbe(s, "O3") for s in specs]
        return Plan(_dedupe(tuple(probes)), name="inkernel")


def _compose_name(a: str, b: str, max_parts: int = 3) -> str:
    """Name for ``a + b``: deduped '+'-join, capped so long compositions stay
    readable in log lines and probe labels (``quick+memory+kernels+2more``)
    instead of growing unboundedly with every ``+``."""
    parts: list[str] = []
    overflow = 0  # names already folded into a previous cap's "Nmore" tail
    for part in (*a.split("+"), *b.split("+")):
        if part.endswith("more") and part[:-4].isdigit():
            overflow += int(part[:-4])
        elif part and part not in parts:
            parts.append(part)
    if len(parts) > max_parts:
        overflow += len(parts) - max_parts
        parts = parts[:max_parts]
    if overflow:
        parts.append(f"{overflow}more")
    return "+".join(parts) or "plan"


def _dedupe(probes: Sequence[Probe]) -> tuple[Probe, ...]:
    seen: set[tuple] = set()
    out: list[Probe] = []
    for p in probes:
        k = p.logical_key()
        if k in seen:
            continue
        seen.add(k)
        out.append(p)
    return tuple(out)


def named_plan(name: str) -> Plan:
    """The CLI's plan registry.
    quick | table2 | memory | inkernel | memory-inkernel | fused | serving |
    collectives | serving-sharded | slo | full."""
    if name == "quick":
        plan = (Plan.clock_overhead(("O0", "O3"))
                + Plan.instructions(ops=QUICK_OPS, opt_levels=("O0", "O3"))
                + Plan.memory((1 << 13, 1 << 17, 1 << 21), steps=(512, 1536))
                + Plan.kernels(("fma",)))
    elif name == "table2":
        plan = (Plan.clock_overhead(("O0", "O3"))
                + Plan.instructions(opt_levels=("O0", "O3")))
    elif name == "memory":
        plan = Plan.memory()
    elif name == "inkernel":
        plan = Plan.inkernel()
    elif name == "memory-inkernel":
        plan = Plan.memory_inkernel()
    elif name == "fused":
        plan = Plan.fused()
    elif name == "serving":
        plan = Plan.serving()
    elif name == "collectives":
        plan = Plan.collectives()
    elif name == "serving-sharded":
        plan = Plan.serving_sharded()
    elif name == "slo":
        plan = Plan.slo()
    elif name == "full":
        # consumer plans (serving, slo) last and dep-free: the full sweep's
        # own instruction + memory rows are the estimator's pricing inputs
        plan = (Plan.clock_overhead(OPT_LEVELS)
                + Plan.instructions(opt_levels=OPT_LEVELS)
                + Plan.memory()
                + Plan.kernels(("fma", "add", "rsqrt"))
                + Plan.inkernel()
                + Plan.memory_inkernel()
                + Plan.fused()
                + Plan.collectives()
                + Plan.serving(with_deps=False)
                + Plan.serving_sharded(with_deps=False)
                + Plan.slo(with_deps=False))
    else:
        raise ValueError(f"unknown plan {name!r}; choose from {PLAN_NAMES}")
    return dataclasses.replace(plan, name=name)
