"""Probe types: the unit of work a :class:`repro.api.Session` schedules.

A probe is one measurement with a stable identity. The identity — the
``(device_kind, backend, jax_version, opt_level, op, dtype)`` tuple — is
exactly a :class:`LatencyRecord` key, which is what makes the session's result
cache work: a probe whose key already exists in the DB is a cache hit and is
never re-run (unless forced).

Concrete probes wrap the existing measurement machinery:

* :class:`InstructionProbe` — one :class:`OpSpec` at one opt level via the
  dependent-chain slope method (paper Table II).
* :class:`MemoryProbe` — the pointer-chase hierarchy probe at one working-set
  size (paper Fig. 6).
* :class:`ClockOverheadProbe` — the cost of the timed region itself at one
  opt level (paper Fig. 5).
* :class:`KernelProbe` — an in-kernel (Pallas) dependent ALU chain, the
  device-side analog of the paper's timed PTX block.
* :class:`KernelChainProbe` — any registry :class:`OpSpec` lowered into a
  Pallas ``fori_loop`` chain (``repro.inkernel``): the paper's in-pipeline
  measurement, one probe per table row.
* :class:`MemoryChaseProbe` — the pointer chase *inside* a Pallas kernel at
  one working-set size, VMEM-resident below the footprint budget and
  HBM-streaming (``memory_space=ANY``) above — the in-kernel Table IV /
  Fig. 6 analog, one probe per ladder rung.
* :class:`ServingCostProbe` — the consumer side: one serving-engine
  prefill/decode cell, priced with the estimator against the session DB and
  wall-clock measured, predicted-vs-measured in one record (docs/serving.md).
* :class:`SloProbe` — the end-to-end consumer: one arrival rate's serving
  SLOs, a seeded trace replayed through both the LatencyDB-priced simulator
  and the engine's continuous-batching slot pool (``repro.traffic``),
  predicted-vs-measured percentiles in one record (docs/traffic.md).

New probe types (energy counters, occupancy sweeps, ...) subclass
:class:`Probe` and immediately gain caching, resumability and structured
failure handling from the session scheduler.
"""
from __future__ import annotations

import dataclasses
import os
import weakref
from typing import Any, Callable, Mapping

from repro.core import measure, membench
from repro.core.chains import OpSpec
from repro.core.latency_db import LatencyRecord
from repro.core.timing import Measurement, Timer
from repro.utils import timestamp


@dataclasses.dataclass(frozen=True)
class ProbeContext:
    """Session-owned machinery handed to every probe run."""

    timer: Timer
    env: Mapping[str, str]              # device_kind / backend / jax_version
    clock_hz: float
    baseline_ns: Callable[[str], float]  # per-level 1-cycle-class baseline
    device: Any = None                   # session's pinned jax device (None = default)
    db: Any = None                       # session's LatencyDB — lets consumer
                                         # probes (ServingCostProbe) price
                                         # against already-measured rows
    compile_cache: Any = None            # CompileCache — persisted executables
    adaptive: bool = False               # adaptive fidelity on: effective rep
                                         # counts ride in record notes


class Probe:
    """One schedulable measurement. Subclasses set identity + implement run.

    Attributes
    ----------
    op: table row name (e.g. ``"fma.float32"``, ``"mem.chase.ws8192"``).
    opt_level: compilation level the probe measures under.
    dtype: dtype axis of the record key.
    category: table grouping (reuses the paper's categories; new probe kinds
        add their own, e.g. ``"memory"``, ``"overhead"``, ``"kernel"``).

    Pipelining (docs/performance.md): probes may split their work into
    :meth:`prepare` — everything XLA-bound (lowering, compiling, cache
    loads), safe to run on the session's compile-ahead thread — and
    :meth:`run_prepared` — everything device-bound, always on the main
    thread so timing stays strictly serial on the device. The base-class
    defaults keep third-party probes working unchanged: ``prepare`` returns
    None and ``run_prepared(ctx, None)`` falls back to :meth:`run`.
    """

    op: str = ""
    opt_level: str = "O3"
    dtype: str = "float32"
    category: str = "uncategorized"

    def logical_key(self) -> tuple[str, str, str]:
        """Environment-independent identity, used for plan dedupe."""
        return (self.op, self.opt_level, self.dtype)

    def match_names(self) -> frozenset[str]:
        """Every name an op filter may address this probe by.

        Always contains the full derived ``op``; subclasses whose op names are
        derived from a base row (``inkernel.add`` from ``add``, fidelity
        suffixes like ``mem.chase.ws8192.s512-1536``) also answer to the base
        forms, so ``Plan.filter(ops=["add"])`` keeps a plan's ``inkernel.add``
        instead of silently dropping it. Exact-by-construction: ``add`` never
        matches the distinct registry row ``add.bfloat16``.
        """
        return frozenset((self.op,))

    def key(self, env: Mapping[str, str]) -> tuple:
        """Full cache key; identical layout to ``LatencyRecord.key()``."""
        return (env["device_kind"], env["backend"], env["jax_version"],
                self.opt_level, self.op, self.dtype)

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        raise NotImplementedError

    def prepare(self, ctx: ProbeContext) -> Any:
        """XLA-bound half: compile this probe's callables, no device timing.

        Runs on the session's compile-ahead thread in pipelined mode (and
        inline in serial mode). The default returns None, which makes
        :meth:`run_prepared` fall back to :meth:`run` — third-party probes
        that only implement ``run`` keep working.
        """
        return None

    def run_prepared(self, ctx: ProbeContext, prepared: Any) -> LatencyRecord:
        """Device-bound half: time the callables ``prepare`` built."""
        return self.run(ctx)

    # ------------------------------------------------------------------ util
    def _record(self, ctx: ProbeContext, m: Measurement, *, guard: int = 0,
                notes: str = "", baseline: float | None = None) -> LatencyRecord:
        """Build the result record from a Measurement, netting out guards.

        ``baseline`` overrides the session's dispatch-level add baseline for
        probes whose guard ops run under a different methodology (in-kernel).
        """
        if ctx.adaptive:
            # the convergence rule may have stopped early (or banked reps may
            # have extended the run): persist the effective sample count
            notes = (notes + " " if notes else "") + f"reps_eff={m.n}"
        ns = max(m.median_ns, 0.0)
        if guard:
            base = baseline if baseline is not None else ctx.baseline_ns(self.opt_level)
        else:
            base = 0.0
        net = ns - guard * base
        if net < 0.0:
            # The guard subtraction went negative: the clamp below would
            # otherwise persist indistinguishably from a genuinely ~0 latency,
            # so flag the row for the auditor (repro.audit surfaces clamped=1
            # rows — a negative net usually means the declared guard count is
            # wrong or the baseline came from a different methodology).
            notes = (notes + " " if notes else "") + "clamped=1"
        return LatencyRecord(
            op=self.op, category=self.category, dtype=self.dtype,
            opt_level=self.opt_level, latency_ns=ns, mad_ns=m.mad_ns,
            cycles=ns * ctx.clock_hz / 1e9, guard=guard,
            net_latency_ns=max(net, 0.0), n_samples=m.n,
            measured_at=timestamp(), notes=notes, **ctx.env)

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.op}@{self.opt_level})"


class InstructionProbe(Probe):
    """One registry OpSpec at one opt level (paper Table II row x column)."""

    def __init__(self, spec: OpSpec, opt_level: str = "O3"):
        self.spec = spec
        self.op = spec.name
        self.opt_level = opt_level
        self.dtype = spec.dtype
        self.category = spec.category

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        m = measure.measure_op_full(self.spec, self.opt_level, ctx.timer)
        return self._record(ctx, m, guard=self.spec.guard, notes=self.spec.notes)

    def prepare(self, ctx: ProbeContext):
        return measure.prepare_op(self.spec, self.opt_level,
                                  cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        if prepared is None:
            return self.run(ctx)
        m = measure.run_prepared_op(prepared, ctx.timer)
        return self._record(ctx, m, guard=self.spec.guard, notes=self.spec.notes)


class ClockOverheadProbe(Probe):
    """Cost of the timed region itself at one opt level (paper Fig. 5)."""

    category = "overhead"

    def __init__(self, opt_level: str = "O3"):
        self.op = "clock_overhead"
        self.opt_level = opt_level

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        import jax
        import jax.numpy as jnp

        from repro.core.optlevels import compile_at_level

        x = jnp.asarray(1.0, jnp.float32)
        if self.opt_level != "O0" and ctx.compile_cache is not None:
            from repro.core.compile_cache import fidelity_key

            key = fidelity_key(ctx.env, self.op, self.opt_level,
                               self.dtype, "null")
            fn, _, _ = ctx.compile_cache.load_or_compile(
                key, lambda: measure._aot_compile(lambda v: v,
                                                  self.opt_level, x))
        else:
            fn = compile_at_level(lambda v: v, self.opt_level, x)
        return (fn, x)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        if prepared is None:
            return self.run(ctx)
        fn, x = prepared
        m = ctx.timer.time_callable(fn, x, reps=measure._REPS[self.opt_level])
        return self._record(ctx, m, notes="null timed region (Fig. 5 analog)")


class MemoryProbe(Probe):
    """Dependent pointer chase at one working-set size (paper Fig. 6 point).

    Non-default chase parameters are part of the op name (and therefore the
    cache key): a low-fidelity short-chase point must never satisfy a cache
    lookup for the standard-fidelity sweep.
    """

    category = "memory"
    dtype = "int32"
    DEFAULT_STEPS = (2048, 6144)
    DEFAULT_LINE_BYTES = 64

    def __init__(self, working_set_bytes: int,
                 line_bytes: int = DEFAULT_LINE_BYTES,
                 steps: tuple[int, int] = DEFAULT_STEPS):
        self.working_set_bytes = int(working_set_bytes)
        self.line_bytes = line_bytes
        self.steps = tuple(steps)
        self.base_op = f"mem.chase.ws{self.working_set_bytes}"
        self.op = self.base_op
        if self.steps != self.DEFAULT_STEPS:
            self.op += f".s{self.steps[0]}-{self.steps[1]}"
        if self.line_bytes != self.DEFAULT_LINE_BYTES:
            self.op += f".line{self.line_bytes}"

    def match_names(self) -> frozenset[str]:
        # "mem" is the whole-family base row: ``--ops mem`` keeps every
        # memory-hierarchy rung, host-level and in-kernel alike
        return frozenset((self.op, self.base_op, "mem"))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        return membench.prepare_chase(self.working_set_bytes,
                                      line_bytes=self.line_bytes,
                                      steps=self.steps,
                                      cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        if prepared is None:
            return self.run(ctx)
        pt = membench.run_prepared_chase(prepared, ctx.timer)
        m = Measurement(median_ns=pt.latency_ns, mad_ns=0.0,
                        min_ns=pt.latency_ns, n=ctx.timer.reps)
        return self._record(
            ctx, m, notes=f"cold_ns={pt.cold_latency_ns:.3f} "
                          f"stride={pt.stride_bytes}")


class KernelProbe(Probe):
    """In-kernel (Pallas) dependent ALU chain, slope-timed.

    The device-side analog of the paper's timed PTX block: the whole kernel is
    the timed region and the two-length slope cancels DMA/launch overhead.
    Runs in interpret mode on CPU; lowers to a real kernel on TPU.
    """

    category = "kernel"
    DEFAULT_LENS = (8, 64)
    DEFAULT_SHAPE = (8, 128)

    def __init__(self, kernel_op: str = "fma",
                 lens: tuple[int, int] = DEFAULT_LENS,
                 shape: tuple[int, int] = DEFAULT_SHAPE, reps: int = 5):
        self.kernel_op = kernel_op
        self.lens = tuple(lens)
        self.shape = tuple(shape)
        self.reps = reps
        # non-default chain lengths / tile are a different experiment: make
        # them part of the cache identity, like MemoryProbe.steps
        self.base_op = f"kernel.alu_chain.{kernel_op}"
        self.op = self.base_op
        if self.lens != self.DEFAULT_LENS:
            self.op += f".l{self.lens[0]}-{self.lens[1]}"
        if self.shape != self.DEFAULT_SHAPE:
            self.op += f".t{self.shape[0]}x{self.shape[1]}"

    def match_names(self) -> frozenset[str]:
        return frozenset((self.op, self.base_op, self.kernel_op))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        import jax.numpy as jnp

        from repro.inkernel.measure import _cached_aot
        from repro.kernels.ops import alu_chain

        x = jnp.full(self.shape, 1.0, jnp.float32)
        a = jnp.full(self.shape, 0.5, jnp.float32)
        fns = {}

        def fn_by_len(n: int):
            if n not in fns:
                raw = lambda x, a, n=n: alu_chain(x, a, n=n,  # noqa: E731
                                                  op=self.kernel_op)
                fns[n] = _cached_aot(raw, (x, a), self.base_op,
                                     f"chain{n}.{self.kernel_op}."
                                     f"t{self.shape[0]}x{self.shape[1]}",
                                     ctx.compile_cache, ctx.env,
                                     dtype="float32")
            return fns[n]

        fn_by_len(self.lens[0])
        fn_by_len(self.lens[1])
        return (fn_by_len, x, a)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        if prepared is None:
            return self.run(ctx)
        fn_by_len, x, a = prepared
        m = ctx.timer.slope(fn_by_len, *self.lens, x, a, reps=self.reps)
        return self._record(
            ctx, m, notes=f"pallas alu_chain tile={self.shape} lens={self.lens}")


class KernelChainProbe(Probe):
    """One registry :class:`OpSpec` as an in-kernel Pallas chain (the paper's
    in-pipeline measurement, ``repro.inkernel``).

    Shares the record schema and category with the spec's dispatch-level
    :class:`InstructionProbe`, but under the op name ``inkernel.<name>`` —
    both rows coexist in one LatencyDB, which is what
    ``LatencyDB.compare_markdown`` pairs up. ``opt_level`` is pinned to
    ``"O3"``: a Pallas kernel is always fully compiled, there is no eager
    analog. Non-default chain lengths / tiles are a different fidelity and
    therefore part of the cache identity, like ``MemoryProbe.steps``
    (``lens=None`` means the library default, ``inkernel.INKERNEL_LENS`` —
    the single source of truth for what "unsuffixed fidelity" means).

    Guard netting stays in-method: the ``guard x add`` subtraction uses an
    *in-kernel* add baseline (measured once per session timer and chain
    lengths), never the dispatch-level baseline — mixing the two
    methodologies would clamp cheap guarded ops to a net of 0 on hardware
    where in-kernel latencies are far below dispatch ones.
    """

    # per-(timer, lens) in-kernel add-pair baseline; WeakKey so session
    # timers don't leak
    _baselines: "weakref.WeakKeyDictionary" = None  # set below the class

    def __init__(self, spec: OpSpec, lens: tuple[int, int] | None = None,
                 shape: tuple[int, int] | None = None, reps: int = 5):
        from repro import inkernel

        if not inkernel.supported(spec):
            raise ValueError(f"spec {spec.name!r} cannot lower in-kernel")
        self.spec = spec
        self.lens = tuple(lens) if lens is not None else tuple(inkernel.INKERNEL_LENS)
        self.shape = tuple(shape) if shape is not None else None
        self.reps = reps
        self.opt_level = "O3"
        self.dtype = spec.dtype
        self.category = spec.category
        self.base_op = f"inkernel.{spec.name}"
        self.op = self.base_op
        if self.lens != tuple(inkernel.INKERNEL_LENS):
            self.op += f".l{self.lens[0]}-{self.lens[1]}"
        if self.shape is not None:
            self.op += f".t{self.shape[0]}x{self.shape[1]}"

    def match_names(self) -> frozenset[str]:
        # addressable by the full derived name, the unsuffixed in-kernel name,
        # and the dispatch-side base row (``--ops add`` keeps ``inkernel.add``)
        return frozenset((self.op, self.base_op, self.spec.name))

    def _inkernel_baseline_ns(self, ctx: ProbeContext) -> float:
        """In-kernel 1-cycle-class baseline: the ``add`` spec's (add ^ xor)
        pair measured in-kernel at the same lens, / (1 + its guard)."""
        from repro import inkernel
        from repro.core import chains

        per_timer = KernelChainProbe._baselines.setdefault(ctx.timer, {})
        if self.lens not in per_timer:
            base = next(o for o in chains.default_registry() if o.name == "add")
            m = inkernel.measure_inkernel_full(base, lens=self.lens,
                                               timer=ctx.timer, reps=self.reps)
            per_timer[self.lens] = max(m.median_ns, 0.0) / (1 + base.guard)
        return per_timer[self.lens]

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        from repro import inkernel

        m = inkernel.measure_inkernel_full(self.spec, lens=self.lens,
                                           shape=self.shape, timer=ctx.timer,
                                           reps=self.reps)
        return self._finish(ctx, m)

    def prepare(self, ctx: ProbeContext):
        from repro import inkernel

        return inkernel.prepare_inkernel(self.spec, lens=self.lens,
                                         shape=self.shape, reps=self.reps,
                                         cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        from repro import inkernel

        if prepared is None:
            return self.run(ctx)
        m = inkernel.run_prepared_inkernel(prepared, ctx.timer)
        return self._finish(ctx, m)

    def _finish(self, ctx: ProbeContext, m: Measurement) -> LatencyRecord:
        from repro import inkernel

        baseline = self._inkernel_baseline_ns(ctx) if self.spec.guard else None
        return self._record(
            ctx, m, guard=self.spec.guard, baseline=baseline,
            notes=f"pallas fori_loop chain lens={self.lens} "
                  f"tile={self.shape or inkernel.default_tile(self.spec.dtype)}")


KernelChainProbe._baselines = weakref.WeakKeyDictionary()


class FusedKernelProbe(Probe):
    """One in-repo fused Pallas kernel as a two-size workload slope
    (``inkernel.fused.<name>`` rows; plan name ``fused``).

    The same netting algebra as :class:`KernelChainProbe`, with the chain
    length replaced by a workload-unit count (KV blocks for the attention
    kernels, sequence chunks for the SSM scan, row blocks for rmsnorm): two
    sizes share the launch path and block shapes, so the slope is the pure
    per-unit kernel cost. The builder (``repro.inkernel.fused.build_fused``)
    is shared with the dataflow auditor, whose signature-linearity
    certificate guarantees the slope's denominator; the certified per-unit
    HBM byte count rides in the record notes (``unit_bytes=``) so
    ``HloLatencyEstimator`` can scale the row to a zoo model's custom-call
    of a different shape.
    """

    def __init__(self, name: str, lens: tuple[int, int] | None = None,
                 reps: int = 5):
        from repro import inkernel

        if name not in inkernel.FUSED_KERNELS:
            raise ValueError(f"unknown fused kernel {name!r}; known: "
                             f"{', '.join(inkernel.FUSED_KERNELS)}")
        self.name = name
        self.lens = tuple(lens) if lens is not None else tuple(
            inkernel.FUSED_LENS)
        self.reps = reps
        self.opt_level = "O3"
        self.dtype = "float32"
        self.category = "kernel"
        self.base_op = f"inkernel.fused.{name}"
        self.op = self.base_op
        if self.lens != tuple(inkernel.FUSED_LENS):
            self.op += f".l{self.lens[0]}-{self.lens[1]}"

    def match_names(self) -> frozenset[str]:
        return frozenset((self.op, self.base_op, self.name))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        from repro import inkernel

        m = inkernel.measure_fused_full(self.name, lens=self.lens,
                                        timer=ctx.timer, reps=self.reps)
        return self._finish(ctx, m)

    def prepare(self, ctx: ProbeContext):
        from repro import inkernel

        return inkernel.prepare_fused(self.name, lens=self.lens,
                                      reps=self.reps,
                                      cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        from repro import inkernel

        if prepared is None:
            return self.run(ctx)
        m = inkernel.run_prepared_fused(prepared, ctx.timer)
        return self._finish(ctx, m)

    def _finish(self, ctx: ProbeContext, m: Measurement) -> LatencyRecord:
        notes = f"pallas fused kernel lens={self.lens[0]}-{self.lens[1]}"
        try:
            from repro.audit.dataflow import fused_unit

            unit = fused_unit(self.name, self.lens)
            notes += (f" unit_bytes={unit['bytes']} "
                      f"unit_ops={sum(unit['ops'].values())}")
        except Exception:
            # the certificate is attached by the audit pass; a failure to
            # derive it here must not lose the measurement
            pass
        return self._record(ctx, m, notes=notes)


class MemoryChaseProbe(Probe):
    """In-kernel pointer chase at one working-set size: the memory-hierarchy
    rows of the in-pipeline method (paper Table IV / Fig. 6 analogs).

    The dependent chase runs *inside* a Pallas kernel
    (``repro.kernels.chase``) under the same two-length ``Timer.slope``
    extraction as :class:`KernelChainProbe`; the ring's residency is selected
    by footprint — BlockSpec-pinned in VMEM below the budget (Table IV, the
    shared-memory analog), ``memory_space=ANY`` streaming from HBM above
    (Fig. 6, the global-memory analog) — and the residency actually used is
    persisted in the record notes (``space=vmem|any``) together with the
    working-set / line metadata (:func:`membench.chasepoint_from_record`).

    Op name ``inkernel.mem.<bytes>``; ``opt_level`` pinned to ``"O3"`` like
    every Pallas probe (a kernel is always fully compiled). Non-default step
    counts, a non-default line padding or a *forced* memory space are a
    different experiment and become fidelity suffixes in the cache identity,
    like ``MemoryProbe.steps``.
    """

    category = "memory"
    dtype = "int32"
    DEFAULT_LINE_BYTES = 64

    def __init__(self, working_set_bytes: int,
                 line_bytes: int = DEFAULT_LINE_BYTES,
                 lens: tuple[int, int] | None = None,
                 memory_space: str | None = None, reps: int = 5):
        from repro import inkernel

        self.working_set_bytes = int(working_set_bytes)
        self.line_bytes = line_bytes
        self.lens = tuple(lens) if lens is not None else tuple(inkernel.CHASE_LENS)
        self.memory_space = memory_space  # None = select by footprint
        self.reps = reps
        self.opt_level = "O3"
        self.base_op = f"inkernel.mem.{self.working_set_bytes}"
        self.host_op = f"mem.chase.ws{self.working_set_bytes}"
        self.op = self.base_op
        if self.lens != tuple(inkernel.CHASE_LENS):
            self.op += f".l{self.lens[0]}-{self.lens[1]}"
        if self.line_bytes != self.DEFAULT_LINE_BYTES:
            self.op += f".line{self.line_bytes}"
        if memory_space is not None:
            self.op += f".{memory_space}"

    def match_names(self) -> frozenset[str]:
        # addressable by the full derived name, the unsuffixed in-kernel row,
        # the host-level twin (``--ops mem.chase.ws8192`` keeps both sides of
        # the pairing) and the whole-family base row ``mem``
        return frozenset((self.op, self.base_op, self.host_op, "mem"))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        from repro import inkernel

        m, space = inkernel.measure_chase_full(
            self.working_set_bytes, line_bytes=self.line_bytes,
            lens=self.lens, timer=ctx.timer, memory_space=self.memory_space,
            reps=self.reps)
        return self._finish(ctx, m, space)

    def prepare(self, ctx: ProbeContext):
        from repro import inkernel

        return inkernel.prepare_chase(
            self.working_set_bytes, line_bytes=self.line_bytes,
            lens=self.lens, memory_space=self.memory_space, reps=self.reps,
            cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        from repro import inkernel

        if prepared is None:
            return self.run(ctx)
        m, space = inkernel.run_prepared_chase(prepared, ctx.timer)
        return self._finish(ctx, m, space)

    def _finish(self, ctx: ProbeContext, m: Measurement,
                space: str) -> LatencyRecord:
        return self._record(
            ctx, m, notes=f"pallas chase ws={self.working_set_bytes} "
                          f"line={self.line_bytes} space={space} "
                          f"lens={self.lens[0]}-{self.lens[1]}")


class CollectiveProbe(Probe):
    """One collective-ladder rung: ``n`` dependent collective ops chained
    inside ``shard_map``, slope-timed (``repro.parallel.ladders``).

    The paper's dependent-chain method pointed at the interconnect: two chain
    lengths share the dispatch, shard_map wrapping and first-transfer warm-up,
    so ``Timer.slope`` isolates the pure per-collective cost. One probe per
    ``(kind, device count, payload)``; op name
    ``coll.<kind>.d<devices>.<bytes>`` with the payload being the *nominal*
    per-device rung (the actual local bytes after divisibility rounding, and
    the ring-convention wire bytes per step, ride in the record notes —
    ``HloLatencyEstimator.collective_ladder`` prices from those).

    ``opt_level`` is pinned to ``"O3"``: a shard_map chain is always fully
    compiled. Non-default chain lengths are a different fidelity and suffix
    the cache identity, like ``MemoryProbe.steps``. Off-TPU the mesh is built
    from simulated XLA host devices
    (``--xla_force_host_platform_device_count``); a backend with fewer
    devices than the row names fails structurally instead of silently
    measuring a smaller group.
    """

    category = "collective"
    dtype = "float32"
    DEFAULT_LENS = (2, 6)

    def __init__(self, kind: str, payload_bytes: int,
                 devices: int | None = None,
                 lens: tuple[int, int] | None = None, reps: int = 5):
        from repro.parallel import ladders

        if kind not in ladders.LADDER_KINDS:
            raise ValueError(f"unknown collective kind {kind!r}; known: "
                             f"{', '.join(ladders.LADDER_KINDS)}")
        if payload_bytes <= 0:
            raise ValueError(f"payload_bytes must be positive, "
                             f"got {payload_bytes}")
        if devices is None:
            import jax

            devices = jax.device_count()
        self.kind = kind
        self.payload_bytes = int(payload_bytes)
        self.devices = int(devices)
        self.lens = tuple(lens) if lens is not None else self.DEFAULT_LENS
        self.reps = reps
        self.opt_level = "O3"
        self.base_op = f"coll.{kind}.d{self.devices}.{self.payload_bytes}"
        self.op = self.base_op
        if self.lens != self.DEFAULT_LENS:
            self.op += f".l{self.lens[0]}-{self.lens[1]}"

    def match_names(self) -> frozenset[str]:
        # addressable by the full rung name, the unsuffixed rung, the kind
        # family (``--ops coll.psum``) and the whole-family row ``coll``
        return frozenset((self.op, self.base_op,
                          f"coll.{self.kind}", "coll"))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        from repro.parallel import ladders

        return ladders.prepare_collective(
            self.kind, self.payload_bytes, self.devices, self.lens,
            op=self.op, cache=ctx.compile_cache, env=ctx.env)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        from repro.parallel import ladders

        if prepared is None:
            return self.run(ctx)
        fn_by_len, x, local_bytes = prepared
        m = ctx.timer.slope(fn_by_len, *self.lens, x, reps=self.reps)
        wire = ladders.step_wire_bytes(self.kind, local_bytes, self.devices)
        return self._record(
            ctx, m,
            notes=f"kind={self.kind} devices={self.devices} "
                  f"payload_bytes={local_bytes} wire_bytes={wire:.0f} "
                  f"lens={self.lens[0]}-{self.lens[1]}")


def serving_tiny_config():
    """The default model the serving cells characterize: small enough for CI
    wall clocks, deep enough (2 scanned periods) that the decode-step HLO
    carries a real ``known_trip_count`` for the estimator's rollup."""
    from repro.models.config import ModelConfig, Runtime

    cfg = ModelConfig(name="serving-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=128, param_dtype="float32",
                      compute_dtype="float32")
    rt = Runtime(remat=False, xent_chunk=16, moe_groups=1)
    return cfg, rt


class ServingCostProbe(Probe):
    """Price + measure one serving cell: the Engine's prefill or decode-step
    HLO at ``(batch, prompt_len)`` — where the measurement side of the repo
    (LatencyDB) meets the model side (perfmodel), the paper's stated purpose.

    The probe lowers :meth:`repro.serving.Engine.lower_prefill` /
    :meth:`~repro.serving.Engine.lower_decode` at the cell, prices the
    optimized HLO with :class:`~repro.core.perfmodel.HloLatencyEstimator`
    against the session's DB (environment-filtered: rows from other
    devices/jax versions never price this cell), then times the compiled
    executable. The record's ``latency_ns`` is the **measured** wall clock;
    the prediction and its :class:`~repro.core.perfmodel.PricedReport`
    digest (coverage, compute/memory split) ride in the notes and are parsed
    back by :func:`~repro.core.perfmodel.servingpoint_from_record`.

    Op names ``serving.prefill.b<B>p<L>`` / ``serving.decode.b<B>p<L>``;
    ``opt_level`` pinned to ``"O3"`` (a lowered executable is always fully
    compiled). A non-default model config is a different experiment and
    suffixes the cache identity with its name, like ``MemoryProbe.steps``.
    """

    category = "serving"

    def __init__(self, phase: str, batch: int, prompt_len: int,
                 cfg=None, rt=None, max_len: int | None = None, reps: int = 5):
        if phase not in ("prefill", "decode"):
            raise ValueError(f"phase must be prefill|decode, got {phase!r}")
        default_cfg, default_rt = serving_tiny_config()
        self.phase = phase
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.cfg = cfg if cfg is not None else default_cfg
        self.rt = rt if rt is not None else default_rt
        self.max_len = max_len
        self.reps = reps
        self.opt_level = "O3"
        self.dtype = self.cfg.compute_dtype
        self.base_op = f"serving.{phase}.b{self.batch}p{self.prompt_len}"
        self.op = self.base_op
        if max_len is not None:
            # a non-default decode cache size is a different experiment
            # (different HLO), so it suffixes the cache identity like
            # MemoryProbe.steps
            self.op += f".c{int(max_len)}"
        if self.cfg.name != default_cfg.name:
            self.op += f".{self.cfg.name}"

    def match_names(self) -> frozenset[str]:
        # addressable by the full cell name, the phase family
        # (``--ops serving.decode``) and the whole-family row ``serving``
        return frozenset((self.op, self.base_op,
                          f"serving.{self.phase}", "serving"))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        """Init params, lower the cell and compile it (via the compile cache).

        The lowering itself always runs (it is what produces the call args);
        only the XLA backend compile — the expensive part — is skipped on a
        cache hit. The optimized HLO text rides in the cache entry's
        ``extra`` payload because a deserialized executable cannot be asked
        for ``as_text()`` on every backend.
        """
        import jax

        from repro.models import transformer
        from repro.serving.engine import Engine

        params = transformer.init_lm(jax.random.PRNGKey(0), self.cfg)
        eng = Engine(params, self.cfg, self.rt)
        if self.phase == "prefill":
            lowered, args = eng.lower_prefill(self.batch, self.prompt_len)
            cache_len = 0                     # prefill builds, never scans, KV
        else:
            cache_len = self.max_len if self.max_len is not None else eng.max_len
            lowered, args = eng.lower_decode(self.batch, self.prompt_len,
                                             cache_len)
        if ctx.compile_cache is not None:
            from repro.core.compile_cache import fidelity_key

            key = fidelity_key(ctx.env, self.op, self.opt_level, self.dtype,
                               f"cache{cache_len}")
            compiled, hlo, _ = ctx.compile_cache.load_or_compile(
                key, lowered.compile, extra=lambda c: c.as_text())
        else:
            compiled = lowered.compile()
            hlo = None
        if hlo is None:
            try:
                hlo = compiled.as_text()
            except Exception:  # noqa: BLE001 - deserialized executable
                hlo = ""
        return (compiled, args, hlo, cache_len)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        import jax

        from repro.core.perfmodel import HloLatencyEstimator

        if prepared is None:
            return self.run(ctx)
        compiled, args, hlo, cache_len = prepared
        if ctx.db is not None and getattr(ctx.db, "path", None):
            # sharded runs (Session.fan_out) give each device its own DB
            # copy; sibling shards flush their dep rows to the shared path
            # after every probe, so pick those up before pricing instead of
            # falling back to default_ns for rows another shard measured
            from repro.core.latency_db import LatencyDB

            if os.path.exists(ctx.db.path):
                ctx.db.merge(LatencyDB(ctx.db.path))
        est = HloLatencyEstimator(ctx.db, opt_level=self.opt_level,
                                  filters=dict(ctx.env))
        report = est.estimate(hlo)
        m = ctx.timer.time_callable(compiled, *args, reps=self.reps)
        # cache= records the KV length this cell actually priced: a decode
        # row is meaningless without it (the scan length dominates), and
        # lower_decode's default changed once already (prompt+32 -> max_len)
        notes = (f"phase={self.phase} batch={self.batch} "
                 f"prompt={self.prompt_len} cache={cache_len} "
                 f"model={self.cfg.name} "
                 f"predicted_ns={report.total_ns:.3f} "
                 f"compute_ns={report.compute_ns:.3f} "
                 f"memory_ns={report.memory_ns:.3f} "
                 f"coverage={report.coverage:.4f} "
                 f"bound={report.bound}")
        return self._record(ctx, m, notes=notes)


class ShardedServingCostProbe(Probe):
    """Price + measure one *tensor-parallel* serving cell: the Engine's
    prefill or decode-step HLO lowered under a ``(1, tp)`` mesh
    (``launch/mesh.make_mesh_for``), params sharded over the ``model`` axis.

    The sharded lowering makes GSPMD insert real collectives; the estimator
    prices the per-shard compute/memory from the existing measured rows
    *plus* the new collective term from the measured ladder rungs
    (``coll.<kind>.d<N>.<bytes>``), and the compiled SPMD executable is
    wall-clock timed on the same simulated mesh — predicted-vs-measured for
    distributed serving in one record. Collective pricing is explicit: the
    notes carry the collective-ns split, the number of priced collective
    instances and the count left unpriced (``coll_unpriced=0`` is the CI
    acceptance gate — zero default-priced collectives).

    Op names ``serving.tp<N>.<phase>.b<B>p<L>`` — rendered by the same
    ``compare_markdown(prefix="serving.")`` table and parsed by the same
    :func:`~repro.core.perfmodel.servingpoint_from_record` (phase rides in
    the notes) as the single-device cells.
    """

    category = "serving"

    def __init__(self, phase: str, batch: int, prompt_len: int, tp: int = 2,
                 cfg=None, rt=None, max_len: int | None = None, reps: int = 5):
        if phase not in ("prefill", "decode"):
            raise ValueError(f"phase must be prefill|decode, got {phase!r}")
        if int(tp) < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        default_cfg, default_rt = serving_tiny_config()
        self.phase = phase
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.tp = int(tp)
        self.cfg = cfg if cfg is not None else default_cfg
        self.rt = rt if rt is not None else default_rt
        self.max_len = max_len
        self.reps = reps
        self.opt_level = "O3"
        self.dtype = self.cfg.compute_dtype
        self.base_op = (f"serving.tp{self.tp}.{phase}"
                        f".b{self.batch}p{self.prompt_len}")
        self.op = self.base_op
        if max_len is not None:
            self.op += f".c{int(max_len)}"
        if self.cfg.name != default_cfg.name:
            self.op += f".{self.cfg.name}"

    def match_names(self) -> frozenset[str]:
        return frozenset((self.op, self.base_op, f"serving.tp{self.tp}",
                          f"serving.{self.phase}", "serving"))

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        return self.run_prepared(ctx, self.prepare(ctx))

    def prepare(self, ctx: ProbeContext):
        """Shard params over the TP mesh, lower the cell, compile (cached).

        Params are ``device_put`` onto their resolved ``NamedSharding``\\ s
        before lowering, so jit infers sharded in_shardings and GSPMD
        partitions the module (``num_partitions=tp``, collectives in the
        optimized HLO). The lowering runs inside
        :func:`repro.parallel.sharding.use_sharding` so the model's
        activation ``annotate`` constraints resolve against the same mesh.
        """
        import jax

        from repro.launch.mesh import make_mesh_for
        from repro.models import transformer
        from repro.parallel import sharding as shd
        from repro.serving.engine import Engine

        if self.tp > jax.device_count():
            raise RuntimeError(
                f"{self.op} needs {self.tp} devices, backend has "
                f"{jax.device_count()} (set XLA_FLAGS=--xla_force_host_"
                f"platform_device_count={self.tp})")
        mesh = make_mesh_for(self.tp, model_parallel=self.tp)
        rules = shd.lm_rules(fsdp=False)
        params = transformer.init_lm(jax.random.PRNGKey(0), self.cfg)
        params = jax.device_put(params,
                                shd.param_shardings(params, mesh, rules))
        with shd.use_sharding(mesh, rules):
            eng = Engine(params, self.cfg, self.rt)
            if self.phase == "prefill":
                lowered, args = eng.lower_prefill(self.batch, self.prompt_len)
                cache_len = 0
            else:
                cache_len = (self.max_len if self.max_len is not None
                             else eng.max_len)
                lowered, args = eng.lower_decode(self.batch, self.prompt_len,
                                                 cache_len)
            if ctx.compile_cache is not None:
                from repro.core.compile_cache import fidelity_key

                key = fidelity_key(ctx.env, self.op, self.opt_level,
                                   self.dtype, f"cache{cache_len}")
                compiled, hlo, _ = ctx.compile_cache.load_or_compile(
                    key, lowered.compile, extra=lambda c: c.as_text())
            else:
                compiled = lowered.compile()
                hlo = None
        if hlo is None:
            try:
                hlo = compiled.as_text()
            except Exception:  # noqa: BLE001 - deserialized executable
                hlo = ""
        return (compiled, args, hlo, cache_len)

    def run_prepared(self, ctx: ProbeContext, prepared) -> LatencyRecord:
        from repro.core.perfmodel import ClassCost, HloLatencyEstimator

        if prepared is None:
            return self.run(ctx)
        compiled, args, hlo, cache_len = prepared
        if ctx.db is not None and getattr(ctx.db, "path", None):
            from repro.core.latency_db import LatencyDB

            if os.path.exists(ctx.db.path):
                ctx.db.merge(LatencyDB(ctx.db.path))
        est = HloLatencyEstimator(ctx.db, opt_level=self.opt_level,
                                  filters=dict(ctx.env))
        report = est.estimate(hlo)
        m = ctx.timer.time_callable(compiled, *args, reps=self.reps)
        coll = report.by_class.get("collective", ClassCost())
        coll_unpriced = sum(
            c for label, c in report.unpriced_opcodes
            if label.startswith("collective:"))
        notes = (f"phase={self.phase} batch={self.batch} "
                 f"prompt={self.prompt_len} cache={cache_len} "
                 f"tp={self.tp} model={self.cfg.name} "
                 f"predicted_ns={report.total_ns:.3f} "
                 f"compute_ns={report.compute_ns:.3f} "
                 f"memory_ns={report.memory_ns:.3f} "
                 f"collective_ns={report.collective_ns:.3f} "
                 f"coll_ops={coll.instances:g} "
                 f"coll_unpriced={coll_unpriced:g} "
                 f"coverage={report.coverage:.4f} "
                 f"bound={report.bound}")
        return self._record(ctx, m, notes=notes)


class SloProbe(Probe):
    """One serving-SLO point: a seeded arrival trace at one rate, replayed
    through *both* sides of ``repro.traffic`` — the LatencyDB-priced
    simulator (predicted) and the engine's continuous-batching slot pool
    (measured) — and aggregated into exact-rank TTFT/TPOT/e2e percentiles.

    The record's ``latency_ns`` is the **measured p50 TTFT** (the headline
    SLO number); every other percentile, both predicted and measured, plus
    goodput and the estimator's coverage, ride in the notes and are parsed
    back by :func:`~repro.core.perfmodel.slopoint_from_record`. Like
    :class:`ServingCostProbe` this is a consumer probe: it prices against
    ``ctx.db``, so schedule it *after* the instruction/memory rows
    (``Plan.slo`` does).

    Op name ``slo.r<rate>``; a non-default trace shape (request count, slot
    count, seed, arrival process) or model is a different experiment and
    suffixes the cache identity, like ``MemoryProbe.steps``.

    This probe intentionally has no ``prepare``/``run_prepared`` split: its
    wall clock is dominated by the slot-pool trace replay, not by XLA
    compiles, and it consumes rows sibling probes may still be flushing —
    the base-class fallback (``run_prepared(ctx, None) -> run``) schedules
    it correctly in pipelined sessions.
    """

    category = "slo"
    DEFAULT_N = 12
    DEFAULT_SLOTS = 4

    def __init__(self, rate_rps: float, n_requests: int = DEFAULT_N,
                 n_slots: int = DEFAULT_SLOTS, seed: int = 0,
                 cfg=None, rt=None, max_len: int | None = None,
                 process: str = "poisson", burstiness_cv: float = 1.0,
                 prompt_len: tuple[int, int] = (4, 8),
                 max_new: tuple[int, int] = (4, 8)):
        default_cfg, default_rt = serving_tiny_config()
        self.rate_rps = float(rate_rps)
        self.n_requests = int(n_requests)
        self.n_slots = int(n_slots)
        self.seed = int(seed)
        self.cfg = cfg if cfg is not None else default_cfg
        self.rt = rt if rt is not None else default_rt
        self.max_len = max_len
        self.process = process
        self.burstiness_cv = float(burstiness_cv)
        self.prompt_len = tuple(prompt_len)
        self.max_new = tuple(max_new)
        self.opt_level = "O3"
        self.dtype = self.cfg.compute_dtype
        self.base_op = f"slo.r{self.rate_rps:g}"
        self.op = self.base_op
        if (self.n_requests, self.n_slots) != (self.DEFAULT_N,
                                               self.DEFAULT_SLOTS):
            self.op += f".n{self.n_requests}s{self.n_slots}"
        if self.seed != 0:
            self.op += f".seed{self.seed}"
        if self.process != "poisson":
            self.op += f".{self.process}{self.burstiness_cv:g}"
        if max_len is not None:
            self.op += f".c{int(max_len)}"
        if self.cfg.name != default_cfg.name:
            self.op += f".{self.cfg.name}"

    def match_names(self) -> frozenset[str]:
        # addressable by the full point name, the rate family and the
        # whole-family row ``slo``
        return frozenset((self.op, self.base_op, "slo"))

    def trace_config(self):
        """The (deterministic) trace recipe this point replays."""
        from repro.traffic.traces import TraceConfig

        return TraceConfig(n_requests=self.n_requests, rate_rps=self.rate_rps,
                           seed=self.seed, process=self.process,
                           burstiness_cv=self.burstiness_cv,
                           prompt_len=self.prompt_len, max_new=self.max_new,
                           vocab_size=self.cfg.vocab_size)

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        import jax

        from repro.models import transformer
        from repro.serving.engine import Engine
        from repro.traffic.simulate import run_slo_point
        from repro.traffic.traces import generate_trace

        params = transformer.init_lm(jax.random.PRNGKey(0), self.cfg)
        eng = Engine(params, self.cfg, self.rt)
        trace = generate_trace(self.trace_config())
        db = ctx.db
        if db is None:
            from repro.core.latency_db import LatencyDB

            db = LatencyDB()
        elif getattr(db, "path", None) and os.path.exists(db.path):
            # pick up sibling shards' dep rows, like ServingCostProbe
            from repro.core.latency_db import LatencyDB

            db.merge(LatencyDB(db.path))
        pred, meas, coverage = run_slo_point(
            eng, db, trace, n_slots=self.n_slots, max_len=self.max_len,
            opt_level=self.opt_level, filters=dict(ctx.env))
        m = Measurement(median_ns=meas.ttft_ns[50.0], mad_ns=0.0,
                        min_ns=meas.ttft_ns[50.0], n=self.n_requests)
        notes = (f"rate={self.rate_rps:g} n={self.n_requests} "
                 f"slots={self.n_slots} seed={self.seed} "
                 f"model={self.cfg.name} "
                 f"pred_ttft_p50_ns={pred.ttft_ns[50.0]:.1f} "
                 f"pred_ttft_p99_ns={pred.ttft_ns[99.0]:.1f} "
                 f"pred_tpot_p50_ns={pred.tpot_ns[50.0]:.1f} "
                 f"pred_tpot_p99_ns={pred.tpot_ns[99.0]:.1f} "
                 f"pred_e2e_p50_ns={pred.e2e_ns[50.0]:.1f} "
                 f"pred_goodput_tok_s={pred.goodput_tok_s:.3f} "
                 f"meas_ttft_p50_ns={meas.ttft_ns[50.0]:.1f} "
                 f"meas_ttft_p99_ns={meas.ttft_ns[99.0]:.1f} "
                 f"meas_tpot_p50_ns={meas.tpot_ns[50.0]:.1f} "
                 f"meas_tpot_p99_ns={meas.tpot_ns[99.0]:.1f} "
                 f"meas_e2e_p50_ns={meas.e2e_ns[50.0]:.1f} "
                 f"meas_goodput_tok_s={meas.goodput_tok_s:.3f} "
                 f"coverage={coverage:.4f}")
        return self._record(ctx, m, notes=notes)
