"""Probe types: the unit of work a :class:`repro.api.Session` schedules.

A probe is one measurement with a stable identity. The identity — the
``(device_kind, backend, jax_version, opt_level, op, dtype)`` tuple — is
exactly a :class:`LatencyRecord` key, which is what makes the session's result
cache work: a probe whose key already exists in the DB is a cache hit and is
never re-run (unless forced).

Concrete probes wrap the existing measurement machinery:

* :class:`InstructionProbe` — one :class:`OpSpec` at one opt level via the
  dependent-chain slope method (paper Table II).
* :class:`MemoryProbe` — the pointer-chase hierarchy probe at one working-set
  size (paper Fig. 6).
* :class:`ClockOverheadProbe` — the cost of the timed region itself at one
  opt level (paper Fig. 5).
* :class:`KernelProbe` — an in-kernel (Pallas) dependent ALU chain, the
  device-side analog of the paper's timed PTX block.

New probe types (energy counters, occupancy sweeps, ...) subclass
:class:`Probe` and immediately gain caching, resumability and structured
failure handling from the session scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

from repro.core import measure, membench
from repro.core.chains import OpSpec
from repro.core.latency_db import LatencyRecord
from repro.core.timing import Measurement, Timer
from repro.utils import timestamp


@dataclasses.dataclass(frozen=True)
class ProbeContext:
    """Session-owned machinery handed to every probe run."""

    timer: Timer
    env: Mapping[str, str]              # device_kind / backend / jax_version
    clock_hz: float
    baseline_ns: Callable[[str], float]  # per-level 1-cycle-class baseline


class Probe:
    """One schedulable measurement. Subclasses set identity + implement run.

    Attributes
    ----------
    op: table row name (e.g. ``"fma.float32"``, ``"mem.chase.ws8192"``).
    opt_level: compilation level the probe measures under.
    dtype: dtype axis of the record key.
    category: table grouping (reuses the paper's categories; new probe kinds
        add their own, e.g. ``"memory"``, ``"overhead"``, ``"kernel"``).
    """

    op: str = ""
    opt_level: str = "O3"
    dtype: str = "float32"
    category: str = "uncategorized"

    def logical_key(self) -> tuple[str, str, str]:
        """Environment-independent identity, used for plan dedupe."""
        return (self.op, self.opt_level, self.dtype)

    def key(self, env: Mapping[str, str]) -> tuple:
        """Full cache key; identical layout to ``LatencyRecord.key()``."""
        return (env["device_kind"], env["backend"], env["jax_version"],
                self.opt_level, self.op, self.dtype)

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        raise NotImplementedError

    # ------------------------------------------------------------------ util
    def _record(self, ctx: ProbeContext, m: Measurement, *, guard: int = 0,
                notes: str = "") -> LatencyRecord:
        """Build the result record from a Measurement, netting out guards."""
        ns = max(m.median_ns, 0.0)
        base = ctx.baseline_ns(self.opt_level) if guard else 0.0
        return LatencyRecord(
            op=self.op, category=self.category, dtype=self.dtype,
            opt_level=self.opt_level, latency_ns=ns, mad_ns=m.mad_ns,
            cycles=ns * ctx.clock_hz / 1e9, guard=guard,
            net_latency_ns=max(ns - guard * base, 0.0), n_samples=m.n,
            measured_at=timestamp(), notes=notes, **ctx.env)

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.op}@{self.opt_level})"


class InstructionProbe(Probe):
    """One registry OpSpec at one opt level (paper Table II row x column)."""

    def __init__(self, spec: OpSpec, opt_level: str = "O3"):
        self.spec = spec
        self.op = spec.name
        self.opt_level = opt_level
        self.dtype = spec.dtype
        self.category = spec.category

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        m = measure.measure_op_full(self.spec, self.opt_level, ctx.timer)
        return self._record(ctx, m, guard=self.spec.guard, notes=self.spec.notes)


class ClockOverheadProbe(Probe):
    """Cost of the timed region itself at one opt level (paper Fig. 5)."""

    category = "overhead"

    def __init__(self, opt_level: str = "O3"):
        self.op = "clock_overhead"
        self.opt_level = opt_level

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        import jax.numpy as jnp

        from repro.core.optlevels import compile_at_level

        x = jnp.asarray(1.0, jnp.float32)
        fn = compile_at_level(lambda v: v, self.opt_level, x)
        m = ctx.timer.time_callable(fn, x, reps=measure._REPS[self.opt_level])
        return self._record(ctx, m, notes="null timed region (Fig. 5 analog)")


class MemoryProbe(Probe):
    """Dependent pointer chase at one working-set size (paper Fig. 6 point).

    Non-default chase parameters are part of the op name (and therefore the
    cache key): a low-fidelity short-chase point must never satisfy a cache
    lookup for the standard-fidelity sweep.
    """

    category = "memory"
    dtype = "int32"
    DEFAULT_STEPS = (2048, 6144)

    def __init__(self, working_set_bytes: int, line_bytes: int = 64,
                 steps: tuple[int, int] = DEFAULT_STEPS):
        self.working_set_bytes = int(working_set_bytes)
        self.line_bytes = line_bytes
        self.steps = tuple(steps)
        self.op = f"mem.chase.ws{self.working_set_bytes}"
        if self.steps != self.DEFAULT_STEPS:
            self.op += f".s{self.steps[0]}-{self.steps[1]}"

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        pt = membench.measure_latency(self.working_set_bytes,
                                      line_bytes=self.line_bytes,
                                      timer=ctx.timer, steps=self.steps)
        m = Measurement(median_ns=pt.latency_ns, mad_ns=0.0,
                        min_ns=pt.latency_ns, n=ctx.timer.reps)
        return self._record(
            ctx, m, notes=f"cold_ns={pt.cold_latency_ns:.3f} "
                          f"stride={pt.stride_bytes}")


class KernelProbe(Probe):
    """In-kernel (Pallas) dependent ALU chain, slope-timed.

    The device-side analog of the paper's timed PTX block: the whole kernel is
    the timed region and the two-length slope cancels DMA/launch overhead.
    Runs in interpret mode on CPU; lowers to a real kernel on TPU.
    """

    category = "kernel"
    DEFAULT_LENS = (8, 64)
    DEFAULT_SHAPE = (8, 128)

    def __init__(self, kernel_op: str = "fma",
                 lens: tuple[int, int] = DEFAULT_LENS,
                 shape: tuple[int, int] = DEFAULT_SHAPE, reps: int = 5):
        self.kernel_op = kernel_op
        self.lens = tuple(lens)
        self.shape = tuple(shape)
        self.reps = reps
        # non-default chain lengths / tile are a different experiment: make
        # them part of the cache identity, like MemoryProbe.steps
        self.op = f"kernel.alu_chain.{kernel_op}"
        if self.lens != self.DEFAULT_LENS:
            self.op += f".l{self.lens[0]}-{self.lens[1]}"
        if self.shape != self.DEFAULT_SHAPE:
            self.op += f".t{self.shape[0]}x{self.shape[1]}"

    def run(self, ctx: ProbeContext) -> LatencyRecord:
        import jax.numpy as jnp

        from repro.kernels.ops import alu_chain

        x = jnp.full(self.shape, 1.0, jnp.float32)
        a = jnp.full(self.shape, 0.5, jnp.float32)

        def fn_by_len(n: int):
            return lambda x, a: alu_chain(x, a, n=n, op=self.kernel_op)

        m = ctx.timer.slope(fn_by_len, *self.lens, x, a, reps=self.reps)
        return self._record(
            ctx, m, notes=f"pallas alu_chain tile={self.shape} lens={self.lens}")
