"""The session: single front door for all characterization runs.

A :class:`Session` owns the pieces every sweep needs exactly once — the
:class:`Timer`, the environment fingerprint, the calibrated clock, the
per-level guard baseline, and a :class:`LatencyDB`-backed result cache — and
executes :class:`Plan`\\ s **incrementally**:

* probes whose cache key already exists in the DB are skipped (``force=True``
  re-measures);
* the DB is flushed to disk after *every* probe, so an interrupted sweep
  resumes for free: re-run the same plan and completed probes are cache hits;
* a probe that raises is recorded as a structured :class:`ProbeFailure` in
  the DB (and superseded when a later run of the same probe succeeds) instead
  of vanishing into a log line. ``KeyboardInterrupt`` is *not* swallowed —
  partial results are already on disk.

A session may be **pinned to one device** (``Session(device=...)``): the
environment fingerprint, the timer, the guard baseline and every probe
execution then derive from that device instead of the process default.
:meth:`Session.fan_out` builds on this to shard a plan across all local
devices — one pinned session per device, probes sequential within each
(timing must not contend), per-shard DBs merged on completion.

Typical use::

    from repro.api import Plan, Session

    session = Session(db="/tmp/latency_db.json")
    result = session.run(Plan.instructions(opt_levels=("O0", "O3"))
                         + Plan.memory())
    print(result.summary())
    print(result.table_markdown())

    # multi-device: same records, wall-clock / n_devices
    result = session.fan_out(Plan.instructions())
"""
from __future__ import annotations

import concurrent.futures
import contextlib
import dataclasses

import jax

from repro.core import chains, measure
from repro.core.latency_db import (LatencyDB, LatencyRecord, ProbeFailure,
                                   current_environment)
from repro.core.timing import Timer
from repro.utils import logger, timestamp

from repro.api.plan import Plan
from repro.api.probes import Probe, ProbeContext


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """Outcome of one scheduled probe."""

    probe: Probe
    status: str                        # "measured" | "cached" | "failed"
    record: LatencyRecord | None = None
    failure: ProbeFailure | None = None


@dataclasses.dataclass
class ResultSet:
    """Per-probe outcomes of one ``Session.run``, in plan order."""

    results: list[ProbeResult]
    db: LatencyDB

    @property
    def measured(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "measured"]

    @property
    def cached(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "cached"]

    @property
    def failed(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "failed"]

    def records(self) -> list[LatencyRecord]:
        return [r.record for r in self.results if r.record is not None]

    def summary(self) -> str:
        return (f"{len(self.measured)} measured, {len(self.cached)} cached, "
                f"{len(self.failed)} failed ({len(self.results)} probes)")

    def table_markdown(self, opt_levels: tuple[str, ...] = ("O3", "O0")) -> str:
        return self.db.table_markdown(opt_levels=opt_levels)

    def __len__(self) -> int:
        return len(self.results)


class Session:
    """Cache-aware scheduler over a LatencyDB (see module docstring).

    Parameters
    ----------
    db: a :class:`LatencyDB`, a path to one (loaded if present, created on
        first flush), or None for an in-memory DB.
    timer: shared :class:`Timer`; defaults to the standard calibration.
    force: re-measure cache hits by default (per-run ``force`` overrides).
    device: pin the session to one jax device (a ``jax.Device`` or an index
        into ``jax.devices()``). The environment fingerprint, every probe
        execution, the timer and the guard baseline all derive from *this*
        device; ``None`` keeps the process default (single-device behavior).
    """

    def __init__(self, db: LatencyDB | str | None = None,
                 timer: Timer | None = None, force: bool = False,
                 device=None):
        if isinstance(device, int):
            device = jax.devices()[device]
        self.device = device
        self.db = db if isinstance(db, LatencyDB) else LatencyDB(path=db)
        self.timer = timer or Timer()
        if self.device is not None:
            if self.timer.device is None:
                self.timer.device = self.device
            elif self.timer.device != self.device:
                # a timer calibrated/pinned on another device would silently
                # override this session's pin inside time_callable
                raise ValueError(
                    f"timer is pinned to {self.timer.device}, session to "
                    f"{self.device}; give each pinned session its own timer")
        self.force = force
        self.env = current_environment(device)
        self._baseline: dict[tuple, float] = {}

    def _device_ctx(self):
        """Scope in which all of this session's jax work runs."""
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    def _device_token(self):
        """Hashable identity of the pinned device for in-session caches."""
        return None if self.device is None else (self.env["backend"],
                                                 self.device.id)

    # ------------------------------------------------------------- baseline
    def baseline_ns(self, opt_level: str, use_db: bool = True) -> float:
        """Per-level 1-cycle-class baseline used to net out guard ops.

        The ``add`` spec is an (add ^ xor) pair in the same latency class, so
        baseline = measured_pair / (1 + guard). Derived from the DB when the
        pair is already cached (and ``use_db``); measured (and cached
        in-session) otherwise. Forced runs pass ``use_db=False`` so a stale
        cached baseline is never mixed into fresh measurements. The cache is
        partitioned by the pinned device: fan-out shards must never share a
        baseline measured on a different device.
        """
        cache_key = (self._device_token(), opt_level, use_db)
        if cache_key not in self._baseline:
            base = next((o for o in chains.default_registry()
                         if o.name == "add"), None)
            if base is None:
                self._baseline[cache_key] = 0.0
            else:
                rec = self.db.get((self.env["device_kind"], self.env["backend"],
                                   self.env["jax_version"], opt_level,
                                   base.name, base.dtype)) if use_db else None
                if rec is not None:
                    ns = rec.latency_ns
                else:
                    with self._device_ctx():
                        ns = measure.measure_op(base, opt_level, self.timer)
                self._baseline[cache_key] = ns / (1 + base.guard)
        return self._baseline[cache_key]

    def _context(self, force: bool = False) -> ProbeContext:
        return ProbeContext(timer=self.timer, env=self.env,
                            clock_hz=self.timer.calibrate_clock_hz(),
                            baseline_ns=lambda lv: self.baseline_ns(
                                lv, use_db=not force),
                            device=self.device, db=self.db)

    # ------------------------------------------------------------ execution
    def run(self, plan: Plan, force: bool | None = None) -> ResultSet:
        """Execute a plan incrementally; returns per-probe outcomes.

        Probes run sequentially (timing probes must not contend with each
        other). After every measured/failed probe the DB is flushed to its
        path, so interrupting a sweep loses at most the in-flight probe.
        """
        force = self.force if force is None else force
        plan = plan.dedupe()
        ctx = self._context(force=force)
        results: list[ProbeResult] = []
        for probe in plan:
            key = probe.key(self.env)
            if not force and key in self.db:
                results.append(ProbeResult(probe, "cached", record=self.db.get(key)))
                logger.debug("cached   %-28s", probe.op + "@" + probe.opt_level)
                continue
            try:
                with self._device_ctx():
                    rec = probe.run(ctx)
            except Exception as e:  # noqa: BLE001 - recorded as structured failure
                failure = ProbeFailure(
                    op=probe.op, dtype=probe.dtype, opt_level=probe.opt_level,
                    error_type=type(e).__name__, message=str(e),
                    failed_at=timestamp(), **self.env)
                self.db.add_failure(failure)
                results.append(ProbeResult(probe, "failed", failure=failure))
                logger.warning("probe %s@%s failed: %s: %s", probe.op,
                               probe.opt_level, type(e).__name__, e)
            else:
                self.db.add(rec)
                results.append(ProbeResult(probe, "measured", record=rec))
                logger.info("measured %-28s %8.1fns (±%.1f)",
                            f"{probe.op}@{probe.opt_level}", rec.latency_ns,
                            rec.mad_ns)
            self._flush()
        return ResultSet(results=results, db=self.db)

    def _flush(self) -> None:
        if self.db.path:
            self.db.save()

    # -------------------------------------------------------------- fan-out
    def fan_out(self, plan: Plan, devices=None, force: bool | None = None
                ) -> ResultSet:
        """Shard ``plan`` across devices; one pinned Session per device.

        The plan is dealt round-robin over ``devices`` (default: all of
        ``jax.local_devices()``) via :meth:`Plan.shard`; each shard runs in
        its own thread through a device-pinned Session. Probes stay
        sequential *within* a device — timing probes must not contend for
        the hardware they are measuring — so wall-clock scales with the
        device count while each measurement still sees an idle device.

        Every shard flushes to this session's DB path (safe: ``save`` is an
        atomic read-merge-write), and on completion the shard DBs are merged
        into ``self.db`` under :meth:`LatencyDB.merge` rules. Returns one
        :class:`ResultSet` with all shard outcomes in shard order.
        """
        devices = list(devices) if devices is not None else jax.local_devices()
        if not devices:
            raise ValueError("fan_out needs at least one device")
        force = self.force if force is None else force
        plan = plan.dedupe()
        shards = plan.shard(len(devices))
        # calibrate once, serially: the spin-loop calibration under N
        # concurrent shard threads would be GIL-inflated ~N-fold, skewing
        # every record's cycles field versus a serial run
        clock_hz = self.timer.calibrate_clock_hz()
        sessions = [
            Session(db=LatencyDB(path=self.db.path),
                    timer=Timer(warmup=self.timer.warmup, reps=self.timer.reps,
                                clock_hz=clock_hz, device=dev),
                    force=force, device=dev)
            for dev in devices]
        logger.info("fan-out: plan '%s' (%d probes) over %d device(s)",
                    plan.name, len(plan), len(devices))
        with concurrent.futures.ThreadPoolExecutor(
                max_workers=len(devices),
                thread_name_prefix="repro-shard") as pool:
            futures = [pool.submit(sess.run, shard, force)
                       for sess, shard in zip(sessions, shards) if len(shard)]
            shard_results = [f.result() for f in futures]
        self.db.merge(*(sess.db for sess in sessions))
        self._flush()
        return ResultSet(
            results=[r for rs in shard_results for r in rs.results],
            db=self.db)
