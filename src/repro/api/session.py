"""The session: single front door for all characterization runs.

A :class:`Session` owns the pieces every sweep needs exactly once — the
:class:`Timer`, the environment fingerprint, the calibrated clock, the
per-level guard baseline, and a :class:`LatencyDB`-backed result cache — and
executes :class:`Plan`\\ s **incrementally**:

* probes whose cache key already exists in the DB are skipped (``force=True``
  re-measures);
* after every measured/failed probe the new rows are appended to the DB's
  journal (:meth:`LatencyDB.flush` — a delta write, not a whole-file
  rewrite), so an interrupted sweep resumes for free: re-run the same plan
  and completed probes are cache hits; the run's final ``save`` compacts the
  journal into one atomic whole-file write;
* a probe that raises is recorded as a structured :class:`ProbeFailure` in
  the DB (and superseded when a later run of the same probe succeeds) instead
  of vanishing into a log line. ``KeyboardInterrupt`` is *not* swallowed —
  partial results are already on disk.

Runs are **pipelined** by default (``pipeline=False`` for strictly serial
execution): a single background compile thread runs probe N+1's
:meth:`Probe.prepare` (lowering, XLA compiles, compile-cache loads) while
probe N's :meth:`Probe.run_prepared` times on the main thread — timing stays
strictly serial on the device, only compilation overlaps it. With a
persistent :class:`~repro.core.compile_cache.CompileCache` attached
(``compile_cache=...``), re-runs skip XLA entirely; with
``adaptive=True``, quiet rows stop repeating once their MAD/median
converges and the saved reps are spent on noisy ones
(:class:`~repro.core.timing.AdaptiveFidelity`). See docs/performance.md.

A session may be **pinned to one device** (``Session(device=...)``): the
environment fingerprint, the timer, the guard baseline and every probe
execution then derive from that device instead of the process default.
:meth:`Session.fan_out` builds on this to shard a plan across all local
devices — one pinned session per device, probes sequential within each
(timing must not contend), per-shard DBs merged on completion.

Typical use::

    from repro.api import Plan, Session

    session = Session(db="/tmp/latency_db.json")
    result = session.run(Plan.instructions(opt_levels=("O0", "O3"))
                         + Plan.memory())
    print(result.summary())
    print(result.table_markdown())

    # multi-device: same records, wall-clock / n_devices
    result = session.fan_out(Plan.instructions())
"""
from __future__ import annotations

import concurrent.futures
import contextlib
import dataclasses
import time
from typing import Any

import jax

from repro.core import chains, measure
from repro.core.compile_cache import CompileCache
from repro.core.latency_db import (LatencyDB, LatencyRecord, ProbeFailure,
                                   current_environment)
from repro.core.timing import AdaptiveFidelity, Timer
from repro.utils import logger, timestamp

from repro.api.plan import Plan
from repro.api.probes import Probe, ProbeContext


def _prepare_probe(probe: Probe, ctx: ProbeContext) -> Any:
    """Probe's XLA-bound half. Probes are duck-typed: one that predates the
    prepare/run_prepared split (only implements ``run``) prepares nothing."""
    prep = getattr(probe, "prepare", None)
    return prep(ctx) if prep is not None else None


def _execute_probe(probe: Probe, ctx: ProbeContext, prepared: Any):
    run_prepared = getattr(probe, "run_prepared", None)
    if run_prepared is not None:
        return run_prepared(ctx, prepared)
    return probe.run(ctx)


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """Outcome of one scheduled probe."""

    probe: Probe
    status: str                        # "measured" | "cached" | "failed"
    record: LatencyRecord | None = None
    failure: ProbeFailure | None = None


@dataclasses.dataclass
class ResultSet:
    """Per-probe outcomes of one ``Session.run``, in plan order."""

    results: list[ProbeResult]
    db: LatencyDB
    # wall-clock attribution for this run: {"compile", "time", "flush"} in ns
    stage_ns: dict = dataclasses.field(default_factory=dict)
    # CompileCache hit/compile counters for THIS run (a delta, not the
    # cache's lifetime totals); None when no cache was configured
    cache_stats: Any = None

    @property
    def measured(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "measured"]

    @property
    def cached(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "cached"]

    @property
    def failed(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "failed"]

    def records(self) -> list[LatencyRecord]:
        return [r.record for r in self.results if r.record is not None]

    def summary(self) -> str:
        s = (f"{len(self.measured)} measured, {len(self.cached)} cached, "
             f"{len(self.failed)} failed ({len(self.results)} probes)")
        if self.cache_stats is not None:
            st = self.cache_stats
            s += f", compile cache: {st.hits} hits, {st.misses} compiled"
        return s

    def table_markdown(self, opt_levels: tuple[str, ...] = ("O3", "O0")) -> str:
        return self.db.table_markdown(opt_levels=opt_levels)

    def __len__(self) -> int:
        return len(self.results)


class Session:
    """Cache-aware scheduler over a LatencyDB (see module docstring).

    Parameters
    ----------
    db: a :class:`LatencyDB`, a path to one (loaded if present, created on
        first flush), or None for an in-memory DB.
    timer: shared :class:`Timer`; defaults to the standard calibration.
    force: re-measure cache hits by default (per-run ``force`` overrides).
    device: pin the session to one jax device (a ``jax.Device`` or an index
        into ``jax.devices()``). The environment fingerprint, every probe
        execution, the timer and the guard baseline all derive from *this*
        device; ``None`` keeps the process default (single-device behavior).
    compile_cache: a :class:`CompileCache`, a directory path for one, or
        None (no executable persistence). Shared across fan-out shards.
    adaptive: True for default :class:`AdaptiveFidelity`, an instance for
        custom thresholds, or None/False to keep fixed rep counts.
    pipeline: overlap probe N+1's compile with probe N's timing (default).
        ``False`` restores strictly serial prepare-then-run execution; the
        measured values are identical either way (only compilation is
        overlapped, never timing).
    audit: statically verify each probe's compiled artifact as it is
        prepared (``repro.audit``: chain count, guard accounting, dependent
        path) and attach the verdict to the record's notes
        (``audit=ok`` / ``audit=transformed:<cause>`` / ...). Runs on the
        compile thread, never the timing thread. Off by default; a failed
        verdict only flags the record — ``python -m repro audit --strict``
        turns flags into a failing exit.
    """

    def __init__(self, db: LatencyDB | str | None = None,
                 timer: Timer | None = None, force: bool = False,
                 device=None, compile_cache: CompileCache | str | None = None,
                 adaptive: AdaptiveFidelity | bool | None = None,
                 pipeline: bool = True, audit: bool = False):
        if isinstance(device, int):
            device = jax.devices()[device]
        self.device = device
        self.db = db if isinstance(db, LatencyDB) else LatencyDB(path=db)
        self.timer = timer or Timer()
        if self.device is not None:
            if self.timer.device is None:
                self.timer.device = self.device
            elif self.timer.device != self.device:
                # a timer calibrated/pinned on another device would silently
                # override this session's pin inside time_callable
                raise ValueError(
                    f"timer is pinned to {self.timer.device}, session to "
                    f"{self.device}; give each pinned session its own timer")
        if isinstance(compile_cache, str):
            compile_cache = CompileCache(compile_cache)
        self.compile_cache = compile_cache
        if adaptive is True:
            adaptive = AdaptiveFidelity()
        elif adaptive is False:
            adaptive = None
        self.adaptive = adaptive
        if adaptive is not None:
            self.timer.adaptive = adaptive
        self.pipeline = pipeline
        self.audit = audit
        self.force = force
        self.env = current_environment(device)
        self._baseline: dict[tuple, float] = {}

    def _device_ctx(self):
        """Scope in which all of this session's jax work runs."""
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    def _device_token(self):
        """Hashable identity of the pinned device for in-session caches."""
        return None if self.device is None else (self.env["backend"],
                                                 self.device.id)

    # ------------------------------------------------------------- baseline
    def baseline_ns(self, opt_level: str, use_db: bool = True) -> float:
        """Per-level 1-cycle-class baseline used to net out guard ops.

        The ``add`` spec is an (add ^ xor) pair in the same latency class, so
        baseline = measured_pair / (1 + guard). Derived from the DB when the
        pair is already cached (and ``use_db``); measured (and cached
        in-session) otherwise. Forced runs pass ``use_db=False`` so a stale
        cached baseline is never mixed into fresh measurements. The cache is
        partitioned by the pinned device: fan-out shards must never share a
        baseline measured on a different device.
        """
        cache_key = (self._device_token(), opt_level, use_db)
        if cache_key not in self._baseline:
            base = next((o for o in chains.default_registry()
                         if o.name == "add"), None)
            if base is None:
                self._baseline[cache_key] = 0.0
            else:
                rec = self.db.get((self.env["device_kind"], self.env["backend"],
                                   self.env["jax_version"], opt_level,
                                   base.name, base.dtype)) if use_db else None
                if rec is not None:
                    ns = rec.latency_ns
                else:
                    with self._device_ctx():
                        ns = measure.measure_op(base, opt_level, self.timer)
                self._baseline[cache_key] = ns / (1 + base.guard)
        return self._baseline[cache_key]

    def _context(self, force: bool = False) -> ProbeContext:
        return ProbeContext(timer=self.timer, env=self.env,
                            clock_hz=self.timer.calibrate_clock_hz(),
                            baseline_ns=lambda lv: self.baseline_ns(
                                lv, use_db=not force),
                            device=self.device, db=self.db,
                            compile_cache=self.compile_cache,
                            adaptive=self.adaptive is not None)

    # ------------------------------------------------------------ execution
    def run(self, plan: Plan, force: bool | None = None,
            pipeline: bool | None = None) -> ResultSet:
        """Execute a plan incrementally; returns per-probe outcomes.

        Timing runs strictly sequentially on the main thread (timing probes
        must not contend with each other). In pipelined mode a single
        background thread runs the *next* probe's ``prepare`` (compiles)
        while the current probe times. After every measured/failed probe the
        new rows are journal-appended to the DB path (cheap delta flush), so
        interrupting a sweep loses at most the in-flight probe; a completed
        run compacts the journal into the main DB file.
        """
        force = self.force if force is None else force
        pipeline = self.pipeline if pipeline is None else pipeline
        plan = plan.dedupe()
        ctx = self._context(force=force)
        probes = list(plan)
        results: dict[int, ProbeResult] = {}
        pending: list[tuple[int, Probe]] = []
        for i, probe in enumerate(probes):
            key = probe.key(self.env)
            if not force and key in self.db:
                results[i] = ProbeResult(probe, "cached", record=self.db.get(key))
                logger.debug("cached   %-28s", probe.op + "@" + probe.opt_level)
            else:
                pending.append((i, probe))
        stage_ns = {"compile": 0, "time": 0, "flush": 0}
        stats0 = (dataclasses.replace(self.compile_cache.stats)
                  if self.compile_cache is not None else None)
        if pending:
            if pipeline and len(pending) > 1:
                self._run_pipelined(pending, ctx, results, stage_ns)
            else:
                self._run_serial(pending, ctx, results, stage_ns)
        if self.db.path:
            t0 = time.perf_counter_ns()
            self.db.save()  # compact the journal into one atomic write
            stage_ns["flush"] += time.perf_counter_ns() - t0
        cache_stats = None
        if stats0 is not None:
            now = self.compile_cache.stats
            cache_stats = dataclasses.replace(
                now, hits=now.hits - stats0.hits,
                misses=now.misses - stats0.misses,
                stores=now.stores - stats0.stores,
                evictions=now.evictions - stats0.evictions,
                errors=now.errors - stats0.errors)
        return ResultSet(results=[results[i] for i in range(len(probes))],
                         db=self.db, stage_ns=stage_ns,
                         cache_stats=cache_stats)

    def _audit_for(self, probe: Probe):
        """Static integrity verdict for one probe's artifact (compile-side).

        Runs right after ``prepare`` so the compile cache's optimized-HLO
        sidecars are warm and the audit never re-invokes XLA for a cached
        chain. Any auditor error degrades to no verdict — auditing must
        never turn a measurable probe into a failure.
        """
        if not self.audit:
            return None
        try:
            from repro.audit import audit_target

            return audit_target(probe.op, probe.opt_level,
                                cache=self.compile_cache, env=self.env)
        except Exception as e:  # noqa: BLE001 - advisory only
            logger.warning("audit of %s@%s errored: %s", probe.op,
                           probe.opt_level, e)
            return None

    def _run_serial(self, pending, ctx, results, stage_ns) -> None:
        """prepare + run_prepared inline, one probe at a time."""
        for i, probe in pending:
            t0 = time.perf_counter_ns()
            prepared, exc, verdict = None, None, None
            try:
                with self._device_ctx():
                    prepared = _prepare_probe(probe, ctx)
                    verdict = self._audit_for(probe)
            except Exception as e:  # noqa: BLE001 - structured failure below
                exc = e
            stage_ns["compile"] += time.perf_counter_ns() - t0
            self._finish_probe(i, probe, ctx, prepared, exc, results, stage_ns,
                               verdict=verdict)

    def _run_pipelined(self, pending, ctx, results, stage_ns) -> None:
        """Compile-ahead: the worker prepares probe N+1 while N times.

        One worker thread, and ``prepare`` only compiles — all timing stays
        on the main thread, so probes never contend for the device while
        being measured. The ``jax.default_device`` scope is thread-local and
        therefore re-entered inside the worker task.
        """
        def _prepare(probe: Probe):
            t0 = time.perf_counter_ns()
            try:
                with self._device_ctx():
                    prepared = _prepare_probe(probe, ctx)
                    verdict = self._audit_for(probe)
                return prepared, None, verdict, time.perf_counter_ns() - t0
            except Exception as e:  # noqa: BLE001 - structured failure later
                return None, e, None, time.perf_counter_ns() - t0

        pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-compile")
        try:
            fut = pool.submit(_prepare, pending[0][1])
            for j, (i, probe) in enumerate(pending):
                cur = fut
                if j + 1 < len(pending):
                    # enqueue the next compile BEFORE waiting on this one:
                    # the worker moves straight on to probe N+1 while the
                    # main thread times probe N below
                    fut = pool.submit(_prepare, pending[j + 1][1])
                prepared, exc, verdict, compile_ns = cur.result()
                stage_ns["compile"] += compile_ns
                self._finish_probe(i, probe, ctx, prepared, exc, results,
                                   stage_ns, verdict=verdict)
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    def _finish_probe(self, i, probe, ctx, prepared, exc, results,
                      stage_ns, verdict=None) -> None:
        """Time one prepared probe on the main thread and record the outcome."""
        if exc is None:
            t0 = time.perf_counter_ns()
            try:
                with self._device_ctx():
                    rec = _execute_probe(probe, ctx, prepared)
            except Exception as e:  # noqa: BLE001 - recorded as failure
                exc = e
            else:
                if verdict is not None:
                    note = verdict.note()
                    rec = dataclasses.replace(
                        rec, notes=f"{rec.notes} {note}".strip())
                    if verdict.failed:
                        logger.warning("audit: %s@%s %s (%s)", probe.op,
                                       probe.opt_level, note, verdict.detail)
                self.db.add(rec)
                results[i] = ProbeResult(probe, "measured", record=rec)
                logger.info("measured %-28s %8.1fns (±%.1f)",
                            f"{probe.op}@{probe.opt_level}", rec.latency_ns,
                            rec.mad_ns)
            stage_ns["time"] += time.perf_counter_ns() - t0
        if exc is not None:
            failure = ProbeFailure(
                op=probe.op, dtype=probe.dtype, opt_level=probe.opt_level,
                error_type=type(exc).__name__, message=str(exc),
                failed_at=timestamp(), **self.env)
            self.db.add_failure(failure)
            results[i] = ProbeResult(probe, "failed", failure=failure)
            logger.warning("probe %s@%s failed: %s: %s", probe.op,
                           probe.opt_level, type(exc).__name__, exc)
        t0 = time.perf_counter_ns()
        self._flush()
        stage_ns["flush"] += time.perf_counter_ns() - t0

    def _flush(self) -> None:
        """Per-probe durability point: journal-append the new rows only."""
        if self.db.path:
            self.db.flush()

    # -------------------------------------------------------------- fan-out
    def fan_out(self, plan: Plan, devices=None, force: bool | None = None
                ) -> ResultSet:
        """Shard ``plan`` across devices; one pinned Session per device.

        The plan is dealt round-robin over ``devices`` (default: all of
        ``jax.local_devices()``) via :meth:`Plan.shard`; each shard runs in
        its own thread through a device-pinned Session. Probes stay
        sequential *within* a device — timing probes must not contend for
        the hardware they are measuring — so wall-clock scales with the
        device count while each measurement still sees an idle device.

        Every shard flushes to this session's DB path (safe: ``save`` is an
        atomic read-merge-write), and on completion the shard DBs are merged
        into ``self.db`` under :meth:`LatencyDB.merge` rules. Returns one
        :class:`ResultSet` with all shard outcomes in shard order.
        """
        devices = list(devices) if devices is not None else jax.local_devices()
        if not devices:
            raise ValueError("fan_out needs at least one device")
        force = self.force if force is None else force
        plan = plan.dedupe()
        shards = plan.shard(len(devices))
        # calibrate once, serially: the spin-loop calibration under N
        # concurrent shard threads would be GIL-inflated ~N-fold, skewing
        # every record's cycles field versus a serial run
        clock_hz = self.timer.calibrate_clock_hz()
        sessions = [
            Session(db=LatencyDB(path=self.db.path),
                    timer=Timer(warmup=self.timer.warmup, reps=self.timer.reps,
                                clock_hz=clock_hz, device=dev,
                                adaptive=self.adaptive),
                    force=force, device=dev,
                    compile_cache=self.compile_cache,  # thread-safe, shared
                    adaptive=self.adaptive, pipeline=self.pipeline)
            for dev in devices]
        logger.info("fan-out: plan '%s' (%d probes) over %d device(s)",
                    plan.name, len(plan), len(devices))
        with concurrent.futures.ThreadPoolExecutor(
                max_workers=len(devices),
                thread_name_prefix="repro-shard") as pool:
            futures = [pool.submit(sess.run, shard, force)
                       for sess, shard in zip(sessions, shards) if len(shard)]
            shard_results = [f.result() for f in futures]
        self.db.merge(*(sess.db for sess in sessions))
        if self.db.path:
            self.db.save()  # compaction: one atomic whole-file write
        return ResultSet(
            results=[r for rs in shard_results for r in rs.results],
            db=self.db)
