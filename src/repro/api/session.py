"""The session: single front door for all characterization runs.

A :class:`Session` owns the pieces every sweep needs exactly once — the
:class:`Timer`, the environment fingerprint, the calibrated clock, the
per-level guard baseline, and a :class:`LatencyDB`-backed result cache — and
executes :class:`Plan`\\ s **incrementally**:

* probes whose cache key already exists in the DB are skipped (``force=True``
  re-measures);
* the DB is flushed to disk after *every* probe, so an interrupted sweep
  resumes for free: re-run the same plan and completed probes are cache hits;
* a probe that raises is recorded as a structured :class:`ProbeFailure` in
  the DB (and superseded when a later run of the same probe succeeds) instead
  of vanishing into a log line. ``KeyboardInterrupt`` is *not* swallowed —
  partial results are already on disk.

Typical use::

    from repro.api import Plan, Session

    session = Session(db="/tmp/latency_db.json")
    result = session.run(Plan.instructions(opt_levels=("O0", "O3"))
                         + Plan.memory())
    print(result.summary())
    print(result.table_markdown())
"""
from __future__ import annotations

import dataclasses

from repro.core import chains, measure
from repro.core.latency_db import (LatencyDB, LatencyRecord, ProbeFailure,
                                   current_environment)
from repro.core.timing import Timer
from repro.utils import logger, timestamp

from repro.api.plan import Plan
from repro.api.probes import Probe, ProbeContext


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """Outcome of one scheduled probe."""

    probe: Probe
    status: str                        # "measured" | "cached" | "failed"
    record: LatencyRecord | None = None
    failure: ProbeFailure | None = None


@dataclasses.dataclass
class ResultSet:
    """Per-probe outcomes of one ``Session.run``, in plan order."""

    results: list[ProbeResult]
    db: LatencyDB

    @property
    def measured(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "measured"]

    @property
    def cached(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "cached"]

    @property
    def failed(self) -> list[ProbeResult]:
        return [r for r in self.results if r.status == "failed"]

    def records(self) -> list[LatencyRecord]:
        return [r.record for r in self.results if r.record is not None]

    def summary(self) -> str:
        return (f"{len(self.measured)} measured, {len(self.cached)} cached, "
                f"{len(self.failed)} failed ({len(self.results)} probes)")

    def table_markdown(self, opt_levels: tuple[str, ...] = ("O3", "O0")) -> str:
        return self.db.table_markdown(opt_levels=opt_levels)

    def __len__(self) -> int:
        return len(self.results)


class Session:
    """Cache-aware scheduler over a LatencyDB (see module docstring).

    Parameters
    ----------
    db: a :class:`LatencyDB`, a path to one (loaded if present, created on
        first flush), or None for an in-memory DB.
    timer: shared :class:`Timer`; defaults to the standard calibration.
    force: re-measure cache hits by default (per-run ``force`` overrides).
    """

    def __init__(self, db: LatencyDB | str | None = None,
                 timer: Timer | None = None, force: bool = False):
        self.db = db if isinstance(db, LatencyDB) else LatencyDB(path=db)
        self.timer = timer or Timer()
        self.force = force
        self.env = current_environment()
        self._baseline: dict[tuple[str, bool], float] = {}

    # ------------------------------------------------------------- baseline
    def baseline_ns(self, opt_level: str, use_db: bool = True) -> float:
        """Per-level 1-cycle-class baseline used to net out guard ops.

        The ``add`` spec is an (add ^ xor) pair in the same latency class, so
        baseline = measured_pair / (1 + guard). Derived from the DB when the
        pair is already cached (and ``use_db``); measured (and cached
        in-session) otherwise. Forced runs pass ``use_db=False`` so a stale
        cached baseline is never mixed into fresh measurements.
        """
        cache_key = (opt_level, use_db)
        if cache_key not in self._baseline:
            base = next((o for o in chains.default_registry()
                         if o.name == "add"), None)
            if base is None:
                self._baseline[cache_key] = 0.0
            else:
                rec = self.db.get((self.env["device_kind"], self.env["backend"],
                                   self.env["jax_version"], opt_level,
                                   base.name, base.dtype)) if use_db else None
                ns = rec.latency_ns if rec is not None else measure.measure_op(
                    base, opt_level, self.timer)
                self._baseline[cache_key] = ns / (1 + base.guard)
        return self._baseline[cache_key]

    def _context(self, force: bool = False) -> ProbeContext:
        return ProbeContext(timer=self.timer, env=self.env,
                            clock_hz=self.timer.calibrate_clock_hz(),
                            baseline_ns=lambda lv: self.baseline_ns(
                                lv, use_db=not force))

    # ------------------------------------------------------------ execution
    def run(self, plan: Plan, force: bool | None = None) -> ResultSet:
        """Execute a plan incrementally; returns per-probe outcomes.

        Probes run sequentially (timing probes must not contend with each
        other). After every measured/failed probe the DB is flushed to its
        path, so interrupting a sweep loses at most the in-flight probe.
        """
        force = self.force if force is None else force
        plan = plan.dedupe()
        ctx = self._context(force=force)
        results: list[ProbeResult] = []
        for probe in plan:
            key = probe.key(self.env)
            if not force and key in self.db:
                results.append(ProbeResult(probe, "cached", record=self.db.get(key)))
                logger.debug("cached   %-28s", probe.op + "@" + probe.opt_level)
                continue
            try:
                rec = probe.run(ctx)
            except Exception as e:  # noqa: BLE001 - recorded as structured failure
                failure = ProbeFailure(
                    op=probe.op, dtype=probe.dtype, opt_level=probe.opt_level,
                    error_type=type(e).__name__, message=str(e),
                    failed_at=timestamp(), **self.env)
                self.db.add_failure(failure)
                results.append(ProbeResult(probe, "failed", failure=failure))
                logger.warning("probe %s@%s failed: %s: %s", probe.op,
                               probe.opt_level, type(e).__name__, e)
            else:
                self.db.add(rec)
                results.append(ProbeResult(probe, "measured", record=rec))
                logger.info("measured %-28s %8.1fns (±%.1f)",
                            f"{probe.op}@{probe.opt_level}", rec.latency_ns,
                            rec.mad_ns)
            self._flush()
        return ResultSet(results=results, db=self.db)

    def _flush(self) -> None:
        if self.db.path:
            self.db.save()
