"""``python -m repro characterize`` — the full paper reproduction, one command.

Examples::

    python -m repro characterize --plan quick --db /tmp/db.json
    python -m repro characterize --plan quick --db /tmp/db.json   # all cache hits
    python -m repro characterize --plan full --db /tmp/db.json --force
    python -m repro characterize --plan table2 --ops add,mul --table
    python -m repro characterize --plan inkernel --table   # in-pipeline probes

Scheduling is cache-aware by default: probes already in the DB for this
(device, backend, jax version) are reported as cache hits and skipped, which
is also what makes interrupted sweeps resumable — partial results are flushed
after every probe, so re-running the same command picks up where it stopped.
"""
from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.api.plan import PLAN_NAMES, named_plan
from repro.api.session import Session
from repro.core.timing import Timer


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Instruction/memory latency characterization (paper pipeline).")
    sub = ap.add_subparsers(dest="command", required=True)

    ch = sub.add_parser("characterize",
                        help="run a characterization plan into a LatencyDB")
    ch.add_argument("--plan", choices=PLAN_NAMES, default="quick",
                    help="named probe plan (default: quick)")
    ch.add_argument("--db", default="/tmp/latency_db.json",
                    help="LatencyDB JSON path (loaded if present; flushed "
                         "after every probe)")
    ch.add_argument("--force", action="store_true",
                    help="re-measure probes already in the DB")
    ch.add_argument("--resume", action="store_true",
                    help="skip probes already in the DB (the default; flag "
                         "kept for explicit scripts)")
    ch.add_argument("--ops", default=None,
                    help="comma-separated op filter applied to the plan "
                         "(e.g. add,mul,clock_overhead)")
    ch.add_argument("--opt-levels", default=None,
                    help="comma-separated opt-level filter (e.g. O0,O3)")
    ch.add_argument("--table", action="store_true",
                    help="print the Table II analog after the run (plus the "
                         "dispatch-vs-in-kernel pairing when the DB holds "
                         "inkernel.* records)")
    ch.add_argument("--recover", action="store_true",
                    help="salvage complete records from a truncated/corrupt "
                         "DB file instead of refusing to load it")
    ch.add_argument("--warmup", type=int, default=2)
    ch.add_argument("--reps", type=int, default=10,
                    help="timed repetitions per measurement point")
    ch.set_defaults(func=cmd_characterize)
    return ap


def cmd_characterize(args: argparse.Namespace) -> int:
    if args.force and args.resume:
        print("error: --force and --resume are mutually exclusive",
              file=sys.stderr)
        return 2
    plan = named_plan(args.plan)
    if args.ops:
        plan = plan.filter(ops=[o.strip() for o in args.ops.split(",")])
    if args.opt_levels:
        plan = plan.filter(opt_levels=[l.strip() for l in args.opt_levels.split(",")])
    if not len(plan):
        print("error: plan is empty after filters", file=sys.stderr)
        return 2

    try:
        from repro.core.latency_db import LatencyDB

        db = LatencyDB.recover(args.db) if args.recover else args.db
        session = Session(db=db,
                          timer=Timer(warmup=args.warmup, reps=args.reps))
    except Exception as e:  # unreadable/corrupt DB file: report, don't clobber
        print(f"error: could not load DB {args.db}: {type(e).__name__}: {e} "
              "(pass --recover to salvage complete records)", file=sys.stderr)
        return 2
    print(f"plan '{plan.name}': {len(plan)} probes -> {args.db} "
          f"[{session.env['backend']}/{session.env['device_kind']}, "
          f"jax {session.env['jax_version']}]")
    result = session.run(plan, force=args.force)

    print(f"plan '{plan.name}': {result.summary()}")
    if result.cached and not result.measured and not result.failed:
        print("all probes were cache hits; pass --force to re-measure")
    for r in result.failed:
        f = r.failure
        print(f"  FAILED {f.op}@{f.opt_level}: {f.error_type}: {f.message}")
    if args.table:
        print()
        print(result.table_markdown())
        compare = session.db.compare_markdown()
        if compare.count("\n") > 1:  # header + separator + >=1 paired row
            print("\n== dispatch vs in-kernel (paper's in-pipeline method) ==")
            print(compare)
    return 1 if result.failed else 0


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
