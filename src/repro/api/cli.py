"""``python -m repro characterize`` — the full paper reproduction, one command.

Examples::

    python -m repro characterize --plan quick --db /tmp/db.json
    python -m repro characterize --plan quick --db /tmp/db.json   # all cache hits
    python -m repro characterize --plan full --db /tmp/db.json --force
    python -m repro characterize --plan table2 --ops add,mul --table
    python -m repro characterize --plan inkernel --table   # in-pipeline probes
    python -m repro characterize --plan memory-inkernel --table  # VMEM/HBM ladder
    python -m repro characterize --plan serving --table  # predicted vs measured
    python -m repro characterize --plan collectives --table  # psum/gather ladder
    python -m repro characterize --plan serving-sharded --table  # TP serving
    python -m repro characterize --plan full --shard auto  # one shard per device
    python -m repro characterize --plan table2 --shard 4   # first 4 devices
    python -m repro serve-slo --rates 20,50,100 --db /tmp/db.json
    python -m repro serve-slo --trace /tmp/trace.json      # replay a saved trace

Scheduling is cache-aware by default: probes already in the DB for this
(device, backend, jax version) are reported as cache hits and skipped, which
is also what makes interrupted sweeps resumable — partial results are flushed
after every probe, so re-running the same command picks up where it stopped.

``--shard`` fans the plan out across local devices (``auto`` = all of them):
one device-pinned Session per shard, probes sequential within each device so
timing never contends, per-shard results merged into one DB (see
docs/fanout.md). Full-registry sweeps then scale with the device count —
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` simulates N devices
on a CPU-only host.
"""
from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.api.plan import PLAN_NAMES, named_plan
from repro.api.session import Session
from repro.core.timing import Timer


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Instruction/memory latency characterization (paper pipeline).")
    sub = ap.add_subparsers(dest="command", required=True)

    ch = sub.add_parser("characterize",
                        help="run a characterization plan into a LatencyDB")
    ch.add_argument("--plan", choices=PLAN_NAMES, default="quick",
                    help="named probe plan (default: quick)")
    ch.add_argument("--db", default="/tmp/latency_db.json",
                    help="LatencyDB JSON path (loaded if present; flushed "
                         "after every probe)")
    ch.add_argument("--force", action="store_true",
                    help="re-measure probes already in the DB")
    ch.add_argument("--resume", action="store_true",
                    help="skip probes already in the DB (the default; flag "
                         "kept for explicit scripts)")
    ch.add_argument("--ops", default=None,
                    help="comma-separated op filter applied to the plan "
                         "(e.g. add,mul,clock_overhead)")
    ch.add_argument("--opt-levels", default=None,
                    help="comma-separated opt-level filter (e.g. O0,O3)")
    ch.add_argument("--table", action="store_true",
                    help="print the Table II analog after the run (plus the "
                         "host-vs-in-kernel pairing when the DB holds "
                         "inkernel.* records — op chains and memory rows)")
    ch.add_argument("--recover", action="store_true",
                    help="salvage complete records from a truncated/corrupt "
                         "DB file instead of refusing to load it")
    ch.add_argument("--shard", default=None, metavar="auto|N",
                    help="fan the plan out across local devices: 'auto' uses "
                         "every device, N pins the first N (probes stay "
                         "sequential within each device)")
    ch.add_argument("--warmup", type=int, default=2)
    ch.add_argument("--reps", type=int, default=10,
                    help="timed repetitions per measurement point")
    ch.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent compiled-executable cache directory: "
                         "re-runs and resumed sweeps skip XLA entirely "
                         "(docs/performance.md)")
    ch.add_argument("--adaptive", action="store_true",
                    help="adaptive fidelity: stop repeating a probe once its "
                         "MAD/median converges, spend the saved reps on "
                         "noisy rows (effective rep counts land in record "
                         "notes as reps_eff=N)")
    ch.add_argument("--serial", action="store_true",
                    help="disable the compile-ahead pipeline (probe N+1's "
                         "compile no longer overlaps probe N's timing); "
                         "measured values are identical either way")
    ch.add_argument("--audit", action="store_true",
                    help="statically verify each probe's compiled artifact "
                         "as it is prepared (chain count, guard accounting, "
                         "dependent path) and attach the verdict to the "
                         "record notes (docs/audit.md)")
    ch.set_defaults(func=cmd_characterize)

    au = sub.add_parser(
        "audit",
        help="statically verify a LatencyDB's measurement artifacts "
             "(chain counts, guard accounting, opcode mapping)")
    au.add_argument("--db", default="/tmp/latency_db.json",
                    help="LatencyDB JSON path to audit; verdicts are "
                         "persisted into record notes")
    au.add_argument("--plan", choices=PLAN_NAMES, default=None,
                    help="restrict the audit to records the named plan "
                         "would produce (default: every record)")
    au.add_argument("--strict", action="store_true",
                    help="exit 1 on any transformed verdict or lint finding "
                         "(default: report and exit 0)")
    au.add_argument("--recheck", action="store_true",
                    help="re-derive verdicts even for records already "
                         "carrying an audit= note")
    au.add_argument("--lint", action="store_true",
                    help="also run the device-free static lints "
                         "(table mapping + guard identity)")
    au.add_argument("--lowering", action="store_true",
                    help="with --lint: also compile one short chain per "
                         "registry spec and check target-opcode presence")
    au.add_argument("--zoo", action="store_true",
                    help="with --lint: also compile the model zoo and check "
                         "every HLO opcode is priced/structural/allowlisted "
                         "(custom-calls resolve through the fused-kernel "
                         "signature registry)")
    au.add_argument("--dataflow", action="store_true",
                    help="with --lint: also open every in-repo Pallas "
                         "kernel's jaxpr and certify serialization, "
                         "residency and signature (docs/audit.md)")
    au.add_argument("--archs", default=None,
                    help="comma-separated arch filter for --zoo "
                         "(default: the full registry)")
    au.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="compile cache used by the characterize run: the "
                         "audit peeks its optimized-HLO sidecars instead of "
                         "re-invoking XLA")
    au.add_argument("--attribution", default=None, metavar="PATH",
                    help="write the per-op O0->O1->O3 transform attribution "
                         "table (markdown) to PATH ('-' for stdout)")
    au.add_argument("--attribution-ops", default="quick",
                    help="'quick' (QUICK_OPS), 'all' (full registry), or a "
                         "comma-separated op list for --attribution")
    au.set_defaults(func=cmd_audit)

    ss = sub.add_parser(
        "serve-slo",
        help="predicted-vs-measured serving SLO sweep over arrival rates")
    ss.add_argument("--db", default="/tmp/latency_db.json",
                    help="LatencyDB JSON path: pricing inputs are read from "
                         "it, slo.<rate> records are flushed back to it")
    ss.add_argument("--rates", default=None,
                    help="comma-separated arrival rates in req/s "
                         "(default: the Plan.slo sweep 20,50,100)")
    ss.add_argument("--trace", default=None,
                    help="replay a saved trace JSON (traffic.save_trace) "
                         "as one uncached point instead of the rate sweep")
    ss.add_argument("--n-requests", type=int, default=12,
                    help="requests per generated trace (rate sweep only)")
    ss.add_argument("--slots", type=int, default=4,
                    help="slot-pool size (max batch in flight)")
    ss.add_argument("--seed", type=int, default=0,
                    help="trace seed: same seed -> identical request stream")
    ss.add_argument("--force", action="store_true",
                    help="re-run slo points already in the DB")
    ss.add_argument("--warmup", type=int, default=2)
    ss.add_argument("--reps", type=int, default=10)
    ss.set_defaults(func=cmd_serve_slo)
    return ap


def _shard_devices(shard: str | None):
    """Resolve ``--shard`` to a device list, None (no fan-out), or an exit code."""
    if shard is None:
        return None
    import jax

    devices = jax.local_devices()
    if shard == "auto":
        n = len(devices)
    else:
        try:
            n = int(shard)
        except ValueError:
            print(f"error: --shard must be 'auto' or a positive integer, "
                  f"got {shard!r}", file=sys.stderr)
            return 2
        if n < 1:
            print("error: --shard must be >= 1", file=sys.stderr)
            return 2
        if n > len(devices):
            print(f"note: --shard {n} clamped to the {len(devices)} local "
                  "device(s)", file=sys.stderr)
            n = len(devices)
    return devices[:n]


def cmd_characterize(args: argparse.Namespace) -> int:
    if args.force and args.resume:
        print("error: --force and --resume are mutually exclusive",
              file=sys.stderr)
        return 2
    plan = named_plan(args.plan)
    if args.ops:
        plan = plan.filter(ops=[o.strip() for o in args.ops.split(",")])
    if args.opt_levels:
        plan = plan.filter(opt_levels=[l.strip() for l in args.opt_levels.split(",")])
    if not len(plan):
        print("error: plan is empty after filters", file=sys.stderr)
        return 2

    try:
        from repro.core.latency_db import LatencyDB

        db = LatencyDB.recover(args.db) if args.recover else args.db
        session = Session(db=db,
                          timer=Timer(warmup=args.warmup, reps=args.reps),
                          compile_cache=args.compile_cache,
                          adaptive=args.adaptive,
                          pipeline=not args.serial,
                          audit=args.audit)
    except Exception as e:  # unreadable/corrupt DB file: report, don't clobber
        print(f"error: could not load DB {args.db}: {type(e).__name__}: {e} "
              "(pass --recover to salvage complete records)", file=sys.stderr)
        return 2
    devices = _shard_devices(args.shard)
    if isinstance(devices, int):  # parse/validation error code
        return devices
    print(f"plan '{plan.name}': {len(plan)} probes -> {args.db} "
          f"[{session.env['backend']}/{session.env['device_kind']}, "
          f"jax {session.env['jax_version']}]")
    if devices is not None:
        print(f"fan-out: {len(devices)} device shard(s): "
              + ", ".join(str(d) for d in devices))
        result = session.fan_out(plan, devices=devices, force=args.force)
    else:
        result = session.run(plan, force=args.force)

    print(f"plan '{plan.name}': {result.summary()}")
    if result.cached and not result.measured and not result.failed:
        print("all probes were cache hits; pass --force to re-measure")
    for r in result.failed:
        f = r.failure
        print(f"  FAILED {f.op}@{f.opt_level}: {f.error_type}: {f.message}")
    if args.table:
        print()
        print(result.table_markdown())
        compare = session.db.compare_markdown()
        if compare.count("\n") > 1:  # header + separator + >=1 paired row
            print("\n== host vs in-kernel (paper's in-pipeline method) ==")
            print(compare)
        coll = session.db.compare_markdown(prefix="coll.")
        if coll.count("\n") > 1:
            print("\n== collective ladder (dependent-chain slope per rung) ==")
            print(coll)
        serving = session.db.compare_markdown(prefix="serving.")
        if serving.count("\n") > 1:
            print("\n== serving predicted vs measured (LatencyDB x perfmodel) ==")
            print(serving)
    return 1 if result.failed else 0


def cmd_audit(args: argparse.Namespace) -> int:
    """Static verification: lints and/or per-record artifact audits.

    Exit codes: 0 clean (or advisory-only without ``--strict``), 1 integrity
    violations under ``--strict``, 2 usage/IO errors.
    """
    import os

    failed = 0

    if args.lint:
        from repro.audit import run_lints

        archs = ([a.strip() for a in args.archs.split(",")]
                 if args.archs else None)
        findings = run_lints(lowering=args.lowering, zoo=args.zoo,
                             archs=archs, dataflow=args.dataflow)
        if findings:
            print(f"{len(findings)} lint finding(s):")
            for f in findings:
                print(f"  [{f.lint}] {f.subject}: {f.message}")
            failed += len(findings)
        else:
            scope = "mapping+guards"
            if args.lowering:
                scope += "+lowering"
            if args.zoo:
                scope += "+zoo"
            if args.dataflow:
                scope += "+dataflow"
            print(f"lints clean ({scope})")

    did_db = False
    if args.db and os.path.exists(args.db):
        from repro.audit import audit_db
        from repro.core.compile_cache import CompileCache
        from repro.core.latency_db import LatencyDB

        try:
            db = LatencyDB(args.db)
        except Exception as e:  # noqa: BLE001 - unreadable DB is a usage error
            print(f"error: could not load DB {args.db}: "
                  f"{type(e).__name__}: {e}", file=sys.stderr)
            return 2
        cache = CompileCache(args.compile_cache) if args.compile_cache else None
        wanted = None
        if args.plan:
            plan = named_plan(args.plan)
            wanted = {(p.op, p.opt_level) for p in plan}
        verdicts = []
        skipped = 0
        if wanted is not None:
            # audit in place but only the plan's rows: filter via a view DB
            sub = LatencyDB()
            for rec in db.records():
                if (rec.op, rec.opt_level) in wanted:
                    sub.add(rec)
                else:
                    skipped += 1
            verdicts = audit_db(sub, cache=cache, recheck=args.recheck)
            from repro.utils import parse_kv_notes

            for rec in sub.records():
                kv = parse_kv_notes(rec.notes)
                db.annotate(rec.key(), audit=kv.get("audit"),
                            audit_transform=kv.get("audit_transform"))
        else:
            verdicts = audit_db(db, cache=cache, recheck=args.recheck)
        db.save()
        did_db = True
        by_status: dict[str, int] = {}
        for v in verdicts:
            by_status[v.status] = by_status.get(v.status, 0) + 1
        print(f"audited {len(verdicts)} record(s)"
              + (f" ({skipped} outside plan '{args.plan}')" if skipped else "")
              + ": " + ", ".join(f"{k}={v}" for k, v in
                                 sorted(by_status.items())))
        bad = [v for v in verdicts if v.failed]
        for v in bad:
            print(f"  TRANSFORMED {v.op}@{v.opt_level}: {v.cause}"
                  + (f" — {v.detail}" if v.detail else ""))
        for v in verdicts:
            if v.status in ("opaque", "unaudited"):
                print(f"  {v.status.upper()} {v.op}@{v.opt_level}: {v.cause}")
        for v in verdicts:
            if v.status == "audited":
                print(f"  AUDITED {v.op}@{v.opt_level}"
                      + (f": {v.detail}" if v.detail else ""))
        failed += len(bad)
    elif args.db and not args.lint and not args.attribution:
        print(f"error: DB {args.db} does not exist (nothing to audit; "
              "pass --lint for device-free checks)", file=sys.stderr)
        return 2

    if args.attribution:
        from repro.audit import write_attribution

        if args.attribution_ops == "all":
            ops = None
        elif args.attribution_ops == "quick":
            from repro.api.plan import QUICK_OPS

            ops = QUICK_OPS
        else:
            ops = [o.strip() for o in args.attribution_ops.split(",")]
        db_for_attr = None
        if did_db:
            from repro.core.latency_db import LatencyDB

            db_for_attr = LatencyDB(args.db)
        if args.attribution == "-":
            n = write_attribution(sys.stdout, ops, db=db_for_attr)
        else:
            with open(args.attribution, "w") as f:
                n = write_attribution(f, ops, db=db_for_attr)
        print(f"attribution table: {n} op(s) -> {args.attribution}")

    if failed and args.strict:
        return 1
    return 0


def cmd_serve_slo(args: argparse.Namespace) -> int:
    from repro.api.plan import Plan
    from repro.core.latency_db import LatencyDB
    from repro.core.perfmodel import slo_markdown, slopoint_from_record

    if args.trace:
        # Replay a saved trace as a one-off point: no Session, no caching —
        # a trace file is an arbitrary workload, not a stable cache identity.
        import os

        import jax

        from repro.api.probes import serving_tiny_config
        from repro.core.latency_db import current_environment
        from repro.models import transformer
        from repro.serving import Engine
        from repro.traffic import load_trace, run_slo_point, slo_table

        trace = load_trace(args.trace)
        if not trace:
            print(f"error: trace {args.trace} holds no requests",
                  file=sys.stderr)
            return 2
        cfg, rt = serving_tiny_config()
        eng = Engine(transformer.init_lm(jax.random.PRNGKey(0), cfg), cfg, rt)
        db = LatencyDB(args.db) if os.path.exists(args.db) else LatencyDB()
        pred, meas, cov = run_slo_point(eng, db, trace, n_slots=args.slots,
                                        filters=current_environment())
        span_s = trace[-1].arrival_ns * 1e-9
        rate = len(trace) / span_s if span_s > 0 else float(len(trace))
        print(f"trace {args.trace}: {len(trace)} requests, effective rate "
              f"{rate:.3g} req/s, estimator coverage {cov:.1%}")
        print(slo_table([{"rate_rps": rate, "predicted": pred,
                          "measured": meas}]))
        return 0

    rates = ([float(r) for r in args.rates.split(",")] if args.rates
             else None)
    kw = dict(n_requests=args.n_requests, n_slots=args.slots, seed=args.seed)
    plan = Plan.slo(rates, **kw) if rates is not None else Plan.slo(**kw)
    session = Session(db=args.db,
                      timer=Timer(warmup=args.warmup, reps=args.reps))
    print(f"plan '{plan.name}': {len(plan)} probes -> {args.db} "
          f"[{session.env['backend']}/{session.env['device_kind']}, "
          f"jax {session.env['jax_version']}]")
    result = session.run(plan, force=args.force)
    print(f"plan '{plan.name}': {result.summary()}")
    if result.cached and not result.measured and not result.failed:
        print("all probes were cache hits; pass --force to re-measure")
    for r in result.failed:
        f = r.failure
        print(f"  FAILED {f.op}@{f.opt_level}: {f.error_type}: {f.message}")
    wanted = {p.rate_rps for p in plan if hasattr(p, "rate_rps")}
    points = sorted((slopoint_from_record(rec)
                     for rec in session.db.query(category="slo",
                                                 **session.env)),
                    key=lambda p: p.rate_rps)
    points = [p for p in points if p.rate_rps in wanted]
    print()
    print(slo_markdown(points))
    return 1 if result.failed else 0


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
