"""Discrete-event serving simulator priced from the measured LatencyDB.

The predicted half of an SLO point: run the *same*
:class:`~repro.traffic.scheduler.ContinuousBatchingScheduler` over the
*same* trace, but with every prefill/decode cost supplied by
:class:`~repro.core.perfmodel.HloLatencyEstimator` pricing the engine's real
lowered HLO against the session DB — no hardware in the loop. Because
scheduler policy and costs are both deterministic, the simulated timeline is
a pure function of ``(trace, DB)``: the throughput-vs-latency curve the
measured tables *predict*, to be held against the curve the engine actually
produces (docs/traffic.md).

Fidelity notes:

* The decode step is priced **once**: the pool's step is one compiled
  executable of fixed shape ``(n_slots, max_len)``, so its cost does not
  depend on occupancy — exactly like the real pool, whose free slots keep
  computing waste rows.
* Prefill is priced per distinct prompt length (each length is its own HLO).
* The simulator does not model eos (it cannot know what the model will
  sample); each request runs its full ``max_new`` budget. Compare against a
  measured run with ``eos_id=None`` for like-for-like schedules, or accept
  the divergence as part of the model error when eos is live.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

from repro.traffic.scheduler import ContinuousBatchingScheduler, ScheduleResult
from repro.traffic.traces import Request
from repro.utils import logger


class PredictedCostModel:
    """Price the slot pool's prefill/decode steps from a LatencyDB.

    Lowers the engine's computations (host-side XLA compile, no execution)
    and prices the optimized HLO with the estimator — environment-filtered,
    like ``ServingCostProbe``, so rows measured on another device never
    price this timeline. ``coverage`` of the least-covered priced module is
    exposed so callers can tell a measurement-backed prediction from a
    ``default_ns``-backed one.
    """

    def __init__(self, engine, db, n_slots: int, *, max_len: int | None = None,
                 opt_level: str = "O3", filters: dict[str, str] | None = None):
        from repro.core.perfmodel import HloLatencyEstimator

        self.engine = engine
        self.n_slots = int(n_slots)
        self.max_len = int(max_len) if max_len is not None else engine.max_len
        self.est = HloLatencyEstimator(db, opt_level=opt_level,
                                       filters=filters)
        self.min_coverage = 1.0

    def _price(self, lowered) -> float:
        report = self.est.estimate(lowered.compile().as_text())
        self.min_coverage = min(self.min_coverage, report.coverage)
        return report.total_ns

    @functools.lru_cache(maxsize=None)
    def prefill_ns(self, prompt_len: int) -> float:
        lowered, _ = self.engine.lower_prefill(1, prompt_len)
        ns = self._price(lowered)
        logger.debug("priced prefill plen=%d: %.0fns", prompt_len, ns)
        return ns

    @functools.lru_cache(maxsize=None)
    def decode_ns(self) -> float:
        lowered, _ = self.engine.lower_decode(self.n_slots, 1, self.max_len)
        ns = self._price(lowered)
        logger.debug("priced decode step b=%d cache=%d: %.0fns",
                     self.n_slots, self.max_len, ns)
        return ns


class SimulatedExecutor:
    """Executor protocol over a :class:`PredictedCostModel` — no hardware.

    Emits placeholder tokens (the simulator cannot know what the model would
    sample), so it must be scheduled with ``eos_id=None``: every request
    consumes exactly its ``max_new`` budget.
    """

    def __init__(self, costs: PredictedCostModel):
        self.costs = costs
        self.n_slots = costs.n_slots
        self._zeros = np.zeros((self.n_slots,), np.int32)

    def admit(self, slot: int, req: Request) -> tuple[int, float]:
        return 0, self.costs.prefill_ns(req.prompt_len)

    def step(self) -> tuple[np.ndarray, float]:
        return self._zeros, self.costs.decode_ns()

    def evict(self, slot: int) -> None:
        pass


def simulate(trace: Sequence[Request], costs: PredictedCostModel
             ) -> ScheduleResult:
    """Predicted timeline of ``trace`` under the DB-priced cost model."""
    sched = ContinuousBatchingScheduler(SimulatedExecutor(costs), eos_id=None)
    return sched.run(trace)


def run_slo_point(engine, db, trace: Sequence[Request], *, n_slots: int = 4,
                  max_len: int | None = None, opt_level: str = "O3",
                  filters: dict[str, str] | None = None, measure: bool = True):
    """One predicted-vs-measured SLO point: the same trace through the
    DB-priced simulator and (optionally) the real engine's slot pool.

    Both sides run ``eos_id=None`` so every request consumes exactly its
    ``max_new`` budget — the schedules differ only through step *costs*,
    which is the quantity under test. Returns
    ``(predicted SloSummary, measured SloSummary | None, min coverage)``.
    """
    from repro.traffic.metrics import summarize
    from repro.traffic.scheduler import EngineExecutor

    costs = PredictedCostModel(engine, db, n_slots, max_len=max_len,
                               opt_level=opt_level, filters=filters)
    pred = summarize(simulate(trace, costs))
    meas = None
    if measure:
        ex = EngineExecutor(engine, n_slots, max_len=max_len,
                            warm_lens=sorted({r.prompt_len for r in trace}))
        meas = summarize(
            ContinuousBatchingScheduler(ex, eos_id=None).run(trace))
    return pred, meas, costs.min_coverage
