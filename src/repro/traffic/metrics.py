"""Serving SLO metrics: TTFT / TPOT / e2e percentiles + goodput.

Turns a :class:`~repro.traffic.scheduler.ScheduleResult` into the numbers a
serving SLO is written against, with the standard definitions:

* **TTFT** — time to first token, ``first_token_ns - arrival_ns``. Includes
  queueing delay (a request that waits for a slot has a large TTFT even if
  its prefill is fast); that is deliberate — it is the user-visible number.
* **TPOT** — time per output token after the first,
  ``(finish - first_token) / (n_tokens - 1)``; ``nan`` for single-token
  requests (no inter-token gap exists) and excluded from aggregation.
* **e2e** — ``finish_ns - arrival_ns``.
* **goodput** — completed output tokens per second of makespan: the
  throughput the pool actually sustained for this trace.

Aggregation uses :func:`repro.utils.percentiles` (exact-rank), so every
reported p50/p90/p99 is an actual request's latency, never an interpolated
midpoint — at the n~10 of a smoke trace that distinction matters.
"""
from __future__ import annotations

import dataclasses
import math

from repro.traffic.scheduler import RequestResult, ScheduleResult
from repro.utils import percentiles

PCTS = (50.0, 90.0, 99.0)


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """SLO view of one finished request (ns, virtual clock)."""

    uid: int
    ttft_ns: float
    tpot_ns: float                    # nan when n_tokens == 1
    e2e_ns: float
    n_tokens: int
    queue_ns: float                   # admission wait: admitted - arrival


def request_metrics(rr: RequestResult) -> RequestMetrics:
    req = rr.request
    ttft = rr.first_token_ns - req.arrival_ns
    tpot = ((rr.finish_ns - rr.first_token_ns) / (rr.n_tokens - 1)
            if rr.n_tokens > 1 else math.nan)
    return RequestMetrics(uid=req.uid, ttft_ns=ttft, tpot_ns=tpot,
                          e2e_ns=rr.finish_ns - req.arrival_ns,
                          n_tokens=rr.n_tokens,
                          queue_ns=rr.admitted_ns - req.arrival_ns)


@dataclasses.dataclass(frozen=True)
class SloSummary:
    """Percentile aggregation of one scheduler run at one arrival rate."""

    n_requests: int
    n_tokens: int
    makespan_ns: float
    goodput_tok_s: float
    ttft_ns: dict[float, float]       # percentile -> ns
    tpot_ns: dict[float, float]
    e2e_ns: dict[float, float]

    def as_record(self) -> dict:
        """Flat JSON-friendly dict (``ttft_p50_ns`` style keys)."""
        out = {"n_requests": self.n_requests, "n_tokens": self.n_tokens,
               "makespan_ns": self.makespan_ns,
               "goodput_tok_s": self.goodput_tok_s}
        for name, d in (("ttft", self.ttft_ns), ("tpot", self.tpot_ns),
                        ("e2e", self.e2e_ns)):
            for p, v in d.items():
                out[f"{name}_p{p:g}_ns"] = v
        return out


def summarize(result: ScheduleResult, pcts=PCTS) -> SloSummary:
    """Aggregate a finished run into exact-rank percentile SLOs."""
    if not result.requests:
        raise ValueError("cannot summarize an empty schedule result")
    ms = [request_metrics(rr) for rr in result.requests]
    n_tokens = sum(m.n_tokens for m in ms)
    tpots = [m.tpot_ns for m in ms if not math.isnan(m.tpot_ns)]
    return SloSummary(
        n_requests=len(ms),
        n_tokens=n_tokens,
        makespan_ns=result.makespan_ns,
        goodput_tok_s=n_tokens / (result.makespan_ns * 1e-9),
        ttft_ns=percentiles([m.ttft_ns for m in ms], pcts),
        tpot_ns=percentiles(tpots, pcts) if tpots
        else {float(p): math.nan for p in pcts},
        e2e_ns=percentiles([m.e2e_ns for m in ms], pcts),
    )


# ---------------------------------------------------------------- rendering
def _ms(ns: float) -> str:
    return "nan" if math.isnan(ns) else f"{ns / 1e6:.3f}"


def slo_table(rows: list[dict]) -> str:
    """Markdown throughput-vs-latency table, one row per arrival rate.

    Each row dict carries ``rate_rps`` plus ``predicted``/``measured``
    :class:`SloSummary` objects (either may be ``None`` when that side was
    not run). All latencies in ms.
    """
    hdr = ("| rate (req/s) | side | TTFT p50 | TTFT p99 | TPOT p50 "
           "| TPOT p99 | e2e p50 | goodput (tok/s) |")
    sep = "|---" * 8 + "|"
    lines = [hdr, sep]
    for row in rows:
        for side in ("predicted", "measured"):
            s = row.get(side)
            if s is None:
                continue
            lines.append(
                f"| {row['rate_rps']:g} | {side} | {_ms(s.ttft_ns[50.0])} "
                f"| {_ms(s.ttft_ns[99.0])} | {_ms(s.tpot_ns[50.0])} "
                f"| {_ms(s.tpot_ns[99.0])} | {_ms(s.e2e_ns[50.0])} "
                f"| {s.goodput_tok_s:.1f} |")
    return "\n".join(lines)
