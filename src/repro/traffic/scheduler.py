"""Continuous-batching scheduler: admission queue + slot recycling.

One scheduler drives both sides of the predicted-vs-measured SLO loop. The
batching policy lives here — FIFO admission into a fixed slot pool, one
lockstep decode step per iteration, a slot freed the *moment* its row
finishes (eos or budget) and re-admitted to the next waiting request — while
the *cost* of each prefill/decode step comes from an executor:

* :class:`EngineExecutor` — the measured side: a real
  :class:`repro.serving.SlotPool` (per-slot positions over one persistent
  batched cache), every admit/step wall-clocked with device completion.
* ``traffic.simulate.SimulatedExecutor`` — the predicted side: the same
  protocol, costs priced from the LatencyDB via ``HloLatencyEstimator``,
  no hardware touched.

Time is a **virtual clock over real service times**: the clock starts at 0,
advances by each executor-reported cost, and jumps forward to the next
arrival when the pool drains — so a trace replays deterministically (no
sleeping, no load generator) while the measured run still prices every step
on the actual engine. TTFT is first-token-completion minus arrival, which
includes queueing delay: that is the number production SLOs bound.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Protocol, Sequence

import numpy as np

from repro.traffic.traces import Request
from repro.utils import block, logger


class Executor(Protocol):
    """Cost-bearing backend the scheduler drives (measured or simulated)."""

    n_slots: int

    def admit(self, slot: int, req: Request) -> tuple[int, float]:
        """Prefill ``req`` into ``slot``; returns (first token, cost ns)."""
        ...

    def step(self) -> tuple[np.ndarray, float]:
        """One lockstep decode step; returns ([n_slots] tokens, cost ns)."""
        ...

    def evict(self, slot: int) -> None:
        ...


@dataclasses.dataclass
class RequestResult:
    """Per-request timeline collected by one scheduler run (all ns, on the
    run's virtual clock; ``arrival_ns`` comes from the trace)."""

    request: Request
    slot: int = -1
    admitted_ns: float = 0.0          # prefill start (admission out of queue)
    first_token_ns: float = 0.0       # prefill complete = first token emitted
    finish_ns: float = 0.0
    tokens: list[int] = dataclasses.field(default_factory=list)
    token_times_ns: list[float] = dataclasses.field(default_factory=list)
    finish_reason: str = ""           # "eos" | "max_new"

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one trace run: per-request timelines + run totals."""

    requests: list[RequestResult]
    n_slots: int
    makespan_ns: float                # virtual-clock time of the last event
    decode_steps: int
    admissions: int

    def by_uid(self) -> dict[int, RequestResult]:
        return {r.request.uid: r for r in self.requests}


class ContinuousBatchingScheduler:
    """FIFO admission over a fixed slot pool with immediate slot recycling.

    Policy, in priority order at every iteration:

    1. **Admit**: while a slot is free and the head-of-queue request has
       arrived (``arrival_ns <= clock``), admit it (one batch-1 prefill,
       clock advances by its cost). A request whose first token is already
       terminal (eos, or ``max_new == 1``) finishes and frees the slot
       within the same admission burst.
    2. **Decode**: if any slot is active, run one lockstep step (clock
       advances by its cost); every active slot emits one token, finished
       rows are evicted immediately — the freed slot is admission-eligible
       on the very next iteration, before the rest of the batch drains.
    3. **Idle**: nothing active and nothing arrived — jump the clock to the
       next arrival.
    """

    def __init__(self, executor: Executor, *, eos_id: int | None = None):
        self.executor = executor
        self.eos_id = eos_id

    def run(self, trace: Sequence[Request]) -> ScheduleResult:
        ex = self.executor
        pending = deque(sorted(trace, key=lambda r: (r.arrival_ns, r.uid)))
        free = list(range(ex.n_slots))
        active: dict[int, RequestResult] = {}           # slot -> in-flight
        done: list[RequestResult] = []
        clock = 0.0
        decode_steps = admissions = 0

        def finish(slot: int, rr: RequestResult, reason: str) -> None:
            rr.finish_ns = clock
            rr.finish_reason = reason
            ex.evict(slot)
            del active[slot]
            free.append(slot)
            free.sort()                                 # deterministic reuse
            done.append(rr)

        while pending or active:
            # -------------------------------------------------- 1. admit
            admitted_any = False
            while pending and free and pending[0].arrival_ns <= clock:
                req = pending.popleft()
                slot = free.pop(0)
                rr = RequestResult(request=req, slot=slot, admitted_ns=clock)
                tok, cost = ex.admit(slot, req)
                clock += cost
                rr.first_token_ns = clock
                rr.tokens.append(tok)
                rr.token_times_ns.append(clock)
                active[slot] = rr
                admissions += 1
                admitted_any = True
                if self.eos_id is not None and tok == self.eos_id:
                    finish(slot, rr, "eos")
                elif req.max_new <= 1:
                    finish(slot, rr, "max_new")
            if admitted_any:
                continue        # new arrivals may have become eligible
            # -------------------------------------------------- 2. decode
            if active:
                toks, cost = ex.step()
                clock += cost
                decode_steps += 1
                for slot in sorted(active):
                    rr = active[slot]
                    tok = int(toks[slot])
                    rr.tokens.append(tok)
                    rr.token_times_ns.append(clock)
                    if self.eos_id is not None and tok == self.eos_id:
                        finish(slot, rr, "eos")
                    elif rr.n_tokens >= rr.request.max_new:
                        finish(slot, rr, "max_new")
                continue
            # -------------------------------------------------- 3. idle
            clock = max(clock, pending[0].arrival_ns)

        done.sort(key=lambda r: r.request.uid)
        return ScheduleResult(requests=done, n_slots=ex.n_slots,
                              makespan_ns=clock, decode_steps=decode_steps,
                              admissions=admissions)


# ------------------------------------------------------------ measured side
class EngineExecutor:
    """The measured executor: a real :class:`~repro.serving.SlotPool`, every
    admit/step wall-clocked to device completion.

    Costs are per-call wall times (including the one-off XLA compilations a
    cold engine pays — pass ``warm_lens`` to compile the prefill/decode
    shapes up front so compile time never lands inside a request's TTFT).
    """

    def __init__(self, engine, n_slots: int, *, max_len: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 warm_lens: Sequence[int] = ()):
        self.pool = engine.slots(n_slots, max_len=max_len) \
            if max_len is not None else engine.slots(n_slots)
        self.pool.temperature = temperature
        self.pool.seed = seed
        self.n_slots = n_slots
        if warm_lens:
            self.warm(warm_lens)

    def warm(self, prompt_lens: Sequence[int]) -> None:
        """Compile prefill/admit at each prompt length + the decode step, so
        measured costs are steady-state service times, not compile time."""
        pool = self.pool
        for plen in sorted(set(int(p) for p in prompt_lens)):
            pool.admit(0, [1] * plen, uid=-1, max_new=1)
            pool.evict(0)
        pool.admit(0, [1], uid=-1, max_new=1)
        pool.step()
        pool.evict(0)
        logger.info("engine executor warm: %d prefill shapes + decode step",
                    len(set(prompt_lens)))

    def admit(self, slot: int, req: Request) -> tuple[int, float]:
        t0 = time.perf_counter_ns()
        tok = self.pool.admit(slot, list(req.prompt), uid=req.uid,
                              max_new=req.max_new)
        block(self.pool.cache)
        return tok, float(time.perf_counter_ns() - t0)

    def step(self) -> tuple[np.ndarray, float]:
        t0 = time.perf_counter_ns()
        toks = self.pool.step()
        block(self.pool.cache)
        return toks, float(time.perf_counter_ns() - t0)

    def evict(self, slot: int) -> None:
        self.pool.evict(slot)
