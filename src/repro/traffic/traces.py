"""Seeded, replayable arrival traces for the serving simulator.

A trace is the workload half of an SLO point: *when* requests arrive and
*what* they ask for. Everything is derived from a :class:`TraceConfig`
through the counter-based Philox discipline of ``data/synthetic.py``
(:func:`repro.data.synthetic.philox_rng`), so the same config replays the
identical request stream on any host — which is what lets the predicted
timeline (``traffic.simulate``) and the measured one (``traffic.scheduler``
driving the real engine) consume *the same* trace, and what makes the CI
determinism check meaningful.

Two arrival processes:

* ``poisson`` — exponential inter-arrivals at ``rate_rps`` (CV = 1), the
  open-loop "millions of independent users" model;
* ``gamma`` — Gamma inter-arrivals with coefficient of variation
  ``burstiness_cv`` at the same mean rate. ``cv > 1`` clusters arrivals into
  bursts (shape ``1/cv²`` < 1), the tail-latency stressor; ``cv < 1``
  smooths them toward a paced load generator.

Traces serialize to JSON (``save_trace`` / ``load_trace``) for the
``python -m repro serve-slo --trace`` replay path.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from repro.data.synthetic import philox_rng
from repro.utils import dump_json


@dataclasses.dataclass(frozen=True)
class Request:
    """One replayable request record of a trace."""

    uid: int
    arrival_ns: float
    prompt: tuple[int, ...]
    max_new: int

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Deterministic recipe for one arrival trace (the trace IS this config).

    ``prompt_len`` / ``max_new`` are inclusive ``(lo, hi)`` ranges sampled
    uniformly; keep the prompt range narrow where compile time matters (every
    distinct prompt length is one prefill compilation).
    """

    n_requests: int
    rate_rps: float
    seed: int = 0
    process: str = "poisson"          # "poisson" | "gamma"
    burstiness_cv: float = 1.0        # gamma only: CV of inter-arrivals
    prompt_len: tuple[int, int] = (4, 8)
    max_new: tuple[int, int] = (4, 8)
    vocab_size: int = 128

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got {self.n_requests}")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.process not in ("poisson", "gamma"):
            raise ValueError(f"process must be poisson|gamma, got {self.process!r}")
        if self.burstiness_cv <= 0:
            raise ValueError(f"burstiness_cv must be > 0, got {self.burstiness_cv}")
        for name in ("prompt_len", "max_new"):
            lo, hi = getattr(self, name)
            if not 1 <= lo <= hi:
                raise ValueError(f"{name} range must satisfy 1 <= lo <= hi, "
                                 f"got ({lo}, {hi})")


def generate_trace(cfg: TraceConfig) -> list[Request]:
    """The trace for ``cfg``: same config -> identical request list, always."""
    rng = philox_rng(cfg.seed, 0)
    mean_gap_s = 1.0 / cfg.rate_rps
    if cfg.process == "poisson":
        gaps = rng.exponential(mean_gap_s, size=cfg.n_requests)
    else:
        # Gamma with mean = mean_gap_s and CV = burstiness_cv:
        # shape k = 1/cv^2, scale = mean/k. cv=1 degenerates to exponential.
        k = 1.0 / (cfg.burstiness_cv ** 2)
        gaps = rng.gamma(k, mean_gap_s / k, size=cfg.n_requests)
    arrivals_ns = np.cumsum(gaps) * 1e9
    plo, phi = cfg.prompt_len
    nlo, nhi = cfg.max_new
    plens = rng.integers(plo, phi + 1, size=cfg.n_requests)
    max_news = rng.integers(nlo, nhi + 1, size=cfg.n_requests)
    out: list[Request] = []
    for i in range(cfg.n_requests):
        # token ids start at 1: 0 is the engines' pad token
        prompt = rng.integers(1, max(cfg.vocab_size, 2), size=int(plens[i]))
        out.append(Request(uid=i, arrival_ns=float(arrivals_ns[i]),
                           prompt=tuple(int(t) for t in prompt),
                           max_new=int(max_news[i])))
    return out


# -------------------------------------------------------------- persistence
def save_trace(path: str, trace: Sequence[Request],
               cfg: TraceConfig | None = None) -> str:
    """Write a trace (and optionally its generating config) as JSON."""
    payload = {
        "requests": [dataclasses.asdict(r) for r in trace],
        "config": dataclasses.asdict(cfg) if cfg is not None else None,
    }
    dump_json(payload, path)
    return path


def load_trace(path: str) -> list[Request]:
    """Load a trace written by :func:`save_trace` (arrival-sorted)."""
    with open(path) as f:
        payload = json.load(f)
    reqs = [Request(uid=int(r["uid"]), arrival_ns=float(r["arrival_ns"]),
                    prompt=tuple(int(t) for t in r["prompt"]),
                    max_new=int(r["max_new"]))
            for r in payload["requests"]]
    return sorted(reqs, key=lambda r: (r.arrival_ns, r.uid))
