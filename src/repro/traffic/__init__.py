"""repro.traffic — continuous-batching serving simulator with
perfmodel-predicted SLO percentiles.

The serving-SLO loop on top of the characterization stack: seeded arrival
traces (``traces``), one continuous-batching scheduler driving either the
real engine or a LatencyDB-priced simulator (``scheduler`` / ``simulate``),
and exact-rank percentile SLO metrics (``metrics``). See docs/traffic.md.
"""
from repro.traffic.traces import (Request, TraceConfig, generate_trace,
                                  load_trace, save_trace)
from repro.traffic.scheduler import (ContinuousBatchingScheduler,
                                     EngineExecutor, Executor, RequestResult,
                                     ScheduleResult)
from repro.traffic.simulate import (PredictedCostModel, SimulatedExecutor,
                                    run_slo_point, simulate)
from repro.traffic.metrics import (RequestMetrics, SloSummary,
                                   request_metrics, slo_table, summarize)

__all__ = [
    "Request", "TraceConfig", "generate_trace", "save_trace", "load_trace",
    "ContinuousBatchingScheduler", "EngineExecutor", "Executor",
    "RequestResult", "ScheduleResult",
    "PredictedCostModel", "SimulatedExecutor", "run_slo_point", "simulate",
    "RequestMetrics", "SloSummary", "request_metrics", "summarize",
    "slo_table",
]
