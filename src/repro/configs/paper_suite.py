"""The paper's own 'configuration': the characterization suite targets.

The paper's Table I describes the seven GPUs it characterizes. The analog
here is the table of execution targets the suite runs against — the host CPU
backend (measured in this container) and the TPU v5e production target
(datasheet constants mandated for §Roofline). ``suite()`` bundles what the
paper's tool sweeps: the op registry, opt levels, and memory working sets.
"""
from __future__ import annotations

import dataclasses

from repro.core import chains
from repro.core.optlevels import OPT_LEVELS
from repro.core.perfmodel import CPU_HOST, TPU_V5E, HardwareSpec


@dataclasses.dataclass(frozen=True)
class SuiteConfig:
    targets: tuple[HardwareSpec, ...]
    opt_levels: tuple[str, ...]
    categories: tuple[str, ...]
    working_sets: tuple[int, ...]          # Fig. 6 sweep
    chain_lengths: tuple[int, int] = (64, 512)
    reps: int = 30


def suite() -> SuiteConfig:
    return SuiteConfig(
        targets=(CPU_HOST, TPU_V5E),
        opt_levels=tuple(OPT_LEVELS),
        categories=tuple(chains.CATEGORIES),
        working_sets=tuple(1 << k for k in range(12, 26)),
    )
