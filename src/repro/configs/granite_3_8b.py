"""Granite-3 8B: 40L d4096 32H(kv8) ff12800 v49155, dense GQA
[hf:ibm-granite/granite-3.0-8b-base]. Note v49155 is not divisible by the
16-way model axis -> vocab replicates (sharding rules fall back); embedding
memory is FSDP-sharded over data instead."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("granite-3-8b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
        vocab_size=49155, rope_theta=1e4, tie_embeddings=True,
        attn_parallelism="heads", fsdp=True)
    smoke = ModelConfig(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=515, tie_embeddings=True)
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
