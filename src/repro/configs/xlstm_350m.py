"""xLSTM-350M: 24L d1024 4H(kv4) no-FFN v50304, sLSTM+mLSTM [7:1]
[arXiv:2405.04517; unverified]. Recurrent state O(1) -> runs long_500k."""
from repro.configs.registry import ArchSpec, register
from repro.models.config import ModelConfig

_PERIOD = tuple([("mlstm", "none")] * 7 + [("slstm", "none")])


@register("xlstm-350m")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304, period=_PERIOD, ssm_expand=2,
        tie_embeddings=True, attn_parallelism="context")
    smoke = ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=512, period=_PERIOD, ssm_expand=2, tie_embeddings=True)
    return ArchSpec(cfg, smoke, skips={})
