"""Yi-9B: 48L d4096 32H(kv4) ff11008 v64000, llama-arch GQA
[arXiv:2403.04652; hf]. Head-parallel TP (32/16=2, kv duplicated 4x)."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("yi-9b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
        vocab_size=64000, rope_theta=1e4, tie_embeddings=False,
        attn_parallelism="heads", fsdp=True)
    smoke = ModelConfig(
        name="yi-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=172,
        vocab_size=500, tie_embeddings=False)
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
