"""InternLM2-20B: 48L d6144 48H(kv8) ff16384 v92544, dense GQA
[arXiv:2403.17297; hf]. Head-parallel TP (48/16=3, kv duplicated 2x)."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("internlm2-20b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab_size=92544, rope_theta=1e6, tie_embeddings=False,
        attn_parallelism="heads", fsdp=True)
    smoke = ModelConfig(
        name="internlm2-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab_size=512, tie_embeddings=False)
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
