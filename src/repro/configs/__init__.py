from repro.configs import registry
from repro.configs.registry import ARCH_IDS, SHAPES, ArchSpec, all_arch_ids, get

__all__ = ["registry", "ARCH_IDS", "SHAPES", "ArchSpec", "all_arch_ids", "get"]
