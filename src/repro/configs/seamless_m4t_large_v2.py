"""SeamlessM4T-large v2 text backbone: 24L enc + 24L dec, d1024 16H(kv16)
ff8192 v256206, enc-dec [arXiv:2308.11596; hf]. Speech frontend STUBBED:
cells feed precomputed frame embeddings (enc len = seq/4). Decoder has a KV
cache -> decode shapes run."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("seamless-m4t-large-v2")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        vocab_size=256206, n_encoder_layers=24, tie_embeddings=True,
        attn_parallelism="heads", fsdp=True, input_kind="frame_embeddings")
    smoke = ModelConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, n_encoder_layers=2, tie_embeddings=True,
        input_kind="frame_embeddings")
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
