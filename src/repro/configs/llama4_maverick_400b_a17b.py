"""Llama-4 Maverick 400B-A17B: 48L d5120 40H(kv8) ff8192 v202048, MoE 128e
top-1 interleaved every other layer + shared expert, early-fusion backbone
[hf:meta-llama/Llama-4 family; unverified]. 40 q-heads do not divide the
16-way model axis -> context-parallel attention (DESIGN.md section 5)."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("llama4-maverick-400b-a17b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, period=(("attn", "moe"), ("attn", "dense")),
        n_experts=128, top_k=1, shared_expert=True, capacity_factor=1.25,
        rope_theta=5e5, tie_embeddings=False, param_dtype="bfloat16",
        attn_parallelism="context", fsdp=True)
    smoke = ModelConfig(
        name="llama4-maverick-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=10, n_kv_heads=2, d_ff=96,
        vocab_size=512, period=(("attn", "moe"), ("attn", "dense")),
        n_experts=8, top_k=1, shared_expert=True, tie_embeddings=False,
        attn_parallelism="context")
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
