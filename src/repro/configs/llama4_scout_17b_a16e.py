"""Llama-4 Scout 17B-A16E: 48L d5120 40H(kv8) ff8192 v202048, MoE 16e top-1
every layer + shared expert [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
Context-parallel attention (40 heads vs 16-way TP)."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("llama4-scout-17b-a16e")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, period=(("attn", "moe"),),
        n_experts=16, top_k=1, shared_expert=True, capacity_factor=1.25,
        rope_theta=5e5, tie_embeddings=False, param_dtype="bfloat16",
        attn_parallelism="context", fsdp=True)
    smoke = ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=10, n_kv_heads=2, d_ff=96,
        vocab_size=512, period=(("attn", "moe"),), n_experts=4, top_k=1,
        shared_expert=True, tie_embeddings=False, attn_parallelism="context")
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
