"""Architecture registry: --arch <id> resolves here.

Every assigned architecture registers its exact ``ModelConfig``, a reduced
``smoke`` config of the same family, and its applicable input-shape cells
(the mandated 4: train_4k / prefill_32k / decode_32k / long_500k; long_500k
only for sub-quadratic archs, per the assignment rule — skips are recorded).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "llama4-scout-17b-a16e",
    "internlm2-20b",
    "granite-3-8b",
    "llama3-405b",
    "yi-9b",
    "jamba-v0.1-52b",
    "xlstm-350m",
    "qwen2-vl-2b",
    "seamless-m4t-large-v2",
)

# shape id -> (seq_len, global_batch, step kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    smoke: ModelConfig
    skips: dict[str, str]        # shape id -> reason

    def applicable_shapes(self) -> list[str]:
        return [s for s in SHAPES if s not in self.skips]


_REGISTRY: dict[str, Callable[[], ArchSpec]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ArchSpec]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]()


def all_arch_ids() -> tuple[str, ...]:
    return ARCH_IDS


FULL_ATTENTION_SKIP = ("long_500k",
                       "full quadratic attention at 524k seq: skipped per "
                       "assignment rule (sub-quadratic archs only)")
