"""Qwen2-VL 2B backbone: 28L d1536 12H(kv2) ff8960 v151936, M-RoPE
(t/h/w sections 16/24/24), dynamic-resolution ViT frontend STUBBED: cells
feed precomputed patch embeddings + 3D positions [arXiv:2409.12191; hf].
12 heads vs 16-way TP -> context-parallel attention."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("qwen2-vl-2b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
        vocab_size=151936, mrope_sections=(16, 24, 24), rope_theta=1e6,
        tie_embeddings=True, attn_parallelism="context", fsdp=True,
        input_kind="patch_embeddings")
    smoke = ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab_size=512, mrope_sections=(2, 3, 3), tie_embeddings=True,
        input_kind="patch_embeddings")
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
