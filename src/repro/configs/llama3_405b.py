"""Llama-3.1 405B: 126L d16384 128H(kv8) ff53248 v128256 [arXiv:2407.21783].
Head-parallel TP (128/16=8); FSDP over pod+data; bf16 params + int8 AdamW
moments to fit 16 GiB/chip (see optim/adamw.py)."""
from repro.configs.registry import ArchSpec, FULL_ATTENTION_SKIP, register
from repro.models.config import ModelConfig


@register("llama3-405b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
        vocab_size=128256, rope_theta=5e5, tie_embeddings=False,
        param_dtype="bfloat16", attn_parallelism="heads", fsdp=True)
    smoke = ModelConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, d_ff=416,
        vocab_size=512, tie_embeddings=False)
    return ArchSpec(cfg, smoke, skips=dict([FULL_ATTENTION_SKIP]))
