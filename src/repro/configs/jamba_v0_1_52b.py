"""Jamba-v0.1 52B: 32L d4096 32H(kv8) ff14336 v65536, Mamba+attention 1:7
interleave, MoE 16e top-2 every other layer [arXiv:2403.19887; hf].
Sub-quadratic -> runs long_500k (SSM state O(1); the 4 attention layers use
a sequence-sharded KV cache with flash-decode LSE combine)."""
from repro.configs.registry import ArchSpec, register
from repro.models.config import ModelConfig

_PERIOD = (("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"),
           ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
           ("mamba", "dense"), ("mamba", "moe"))


@register("jamba-v0.1-52b")
def spec() -> ArchSpec:
    cfg = ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=65536, period=_PERIOD, n_experts=16, top_k=2,
        capacity_factor=1.25, ssm_state=16, ssm_conv=4, ssm_expand=2,
        tie_embeddings=False, param_dtype="bfloat16",
        attn_parallelism="heads", fsdp=True)
    smoke = ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=512, period=_PERIOD, n_experts=4, top_k=2, ssm_state=8,
        tie_embeddings=False)
    return ArchSpec(cfg, smoke, skips={})
