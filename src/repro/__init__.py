"""repro: TPU-native instruction/memory latency characterization (the paper's
technique) integrated as a first-class subsystem of a multi-pod JAX
training/serving framework. See DESIGN.md.

The characterization front door is ``repro.api`` (``Session`` / ``Plan`` /
``Probe`` / ``ResultSet``), also exposed lazily here::

    from repro import Session, Plan

CLI: ``python -m repro characterize --plan quick|table2|memory|inkernel|full``.
In-kernel (Pallas) probes — the paper's in-pipeline method — live in
``repro.inkernel`` (see docs/inkernel.md).
"""
__version__ = "1.2.0"

_API_EXPORTS = ("Session", "Plan", "Probe", "ResultSet", "named_plan")


def __getattr__(name: str):
    if name in _API_EXPORTS:  # lazy: keep `import repro` free of jax imports
        import repro.api as api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
