"""repro: TPU-native instruction/memory latency characterization (the paper's
technique) integrated as a first-class subsystem of a multi-pod JAX
training/serving framework. See DESIGN.md."""
__version__ = "1.0.0"
