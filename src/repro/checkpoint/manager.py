"""Fault-tolerant checkpointing: atomic, retained, async, elastically
resharded on restore.

Layout per step: ``<dir>/step_<n>/host_<i>.npz`` (flattened leaf arrays) +
``meta.json`` (treedef paths, shapes, dtypes, step). Writes go to a temp dir
then ``os.rename`` (atomic on POSIX) so a crash mid-save never corrupts the
latest checkpoint; ``COMMIT`` marker closes the step. Restore accepts ANY
target sharding: arrays are materialized host-side then ``device_put`` with
the new sharding — that is the elastic-scaling path (checkpoints written on
one mesh restore onto another; tested across mesh shapes).
"""
from __future__ import annotations

import concurrent.futures
import os
import re
import shutil
from typing import Any, Callable

import jax
import numpy as np

from repro.utils import dump_json, load_json, logger


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if async_save else None
        self._pending: concurrent.futures.Future | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, host_id: int = 0,
             blocking: bool = False) -> None:
        self.wait()
        host_arrays = {k: np.asarray(v) for k, v in _flatten_with_paths(tree)
                       if v is not None}
        meta = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host_arrays.items()}}

        def write() -> None:
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + f".tmp{host_id}"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{host_id}.npz"), **host_arrays)
            dump_json(meta, os.path.join(tmp, "meta.json"))
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
            logger.info("checkpoint step %d saved", step)

        if self._pool and not blocking:
            self._pending = self._pool.submit(write)
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: int | None = None, *,
                shardings: Any = None, host_id: int = 0) -> tuple[int, Any]:
        """Restore into the structure of ``tree_like``; optional resharding.

        ``shardings``: matching pytree (or prefix) of NamedSharding for
        elastic restore onto a different mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        blob = np.load(os.path.join(path, f"host_{host_id}.npz"))
        keys = [k for k, _ in _flatten_with_paths(tree_like)]
        leaves = [blob[k] for k in keys]
        treedef = jax.tree_util.tree_structure(tree_like)
        restored = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return step, restored
