"""Unit-workload builders for the fused-kernel probe rows.

Each in-repo fused Pallas kernel (``kernels/``) gets a parameterized *unit
workload*: ``build_fused(name, n)`` returns a jit-able callable plus its
arguments, sized so the kernel executes exactly ``n`` workload units (KV
blocks for attention, sequence chunks for the SSM scan, row blocks for
rmsnorm). Two sizes measured with :meth:`Timer.slope` net the launch/DMA
overhead exactly like the chain probes net theirs — the per-unit latency is
the slope — and the same two sizes feed the dataflow auditor's signature
linearity certificate (:func:`repro.audit.dataflow.audit_fused`), which
derives the per-unit HBM byte count (``unit_bytes``) that the estimator
scales when pricing a zoo-model custom-call of a different shape.

One builder is the single source of truth for probe, auditor, and registry:
what is measured is exactly what is certified.
"""
from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp

Array = Any

FUSED_KERNELS = ("flash_attention", "flash_decode", "mamba_scan", "rmsnorm")

# two workload sizes for the slope; larger spans amortize per-unit noise but
# these run in interpret mode on CPU, so stay small
FUSED_LENS = (2, 6)

_BLK = 16     # q/k block for the attention kernels (TPU-lane friendly)
_HEADS = 2    # grouped heads per KV head
_CHUNK = 8    # mamba chunk (= sequence units)
_DM = 8       # mamba model dim
_DN = 4       # mamba state dim
_ROWS = 8     # rmsnorm block rows
_COLS = 64    # rmsnorm feature dim


def _ramp(shape, lo=0.05, hi=0.95, dtype=jnp.float32) -> Array:
    """Deterministic well-conditioned values in [lo, hi] (no RNG: builders
    must be reproducible across probe and auditor call sites)."""
    n = 1
    for d in shape:
        n *= d
    flat = lo + (hi - lo) * (jnp.arange(n, dtype=jnp.float32) % 17) / 16.0
    return flat.reshape(shape).astype(dtype)


def build_fused(name: str, n: int, *, interpret: bool | None = None
                ) -> tuple[Callable, tuple]:
    """(fn, args) running fused kernel ``name`` over ``n`` workload units."""
    if name == "flash_attention":
        from repro.kernels.flash_attention import flash_attention

        q = _ramp((1, _BLK, _HEADS, _BLK))
        k = _ramp((1, _BLK * n, 1, _BLK))
        v = _ramp((1, _BLK * n, 1, _BLK))

        def fn(q, k, v):
            # causal=False: every KV block is visited, so work is exactly
            # linear in n (causal skips masked blocks and breaks the slope)
            return flash_attention(q, k, v, causal=False, block_q=_BLK,
                                   block_k=_BLK, interpret=interpret)

        return fn, (q, k, v)
    if name == "flash_decode":
        from repro.kernels.flash_decode import flash_decode

        q = _ramp((1, _HEADS, _BLK))
        k = _ramp((1, _BLK * n, 1, _BLK))
        v = _ramp((1, _BLK * n, 1, _BLK))
        kv_len = jnp.full((1,), _BLK * n, jnp.int32)

        def fn(q, k, v, kv_len):
            return flash_decode(q, k, v, kv_len, block_k=_BLK,
                                interpret=interpret)

        return fn, (q, k, v, kv_len)
    if name == "mamba_scan":
        from repro.kernels.mamba_scan import mamba_scan

        s = _CHUNK * n
        x = _ramp((1, s, _DM))
        dt = _ramp((1, s, _DM))
        a = -_ramp((_DM, _DN), lo=0.1, hi=1.0)   # stable decay: A < 0
        b = _ramp((1, s, _DN))
        c = _ramp((1, s, _DN))
        d = _ramp((_DM,))

        def fn(x, dt, a, b, c, d):
            return mamba_scan(x, dt, a, b, c, d, chunk=_CHUNK,
                              interpret=interpret)

        return fn, (x, dt, a, b, c, d)
    if name == "rmsnorm":
        from repro.kernels.rmsnorm import rmsnorm

        x = _ramp((_ROWS * n, _COLS))
        w = _ramp((_COLS,), lo=0.5, hi=1.5)

        def fn(x, w):
            return rmsnorm(x, w, block_rows=_ROWS, interpret=interpret)

        return fn, (x, w)
    raise ValueError(f"unknown fused kernel {name!r}; "
                     f"known: {', '.join(FUSED_KERNELS)}")
