"""Slope extraction for in-kernel chains (the paper's Fig. 5 algebra).

Two kernels differing only in chain length share the identical DMA-in, DMA-out
and launch path, so ``(T(n2) - T(n1)) / (n2 - n1)`` is the pure in-pipeline
per-op cost — the same cancellation the paper gets by subtracting the
calibrated ``%clock`` read overhead. Reuses :meth:`Timer.slope` unchanged
(min-statistics noise floor included) so dispatch-level and in-kernel numbers
are produced by one algebra and stay directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax

from repro.core.chains import OpSpec
from repro.core.timing import Measurement, Timer
from repro.inkernel.factory import build_chain, tiles

# In-kernel chains are compiled (never eager), so both lengths stay short:
# fori_loop keeps compile time O(1) in n, and 8 vs 64 already puts the per-op
# signal well above the (cancelled) launch overhead.
INKERNEL_LENS = (8, 64)

# Chase step counts for the in-kernel memory rows: long enough that the
# per-load slope dominates the (cancelled) DMA-in of the ring on the VMEM
# path, short enough that the serial dependent-load chain stays cheap to run
# at both lengths even when every step streams from HBM.
CHASE_LENS = (64, 192)


def _cached_aot(fn: Callable, args: tuple, op: str, fidelity: str,
                cache: Any, env: Mapping[str, str] | None,
                dtype: str = "int32") -> Callable:
    """AOT-compile ``fn`` for ``args`` through the compile cache.

    Without a cache the raw callable is returned unchanged (the kernel
    factories jit internally), preserving the legacy serial behavior.
    """
    if cache is not None and env is not None:
        from repro.core.compile_cache import fidelity_key, hlo_extra

        key = fidelity_key(env, op, "O3", dtype, fidelity)
        compiled, _, _ = cache.load_or_compile(
            key, lambda: jax.jit(fn).lower(*args).compile(), extra=hlo_extra)
        return compiled
    return fn


@dataclasses.dataclass
class PreparedKernel:
    """Compiled two-length kernel callables plus their slope parameters.

    The XLA-bound half of an in-kernel probe — built off the timing thread
    (Session's compile-ahead worker), consumed on the main thread by
    :func:`run_prepared_inkernel` / :func:`run_prepared_chase`.
    """

    lens: tuple[int, int]
    retry_lens: tuple[int, int] | None
    args: tuple
    reps: int | None
    memory_space: str = ""        # chase only
    _fns: dict[int, Callable] = dataclasses.field(default_factory=dict)
    _build: Callable[[int], Callable] | None = None

    def fn_by_len(self, n: int) -> Callable:
        """Memoized kernel; the widened retry length compiles lazily."""
        if n not in self._fns:
            self._fns[n] = self._build(n)
        return self._fns[n]


def prepare_chase(working_set_bytes: int, line_bytes: int = 64,
                  lens: tuple[int, int] = CHASE_LENS,
                  interpret: bool | None = None,
                  memory_space: str | None = None,
                  reps: int | None = None,
                  cache: Any = None, env: Mapping[str, str] | None = None
                  ) -> PreparedKernel:
    """Build the ring and compile both chase-step kernels; no timing."""
    from repro.core.membench import build_ring
    from repro.kernels.chase import chase, select_memory_space

    ring, start = build_ring(working_set_bytes, line_bytes)
    space = (memory_space if memory_space is not None
             else select_memory_space(ring.size * 4))

    def build(n: int) -> Callable:
        fn = lambda r, s: chase(r, s, steps=n, interpret=interpret,  # noqa: E731
                                memory_space=space)
        return _cached_aot(fn, (ring, start), f"inkernel.mem.{working_set_bytes}",
                           f"steps{n}.{space}.line{line_bytes}", cache, env)

    prepared = PreparedKernel(lens=lens, retry_lens=None, args=(ring, start),
                              reps=reps, memory_space=space, _build=build)
    prepared.fn_by_len(lens[0])
    prepared.fn_by_len(lens[1])
    return prepared


def run_prepared_chase(prepared: PreparedKernel, timer: Timer | None = None
                       ) -> tuple[Measurement, str]:
    """Time a prepared chase: ``(measurement, memory_space)``."""
    timer = timer or Timer()
    m = timer.slope(prepared.fn_by_len, *prepared.lens, *prepared.args,
                    reps=prepared.reps, retry_lens=prepared.retry_lens)
    return m, prepared.memory_space


def measure_chase_full(working_set_bytes: int, line_bytes: int = 64,
                       lens: tuple[int, int] = CHASE_LENS,
                       timer: Timer | None = None,
                       interpret: bool | None = None,
                       memory_space: str | None = None,
                       reps: int | None = None) -> tuple[Measurement, str]:
    """Per-load in-kernel chase latency at one working-set size.

    The same two-length :meth:`Timer.slope` extraction as the op chains: two
    kernels differing only in chase step count share the identical ring
    residency, DMA and launch path, so the slope is the pure dependent-load
    cost at whichever level the ring lives in. Returns ``(measurement,
    memory_space)`` where the space is the residency actually used —
    ``"vmem"`` (BlockSpec-resident, Table IV analog) or ``"any"``
    (HBM-streaming, Fig. 6 analog) — selected by ring footprint unless
    forced. Equivalent to ``run_prepared_chase(prepare_chase(...))``.
    """
    return run_prepared_chase(
        prepare_chase(working_set_bytes, line_bytes, lens,
                      interpret=interpret, memory_space=memory_space,
                      reps=reps),
        timer)


def prepare_inkernel(spec: OpSpec, lens: tuple[int, int] = INKERNEL_LENS,
                     shape: tuple[int, int] | None = None,
                     interpret: bool | None = None,
                     reps: int | None = None,
                     cache: Any = None, env: Mapping[str, str] | None = None
                     ) -> PreparedKernel:
    """Compile both chain-length kernels for ``spec``; no timing."""
    from repro.core.measure import retry_lens_for

    n1, n2 = lens
    if spec.max_chain is not None:
        n1, n2 = min(n1, max(spec.max_chain // 3, 1)), min(n2, spec.max_chain)
    carry, operands = tiles(spec, shape)

    def build(n: int) -> Callable:
        fn = build_chain(spec, n, interpret=interpret)
        return _cached_aot(fn, (carry,) + operands, f"inkernel.{spec.name}",
                           f"chain{n}.tile{'x'.join(map(str, carry.shape))}",
                           cache, env, dtype=spec.dtype)

    prepared = PreparedKernel(lens=(n1, n2),
                              retry_lens=retry_lens_for(spec, n1, n2),
                              args=(carry,) + tuple(operands), reps=reps,
                              _build=build)
    prepared.fn_by_len(n1)
    prepared.fn_by_len(n2)
    return prepared


def run_prepared_inkernel(prepared: PreparedKernel,
                          timer: Timer | None = None) -> Measurement:
    """Time a prepared in-kernel chain: the device-serial half."""
    timer = timer or Timer()
    return timer.slope(prepared.fn_by_len, *prepared.lens, *prepared.args,
                       reps=prepared.reps, retry_lens=prepared.retry_lens)


def prepare_fused(name: str, lens: tuple[int, int] | None = None,
                  interpret: bool | None = None, reps: int | None = None,
                  cache: Any = None, env: Mapping[str, str] | None = None
                  ) -> PreparedKernel:
    """Compile a fused kernel at both workload sizes; no timing.

    Unlike the chain kernels, the two workload sizes have *different* input
    shapes (the KV cache / sequence grows with ``n``), so each compiled
    callable closes over its own arguments and ``PreparedKernel.args`` stays
    empty — ``Timer.slope`` then times two zero-arg thunks, which is exactly
    the same overhead-cancelling algebra (both share the launch + DMA path
    of their common block shapes)."""
    import functools

    from repro.inkernel.fused import FUSED_LENS, build_fused

    lens = tuple(lens or FUSED_LENS)

    def build(n: int) -> Callable:
        fn, args = build_fused(name, n, interpret=interpret)
        compiled = _cached_aot(fn, args, f"inkernel.fused.{name}",
                               f"units{n}", cache, env, dtype="float32")
        return functools.partial(compiled, *args)

    prepared = PreparedKernel(lens=lens, retry_lens=None, args=(), reps=reps,
                              _build=build)
    prepared.fn_by_len(lens[0])
    prepared.fn_by_len(lens[1])
    return prepared


def run_prepared_fused(prepared: PreparedKernel,
                       timer: Timer | None = None) -> Measurement:
    """Time a prepared fused kernel: per-workload-unit latency slope."""
    timer = timer or Timer()
    return timer.slope(prepared.fn_by_len, *prepared.lens,
                       reps=prepared.reps, retry_lens=prepared.retry_lens)


def measure_fused_full(name: str, lens: tuple[int, int] | None = None,
                       timer: Timer | None = None,
                       interpret: bool | None = None,
                       reps: int | None = None) -> Measurement:
    """Per-unit latency of one fused kernel (KV block / chunk / row block).

    Serial form of ``run_prepared_fused(prepare_fused(...))``.
    """
    return run_prepared_fused(
        prepare_fused(name, lens, interpret=interpret, reps=reps), timer)


def measure_inkernel_full(spec: OpSpec, lens: tuple[int, int] = INKERNEL_LENS,
                          shape: tuple[int, int] | None = None,
                          timer: Timer | None = None,
                          interpret: bool | None = None,
                          reps: int | None = None) -> Measurement:
    """Per-op in-kernel latency for ``spec`` with dispersion (median + MAD).

    Equivalent to ``run_prepared_inkernel(prepare_inkernel(...))`` — the
    serial form of the pipelined split.
    """
    return run_prepared_inkernel(
        prepare_inkernel(spec, lens, shape, interpret=interpret, reps=reps),
        timer)
