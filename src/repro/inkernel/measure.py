"""Slope extraction for in-kernel chains (the paper's Fig. 5 algebra).

Two kernels differing only in chain length share the identical DMA-in, DMA-out
and launch path, so ``(T(n2) - T(n1)) / (n2 - n1)`` is the pure in-pipeline
per-op cost — the same cancellation the paper gets by subtracting the
calibrated ``%clock`` read overhead. Reuses :meth:`Timer.slope` unchanged
(min-statistics noise floor included) so dispatch-level and in-kernel numbers
are produced by one algebra and stay directly comparable.
"""
from __future__ import annotations

from repro.core.chains import OpSpec
from repro.core.timing import Measurement, Timer
from repro.inkernel.factory import build_chain, tiles

# In-kernel chains are compiled (never eager), so both lengths stay short:
# fori_loop keeps compile time O(1) in n, and 8 vs 64 already puts the per-op
# signal well above the (cancelled) launch overhead.
INKERNEL_LENS = (8, 64)

# Chase step counts for the in-kernel memory rows: long enough that the
# per-load slope dominates the (cancelled) DMA-in of the ring on the VMEM
# path, short enough that the serial dependent-load chain stays cheap to run
# at both lengths even when every step streams from HBM.
CHASE_LENS = (64, 192)


def measure_chase_full(working_set_bytes: int, line_bytes: int = 64,
                       lens: tuple[int, int] = CHASE_LENS,
                       timer: Timer | None = None,
                       interpret: bool | None = None,
                       memory_space: str | None = None,
                       reps: int | None = None) -> tuple[Measurement, str]:
    """Per-load in-kernel chase latency at one working-set size.

    The same two-length :meth:`Timer.slope` extraction as the op chains: two
    kernels differing only in chase step count share the identical ring
    residency, DMA and launch path, so the slope is the pure dependent-load
    cost at whichever level the ring lives in. Returns ``(measurement,
    memory_space)`` where the space is the residency actually used —
    ``"vmem"`` (BlockSpec-resident, Table IV analog) or ``"any"``
    (HBM-streaming, Fig. 6 analog) — selected by ring footprint unless
    forced.
    """
    from repro.core.membench import build_ring
    from repro.kernels.chase import chase, select_memory_space

    timer = timer or Timer()
    ring, start = build_ring(working_set_bytes, line_bytes)
    space = (memory_space if memory_space is not None
             else select_memory_space(ring.size * 4))

    def fn_by_len(n: int):
        return lambda r, s: chase(r, s, steps=n, interpret=interpret,
                                  memory_space=space)

    m = timer.slope(fn_by_len, *lens, ring, start, reps=reps)
    return m, space


def measure_inkernel_full(spec: OpSpec, lens: tuple[int, int] = INKERNEL_LENS,
                          shape: tuple[int, int] | None = None,
                          timer: Timer | None = None,
                          interpret: bool | None = None,
                          reps: int | None = None) -> Measurement:
    """Per-op in-kernel latency for ``spec`` with dispersion (median + MAD)."""
    timer = timer or Timer()
    n1, n2 = lens
    if spec.max_chain is not None:
        n1, n2 = min(n1, max(spec.max_chain // 3, 1)), min(n2, spec.max_chain)
    carry, operands = tiles(spec, shape)

    def fn_by_len(n: int):
        return build_chain(spec, n, interpret=interpret)

    return timer.slope(fn_by_len, n1, n2, carry, *operands, reps=reps)
