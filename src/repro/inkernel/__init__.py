"""``repro.inkernel`` — the paper's *in-pipeline* probes, inside Pallas kernels.

The dispatch-level path (``repro.core.measure``) times a jitted region from
the host, so every number includes the host->device round trip that the
two-length slope must cancel. The paper instead samples ``%clock`` around one
dependent instruction *inside* the kernel. This subsystem is the TPU analog:

* :func:`build_chain` / :func:`tiles` — lower any registry ``OpSpec.step``
  into a Pallas kernel whose body is a ``fori_loop`` dependent chain on a
  VMEM-resident tile (see ``repro.kernels.opchain``);
* :func:`measure_inkernel_full` — per-op latency from the slope between two
  in-kernel chain lengths, reusing ``Timer.slope`` so the DMA + launch
  overhead cancels exactly as the paper's clock-overhead subtraction;
* :func:`supported` / :func:`supported_specs` — the lowering policy (64-bit
  carries stay on the dispatch path: TPUs lack native i64/f64 lanes);
* :func:`measure_chase_full` — the memory-hierarchy rows: the dependent
  pointer chase (``repro.kernels.chase``) at one working-set size, VMEM- or
  HBM-resident by footprint, under the same slope extraction;
* :func:`measure_fused_full` / :func:`build_fused` — the fused production
  kernels (flash_attention, flash_decode, mamba_scan, rmsnorm) as two-size
  workload slopes (``inkernel.fused.<name>`` rows), certified by
  ``repro.audit.dataflow`` and priced into zoo models by
  ``core.perfmodel``.

The scheduled front doors are :class:`repro.api.KernelChainProbe` (plan name
``inkernel``), :class:`repro.api.MemoryChaseProbe` (plan name
``memory-inkernel``) and :class:`repro.api.FusedKernelProbe` (plan name
``fused``), which add LatencyDB caching, resume and structured failures on
top. See docs/inkernel.md and docs/memory.md for the methodology mapping to
the paper.
"""
from repro.inkernel.factory import (build_chain, default_tile, supported,
                                    supported_specs, tiles)
from repro.inkernel.fused import FUSED_KERNELS, FUSED_LENS, build_fused
from repro.inkernel.measure import (CHASE_LENS, INKERNEL_LENS, PreparedKernel,
                                    measure_chase_full, measure_fused_full,
                                    measure_inkernel_full, prepare_chase,
                                    prepare_fused, prepare_inkernel,
                                    run_prepared_chase, run_prepared_fused,
                                    run_prepared_inkernel)

__all__ = [
    "CHASE_LENS", "FUSED_KERNELS", "FUSED_LENS", "INKERNEL_LENS",
    "PreparedKernel", "build_chain", "build_fused", "default_tile",
    "measure_chase_full", "measure_fused_full", "measure_inkernel_full",
    "prepare_chase", "prepare_fused", "prepare_inkernel",
    "run_prepared_chase", "run_prepared_fused", "run_prepared_inkernel",
    "supported", "supported_specs", "tiles",
]
