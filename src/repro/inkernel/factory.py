"""Lowering policy + kernel construction for in-kernel chain probes.

Turns one :class:`~repro.core.chains.OpSpec` into a runnable Pallas chain:
the carry and operand scalars become VPU-shaped tiles (every lane runs the
same dependent chain, which is also how the paper's warp executes one timed
instruction), and ``OpSpec.step`` becomes the ``fori_loop`` body of
``repro.kernels.opchain.op_chain``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.core.chains import OpSpec, default_registry
from repro.kernels.opchain import op_chain

Array = Any

# 64-bit carries stay on the dispatch path: TPUs have no native i64/f64 lanes
# and Mosaic will not lower them; x64 specs keep their Table II row via
# InstructionProbe instead.
_X64_DTYPES = ("int64", "uint64", "float64")


def supported(spec: OpSpec) -> bool:
    """True if ``spec`` can run as an in-kernel Pallas chain."""
    return not spec.requires_x64 and spec.dtype not in _X64_DTYPES


def supported_specs(registry: Sequence[OpSpec] | None = None,
                    ops: Iterable[str] | None = None,
                    categories: Iterable[str] | None = None) -> list[OpSpec]:
    """The in-kernel-eligible slice of the registry, optionally filtered."""
    registry = list(registry if registry is not None else default_registry())
    keep_ops = set(ops) if ops is not None else None
    keep_cats = set(categories) if categories is not None else None
    return [s for s in registry if supported(s)
            and (keep_ops is None or s.name in keep_ops)
            and (keep_cats is None or s.category in keep_cats)]


def default_tile(dtype: str) -> tuple[int, int]:
    """One VPU vreg for the dtype: (8, 128) sublanes x lanes, doubled
    sublanes for 16-bit packing (the TPU tiling constraint)."""
    return (16, 128) if jnp.dtype(dtype).itemsize == 2 else (8, 128)


def tiles(spec: OpSpec, shape: tuple[int, int] | None = None
          ) -> tuple[Array, tuple[Array, ...]]:
    """Carry + operand tiles for ``spec``: its scalar values, broadcast."""
    shape = shape or default_tile(spec.dtype)
    carry = jnp.full(shape, spec.init, spec.dtype)
    operands = tuple(jnp.full(shape, v, spec.dtype) for v in spec.operands)
    return carry, operands


def build_chain(spec: OpSpec, n: int, *, interpret: bool | None = None
                ) -> Callable[..., jax.Array]:
    """Jitted ``(carry_tile, *operand_tiles) -> out_tile`` of an n-long chain."""
    if not supported(spec):
        raise ValueError(
            f"spec {spec.name!r} (dtype={spec.dtype}, requires_x64="
            f"{spec.requires_x64}) cannot lower in-kernel; use the dispatch "
            "path (InstructionProbe)")
    return functools.partial(op_chain, step=spec.step, n=n, interpret=interpret)
