from repro.parallel import sharding
from repro.parallel.sharding import (Param, ShardingRules, annotate, boxed_axes,
                                     is_param, lm_rules, param_shardings, rebox,
                                     spec_tree, unbox, use_sharding,
                                     with_layer_axis)

__all__ = ["sharding", "Param", "ShardingRules", "annotate", "boxed_axes",
           "is_param", "lm_rules", "param_shardings", "rebox", "spec_tree",
           "unbox", "use_sharding", "with_layer_axis"]
