from repro.parallel import collectives, ladders, sharding
from repro.parallel.collectives import (collective_matmul, quantized_psum_mean,
                                        reference_matmul)
from repro.parallel.ladders import (DEFAULT_PAYLOADS, LADDER_KINDS, chain_fn,
                                    ladder_mesh, local_payload_bytes,
                                    payload_shape, step_wire_bytes)
from repro.parallel.sharding import (Param, ShardingRules, annotate, boxed_axes,
                                     is_param, lm_rules, param_shardings, rebox,
                                     spec_tree, unbox, use_sharding,
                                     with_layer_axis)

__all__ = ["collectives", "ladders", "sharding",
           "collective_matmul", "quantized_psum_mean", "reference_matmul",
           "DEFAULT_PAYLOADS", "LADDER_KINDS", "chain_fn", "ladder_mesh",
           "local_payload_bytes", "payload_shape", "step_wire_bytes",
           "Param", "ShardingRules", "annotate", "boxed_axes",
           "is_param", "lm_rules", "param_shardings", "rebox", "spec_tree",
           "unbox", "use_sharding", "with_layer_axis"]
