"""Logical-axis sharding: DP / FSDP / TP / EP / CP / SP on the production mesh.

Models annotate parameters (via :class:`Param` boxes) and activations (via
:func:`annotate`) with *logical* axis names; a :class:`ShardingRules` table
resolves those to mesh axes. Resolution enforces even divisibility (GSPMD
rejects uneven input shardings — verified empirically) and falls back to
replication otherwise, so e.g. 40 query heads on a 16-way model axis
automatically degrade to the context-parallel attention path (DESIGN.md §6).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Param boxes
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter leaf tagged with logical axis names (one per dim)."""

    value: Any
    axes: tuple[str | None, ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], tuple(aux))


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def unbox(tree: Any) -> Any:
    """Strip Param boxes -> raw array pytree."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def boxed_axes(tree: Any) -> Any:
    """Matching pytree of logical-axes tuples."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def rebox(values: Any, axes: Any) -> Any:
    leaves_v = jax.tree_util.tree_leaves(values)
    leaves_a, tda = jax.tree_util.tree_flatten(axes, is_leaf=_is_axes_leaf)
    assert len(leaves_v) == len(leaves_a), (len(leaves_v), len(leaves_a))
    return jax.tree_util.tree_unflatten(
        tda, [Param(v, a) for v, a in zip(leaves_v, leaves_a)])


def with_layer_axis(tree: Any, name: str = "layers") -> Any:
    """After vmap-stacked init, prefix every Param's axes with ``name``."""
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, (name,) + p.axes), tree, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------
MeshAxes = tuple[str, ...] | str | None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axes. Missing names replicate."""

    mapping: Mapping[str, MeshAxes]

    def resolve(self, axes: Sequence[str | None], shape: Sequence[int] | None,
                mesh: Mesh) -> P:
        used: set[str] = set()
        out: list[MeshAxes] = []
        for i, name in enumerate(axes):
            m = self.mapping.get(name) if name else None
            if m is None:
                out.append(None)
                continue
            parts = (m,) if isinstance(m, str) else tuple(m)
            parts = tuple(p for p in parts if p in mesh.shape and p not in used)
            if not parts:
                out.append(None)
                continue
            size = int(np.prod([mesh.shape[p] for p in parts]))
            if shape is not None and shape[i] % size != 0:
                # uneven -> replicate (GSPMD requires divisibility); callers
                # that care (attention) pick CP instead via policy.
                out.append(None)
                continue
            used.update(parts)
            out.append(parts if len(parts) > 1 else parts[0])
        return P(*out)


# Megatron-style LM defaults; per-arch configs override (see configs/).
def lm_rules(*, fsdp: bool = True, context_parallel_seq: bool = False,
             fsdp_axes: MeshAxes = ("pod", "data")) -> ShardingRules:
    m: dict[str, MeshAxes] = {
        # activations
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "cp_seq": "model" if context_parallel_seq else None,
        "kv_seq": "model",    # decode caches: flash-decode partial softmax
        "kv_hd": "model",     # decode caches: split-K alternative (§Perf)
        # params
        "embed": fsdp_axes if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv_dim": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "lstm_inner": "model",
        "layers": None,
        "conv": None,
    }
    return ShardingRules(m)


# ---------------------------------------------------------------------------
# Context: active (mesh, rules); annotate() is a no-op outside it, so smoke
# tests run the same model code without any mesh.
# ---------------------------------------------------------------------------
_CTX: contextvars.ContextVar[tuple[Mesh, ShardingRules] | None] = \
    contextvars.ContextVar("sharding_ctx", default=None)


def _mesh_ctx(mesh: Mesh):
    """Enter the mesh with whatever this jax version provides.

    jax >= 0.5 has jax.set_mesh; some 0.4.x ship jax.sharding.use_mesh; on
    older jax the explicit NamedSharding(mesh, ...) paths below don't need a
    global mesh at all, so fall back to a no-op.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext()


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: ShardingRules):
    tok = _CTX.set((mesh, rules))
    try:
        with _mesh_ctx(mesh):
            yield
    finally:
        _CTX.reset(tok)


def current() -> tuple[Mesh, ShardingRules] | None:
    return _CTX.get()


def annotate(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain an activation's sharding by logical axes (no-op w/o mesh)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.resolve(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_weight(value: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """ZeRO-3 just-in-time gather: constrain a weight to its sharding WITHOUT
    the data axes, forcing GSPMD to all-gather the FSDP shards right before
    use (wire = weight bytes once) instead of all-reducing activation
    partial-sums (wire = activation bytes per matmul) — §Perf knob."""
    ctx = _CTX.get()
    if ctx is None:
        return value
    mesh, rules = ctx
    spec = rules.resolve(axes[-value.ndim:], value.shape, mesh)
    stripped = []
    for entry in spec:
        parts = (entry,) if isinstance(entry, str) else (entry or ())
        parts = tuple(p for p in parts if p not in ("data", "pod"))
        stripped.append(parts[0] if len(parts) == 1 else (parts or None))
    return jax.lax.with_sharding_constraint(
        value, NamedSharding(mesh, P(*stripped)))


def param_shardings(boxed: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """NamedSharding pytree for a boxed param tree (for jit in_shardings)."""
    def one(p: Param):
        shape = getattr(p.value, "shape", None)
        return NamedSharding(mesh, rules.resolve(p.axes, shape, mesh))
    return jax.tree_util.tree_map(one, boxed, is_leaf=is_param)


def spec_tree(boxed: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    def one(p: Param):
        shape = getattr(p.value, "shape", None)
        return rules.resolve(p.axes, shape, mesh)
    return jax.tree_util.tree_map(one, boxed, is_leaf=is_param)


def shard_like(tree: Any, shardings: Any) -> Any:
    """with_sharding_constraint a raw pytree with a sharding pytree."""
    return jax.tree_util.tree_map(jax.lax.with_sharding_constraint, tree, shardings)
