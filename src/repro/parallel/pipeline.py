"""GPipe-style pipeline parallelism via shard_map + ppermute (opt-in PP).

Stages live on the ``stage`` mesh axis (on the production mesh this is the
``pod`` axis: one pipeline stage per pod, DP x TP inside the pod). The
schedule is the classic GPipe fill-drain loop: T = M + S - 1 ticks, activations
hop stage->stage+1 by collective-permute each tick, microbatch i occupies
stage s at tick i+s. Bubble fraction = (S-1)/(M+S-1), reported by
``bubble_fraction`` so launchers can budget microbatches.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x_micro: jax.Array, mesh: Mesh,
                     axis: str = "pod") -> jax.Array:
    """Run ``stage_fn`` as an S-stage pipeline.

    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_micro: [M, mb, ...] microbatched input (replicated across stages).
    Returns [M, mb, ...] outputs (from the last stage, broadcast).
    """
    s = mesh.shape[axis]

    def body(params, xs):                    # params: leading dim 1 (local)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        m = xs.shape[0]
        ticks = m + s - 1
        stage = lax.axis_index(axis)
        perm = [(j, (j + 1) % s) for j in range(s - 1)]   # open chain
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any) — others use the hop input
            feed = jnp.where(t < m, t, m - 1)
            inp = jnp.where(stage == 0,
                            xs[feed].astype(buf.dtype), buf)
            out = stage_fn(params, inp)
            # last stage emits microbatch t-(s-1)
            emit = t - (s - 1)
            do_emit = jnp.logical_and(stage == s - 1, emit >= 0)
            idx = jnp.clip(emit, 0, m - 1)
            outs = lax.cond(
                do_emit, lambda o: o.at[idx].set(out), lambda o: o, outs)
            buf = lax.ppermute(out, axis, perm)
            return buf, outs

        _, outs = lax.fori_loop(0, ticks, tick, (buf, outs))
        # broadcast final outputs from the last stage to everyone (psum of
        # a one-hot-by-stage buffer == broadcast)
        return lax.psum(jnp.where(stage == s - 1, outs, jnp.zeros_like(outs)),
                        axis)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(*([None] * x_micro.ndim))),
                   out_specs=P(*([None] * x_micro.ndim)),
                   check_rep=False)
    return fn(stage_params, x_micro)


def reference_forward(stage_fn, stage_params, x_micro: jax.Array) -> jax.Array:
    """Sequential oracle for tests."""
    def run_one(x):
        s = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
        for i in range(s):
            p = jax.tree_util.tree_map(lambda a: a[i], stage_params)
            x = stage_fn(p, x)
        return x
    return jax.vmap(run_one)(x_micro)
