"""Collective dependent-chain ladders: the interconnect analog of the paper's
instruction chains.

The method transfers unchanged: build a chain of ``n`` *dependent* collective
ops inside ``shard_map`` (each step consumes the previous step's carry, so the
fabric traffic is serialized exactly like the ALU chains serialize issue), time
two chain lengths, and take ``Timer.slope`` — dispatch, shard_map wrapping and
the first transfer's warm-up cancel in the subtraction. One row per
``(kind, device count, payload)`` rung: ``coll.<kind>.d<devices>.<bytes>``.

Four kinds, chosen so every step is shape-invariant (a chain needs a fixed
carry shape) while keeping the collective itself un-foldable:

* ``psum`` — ``lax.psum`` (HLO all-reduce); shape-preserving, rescaled by
  ``1/n`` so long chains stay finite.
* ``all_gather`` — ``lax.all_gather(tiled)`` followed by a *dynamic* slice at
  ``axis_index`` back to the local shard: the data-dependent start index keeps
  XLA from folding the gather into a local copy.
* ``reduce_scatter`` — ``lax.psum_scatter(tiled)`` re-tiled back up to the
  carry shape (a cheap local broadcast-concat; the wire cost is the scatter).
* ``ppermute`` — a ring rotation; shape-preserving by construction.

Wire-byte accounting mirrors :mod:`repro.core.hlo_analysis` ring-factor
conventions exactly (``wire = ring_factor(kind, n) x result_bytes``) so the
estimator's ``wire_bytes / rung_wire_bytes`` scaling is self-consistent: a
rung prices the HLO ops it is made of at ratio 1.0 by construction.

Off-TPU this runs on simulated XLA host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``); the slope then
measures the host backend's inter-device copy path, which is exactly what the
sharded-serving probes execute on the same backend.
"""
from __future__ import annotations

from typing import Any, Mapping

import numpy as np

from repro.core.hlo_analysis import LADDER_TO_COLLECTIVE, ring_factor

LADDER_KINDS = tuple(LADDER_TO_COLLECTIVE)          # psum, all_gather, ...
LADDER_AXIS = "coll"
DEFAULT_LENS = (2, 6)
DEFAULT_COLS = 128
# per-device payload rungs (bytes): small / medium / large transfers
DEFAULT_PAYLOADS = (1 << 12, 1 << 16, 1 << 20)


def ladder_mesh(devices: int):
    """A 1-axis mesh over the first ``devices`` local devices."""
    import jax
    from jax.sharding import Mesh

    avail = jax.devices()
    if devices < 1 or devices > len(avail):
        raise RuntimeError(
            f"collective ladder needs {devices} device(s), backend has "
            f"{len(avail)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={devices} for simulated host devices)")
    return Mesh(np.array(avail[:devices]), (LADDER_AXIS,))


def payload_shape(payload_bytes: int, devices: int,
                  cols: int = DEFAULT_COLS) -> tuple[int, int]:
    """Local (per-device) f32 carry shape closest to ``payload_bytes``.

    Rows are rounded up to a multiple of ``devices`` so the reduce-scatter
    step's ``scatter_dimension=0`` tiling divides evenly; the *actual* local
    byte count (which may exceed the nominal rung) is what the probe records
    in its notes.
    """
    rows = max(1, int(round(payload_bytes / (4 * cols))))
    rows = ((rows + devices - 1) // devices) * devices
    return rows, cols


def local_payload_bytes(payload_bytes: int, devices: int,
                        cols: int = DEFAULT_COLS) -> int:
    rows, cols = payload_shape(payload_bytes, devices, cols)
    return rows * cols * 4


def step_wire_bytes(kind: str, local_bytes: float, devices: int) -> float:
    """Ring-algorithm wire bytes one chain step moves, per device.

    Derived from the step's collective *result* bytes with the same factors
    :func:`repro.core.hlo_analysis.ring_factor` applies when parsing HLO —
    the two sides of the ``wire_bytes / rung_bytes`` pricing ratio must use
    one convention.
    """
    hlo_kind = LADDER_TO_COLLECTIVE[kind]
    if kind == "all_gather":
        result_bytes = local_bytes * devices       # tiled gather result
    elif kind == "reduce_scatter":
        result_bytes = local_bytes / devices       # tiled scatter result
    else:
        result_bytes = local_bytes                 # psum / ppermute preserve
    return ring_factor(hlo_kind, devices) * result_bytes


def _step(kind: str, x, axis: str, ndev: int):
    import jax.numpy as jnp
    from jax import lax

    if kind == "psum":
        return lax.psum(x, axis) * (1.0 / ndev)
    if kind == "all_gather":
        g = lax.all_gather(x, axis, axis=0, tiled=True)
        start = lax.axis_index(axis) * x.shape[0]
        return lax.dynamic_slice_in_dim(g, start, x.shape[0], 0)
    if kind == "reduce_scatter":
        s = lax.psum_scatter(x, axis, scatter_dimension=0,
                             tiled=True) * (1.0 / ndev)
        return jnp.tile(s, (ndev, 1))
    if kind == "ppermute":
        perm = [(j, (j + 1) % ndev) for j in range(ndev)]
        return lax.ppermute(x, axis, perm)
    raise ValueError(f"unknown ladder kind {kind!r}; known: {LADDER_KINDS}")


def chain_fn(kind: str, n: int, mesh):
    """``n`` dependent collective steps inside ``shard_map``, unrolled.

    Unrolled (not ``fori_loop``) so the optimized HLO carries exactly ``n``
    collective ops of the expected kind — what makes the two-lens histogram
    delta and the carry->root dependence walk in ``repro.audit`` exact.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    ndev = mesh.shape[LADDER_AXIS]

    def body(x):
        for _ in range(n):
            x = _step(kind, x, LADDER_AXIS, ndev)
        return x

    return shard_map(body, mesh=mesh, in_specs=P(LADDER_AXIS),
                     out_specs=P(LADDER_AXIS), check_rep=False)


def make_payload(mesh, payload_bytes: int, cols: int = DEFAULT_COLS):
    """The sharded global carry: local shard = one payload rung."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    ndev = mesh.shape[LADDER_AXIS]
    rows, cols = payload_shape(payload_bytes, ndev, cols)
    x = jnp.ones((ndev * rows, cols), jnp.float32)
    return jax.device_put(x, NamedSharding(mesh, P(LADDER_AXIS)))


def chain_cache_key(env: Mapping[str, str], op: str, n: int):
    """CompileCache identity of one chain compile (shared with the auditor)."""
    from repro.core.compile_cache import fidelity_key

    return fidelity_key(env, op, "O3", "float32", f"chain{n}")


def compile_chain(kind: str, n: int, mesh, x, *, op: str,
                  cache: Any = None, env: Mapping[str, str] | None = None):
    """AOT-compile one chain length, riding the compile cache when given.

    The optimized HLO text rides in the cache entry's ``extra`` payload so
    the audit pass (``repro.audit.chain_check.audit_collective``) can verify
    the chain without re-invoking XLA on a warm cache.
    """
    import jax

    def do_compile():
        return jax.jit(chain_fn(kind, n, mesh)).lower(x).compile()

    if cache is not None and env is not None:
        compiled, _, _ = cache.load_or_compile(
            chain_cache_key(env, op, n), do_compile,
            extra=lambda c: c.as_text())
        return compiled
    return do_compile()


def prepare_collective(kind: str, payload_bytes: int, devices: int,
                       lens: tuple[int, int], *, op: str,
                       cache: Any = None,
                       env: Mapping[str, str] | None = None):
    """Build + compile the two chain lens; returns ``(fn_by_len, x, bytes)``.

    ``fn_by_len`` compiles further lengths on demand — ``Timer.slope``'s
    noisy-slope retry widens the second length past the prepared pair.
    """
    mesh = ladder_mesh(devices)
    x = make_payload(mesh, payload_bytes)
    fns: dict[int, Any] = {}

    def fn_by_len(n: int):
        if n not in fns:
            fns[n] = compile_chain(kind, n, mesh, x, op=op,
                                   cache=cache, env=env)
        return fns[n]

    for n in lens:
        fn_by_len(n)
    local_bytes = local_payload_bytes(payload_bytes, devices)
    return fn_by_len, x, local_bytes


def chain_hlo_text(kind: str, payload_bytes: int, devices: int, n: int, *,
                   op: str, cache: Any = None,
                   env: Mapping[str, str] | None = None) -> str:
    """Optimized HLO of one chain compile; cache sidecars are peeked first."""
    import jax

    if cache is not None and env is not None:
        text = cache.peek_extra(chain_cache_key(env, op, n))
        if text:
            return text
    mesh = ladder_mesh(devices)
    x = make_payload(mesh, payload_bytes)
    return jax.jit(chain_fn(kind, n, mesh)).lower(x).compile().as_text()
