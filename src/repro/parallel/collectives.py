"""Explicit collectives beyond GSPMD: quantized gradient all-reduce with
error feedback, and a ppermute-overlapped collective matmul.

These are the distributed-optimization tricks layer:

* ``quantized_psum`` — int8 gradient all-reduce inside shard_map. Gradients
  are quantized per 128-block, summed in int32... actually summed in f32 of
  dequantized values (ring psum of int8 payloads would need custom reduce;
  XLA psum operates on the dequantized tensor but the WIRE cost is what the
  int8 all-gather stage pays). We implement the standard 2-phase algorithm:
  reduce-scatter in f32 on 1/N of the tensor, then all-gather the quantized
  shard — wire bytes drop ~4x vs f32 all-gather phase. Residual error is
  kept host-side per step (error feedback) so the compression is unbiased
  over time.
* ``collective_matmul`` — TP matmul where the all-gather of the activations
  is replaced by a ring of ppermutes overlapped with partial matmuls
  (Wang et al.; the TPU "collective matmul" pattern). Verifiable in HLO: no
  all-gather, N-1 collective-permutes instead.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.adamw import dequantize_i8, quantize_i8


# ------------------------------------------------------------ quantized psum
def quantized_psum_mean(grads: Any, mesh: Mesh, axis: str = "data",
                        error: Any = None) -> tuple[Any, Any]:
    """Mean-reduce gradients over ``axis`` with int8-compressed all-gather
    phase + error feedback. Returns (reduced, new_error)."""
    if error is None:
        error = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32),
                                       grads)

    n = mesh.shape[axis]

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if g.ndim < 2 or g.shape[0] % n != 0:
            out = lax.pmean(gf, axis)
            return out.astype(g.dtype), jnp.zeros_like(gf)
        # phase 1: reduce-scatter exact (f32)
        shard = lax.psum_scatter(gf, axis, scatter_dimension=0, tiled=True) / n
        # phase 2: quantize the owned shard, all-gather int8 + scales
        qs = quantize_i8(shard)
        if isinstance(qs, dict):
            deq_shard = dequantize_i8(qs, shard.shape)
            gathered_q = lax.all_gather(qs["q"], axis, axis=0, tiled=True)
            gathered_s = lax.all_gather(qs["scale"], axis, axis=0, tiled=True)
            out = dequantize_i8({"q": gathered_q, "scale": gathered_s}, gf.shape)
        else:
            deq_shard = shard
            out = lax.all_gather(shard, axis, axis=0, tiled=True)
        # error feedback: what our shard lost to quantization, re-injected
        # next step. After psum_scatter(tiled=True) device j owns rows
        # [j*rows : (j+1)*rows], so the residual must land at that offset —
        # writing block 0 on every device double-counts block 0's error and
        # drops everyone else's.
        err_shard = shard - deq_shard
        rows = shard.shape[0]
        new_e = lax.dynamic_update_slice_in_dim(
            jnp.zeros_like(gf), err_shard, lax.axis_index(axis) * rows, 0)
        return out.astype(g.dtype), new_e

    def mapped(gs, es):
        pairs = jax.tree_util.tree_map(one, gs, es)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        outs = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
        errs = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
        return outs, errs

    specs = jax.tree_util.tree_map(lambda _: P(), grads)
    fn = shard_map(mapped, mesh=mesh, in_specs=(specs, specs),
                   out_specs=(specs, specs), check_rep=False)
    return fn(grads, error)


# --------------------------------------------------------- collective matmul
def collective_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                      axis: str = "model") -> jax.Array:
    """TP matmul with the activation all-gather replaced by a ppermute ring.

    x: [B, D] sharded on dim 1 over ``axis``; w: [D, F] column-sharded on
    dim 1. Each device computes its output column shard y[:, f_j] =
    sum_k x_k @ W[rows_k, f_j] by rotating x shards around the ring and
    multiplying against the matching local row block — every step overlaps
    one ppermute with one partial matmul (no all-gather in the HLO).
    """
    n = mesh.shape[axis]

    def body(xs, ws):                  # xs: [B, D/n]; ws: [D, F/n] (local)
        dn = xs.shape[1]
        wsr = ws.reshape(n, dn, ws.shape[-1])
        perm = [(j, (j + 1) % n) for j in range(n)]
        src = lax.axis_index(axis)

        def step(i, carry):
            acc, cur = carry
            k = (src - i) % n          # origin of the shard we currently hold
            acc = acc + cur @ wsr[k]
            return acc, lax.ppermute(cur, axis, perm)

        acc0 = jnp.zeros((xs.shape[0], ws.shape[-1]), xs.dtype)
        acc, cur = lax.fori_loop(0, n - 1, step, (acc0, xs))
        k = (src - (n - 1)) % n
        return acc + cur @ wsr[k]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis), P(None, axis)),
                   out_specs=P(None, axis), check_rep=False)
    return fn(x, w)


def reference_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return x @ w.reshape(-1, w.shape[-1]) if w.ndim == 3 else x @ w
