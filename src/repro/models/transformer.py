"""Decoder-only LM assembly: init / train loss / prefill / decode step.

Layers are scanned per *period* (config.period); parameters and KV caches are
stacked over periods so the HLO stays compact at 126 layers, with costs
corrected for trip counts by the static analyzer. All functions take BOXED
params (Param leaves); jit shardings are derived from the boxes.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import blocks, common, ssm, xlstm
from repro.models.config import Layer, ModelConfig, Runtime
from repro.parallel.sharding import Param, annotate, with_layer_axis

Params = dict[str, Any]


# ------------------------------------------------------------------- blocks
def init_block(key, layer: Layer, cfg: ModelConfig) -> Params:
    mixer, ffn = layer
    k1, k2 = jax.random.split(key)
    p: Params = {}
    if mixer == "attn":
        p["mixer"] = blocks.init_attn(k1, cfg)
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg)
    elif mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(k1, cfg)
    elif mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(k1, cfg)
    if ffn == "dense":
        p["ffn"] = blocks.init_mlp(k2, cfg)
    elif ffn == "moe":
        p["ffn"] = blocks.init_moe(k2, cfg)
    return p


def block_train(p: Params, x, layer: Layer, cfg: ModelConfig, rt: Runtime,
                positions):
    """Returns (x, aux_loss, prefill_cache)."""
    mixer, ffn = layer
    cache: Params = {}
    if mixer == "attn":
        x, (k, v) = blocks.attn_train(p["mixer"], x, cfg, rt, positions)
        cache = {"k": k.astype(cfg.cdtype), "v": v.astype(cfg.cdtype)}
    elif mixer == "mamba":
        x, cache = ssm.mamba_train(p["mixer"], x, cfg, rt)
    elif mixer == "mlstm":
        x, cache = xlstm.mlstm_train(p["mixer"], x, cfg, rt)
    elif mixer == "slstm":
        x, cache = xlstm.slstm_train(p["mixer"], x, cfg, rt)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        x = blocks.mlp_apply(p["ffn"], x, cfg, rt)
    elif ffn == "moe":
        x, aux = blocks.moe_apply(p["ffn"], x, cfg, rt)
    return x, aux, cache


def block_decode(p: Params, x, cache: Params, pos, layer: Layer,
                 cfg: ModelConfig, rt: Runtime, positions=None):
    mixer, ffn = layer
    if mixer == "attn":
        x, cache = blocks.attn_decode(p["mixer"], x, cache, pos, cfg, rt, positions)
    elif mixer == "mamba":
        x, cache = ssm.mamba_decode(p["mixer"], x, cache, cfg)
    elif mixer == "mlstm":
        x, cache = xlstm.mlstm_decode(p["mixer"], x, cache, cfg)
    elif mixer == "slstm":
        x, cache = xlstm.slstm_decode(p["mixer"], x, cache, cfg)
    if ffn == "dense":
        x = blocks.mlp_apply(p["ffn"], x, cfg, rt)
    elif ffn == "moe":
        x, _ = blocks.moe_apply(p["ffn"], x, cfg, rt)
    return x, cache


def init_block_cache(layer: Layer, cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> Params:
    mixer, _ = layer
    if mixer == "attn":
        return blocks.init_attn_cache(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return ssm.init_mamba_cache(cfg, batch, dtype)
    if mixer == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch)
    if mixer == "slstm":
        return xlstm.init_slstm_cache(cfg, batch)
    return {}


# --------------------------------------------------------------------- LM
def init_lm(key, cfg: ModelConfig) -> Params:
    kk = jax.random.split(key, 3 + cfg.n_periods)

    def init_period(k):
        ks = jax.random.split(k, len(cfg.period))
        return {f"l{i}": init_block(ks[i], layer, cfg)
                for i, layer in enumerate(cfg.period)}

    periods = jax.vmap(init_period)(kk[3:])
    params: Params = {
        "embed": Param(common.trunc_normal(kk[0], (cfg.vocab_size, cfg.d_model),
                                           cfg.d_model ** -0.5, cfg.pdtype),
                       ("vocab", "embed")),
        "periods": with_layer_axis(periods),
        "final_norm": Param(jnp.ones((cfg.d_model,), cfg.pdtype), ("embed",)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Param(
            common.trunc_normal(kk[1], (cfg.vocab_size, cfg.d_model),
                                cfg.d_model ** -0.5, cfg.pdtype),
            ("vocab", "embed"))
    return params


def _embed_in(params: Params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is not None:
        x = embeds.astype(cfg.cdtype)
    else:
        x = params["embed"].value.astype(cfg.cdtype)[tokens]
    return annotate(x, "batch", "seq", None)


def _out_embed(params: Params, cfg: ModelConfig):
    return (params.get("lm_head") or params["embed"]).value


def _period_train(pp: Params, x, cfg: ModelConfig, rt: Runtime, positions,
                  want_cache: bool):
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for i, layer in enumerate(cfg.period):
        x, aux, cache = block_train(pp[f"l{i}"], x, layer, cfg, rt, positions)
        aux_total = aux_total + aux
        if want_cache:
            caches[f"l{i}"] = cache
    return x, aux_total, caches


def forward(params: Params, cfg: ModelConfig, rt: Runtime, *, tokens=None,
            embeds=None, positions=None, want_cache: bool = False):
    """Full-sequence forward. Returns (hidden [B,S,D], aux, stacked caches)."""
    x = _embed_in(params, cfg, tokens, embeds)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, pp):
        x, aux = carry
        x, a, caches = _period_train(pp, x, cfg, rt, positions, want_cache)
        return (x, aux + a), caches

    body_fn = jax.checkpoint(body) if rt.remat else body
    if rt.scan_layers:
        (x, aux), caches = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["periods"])
    else:
        aux = jnp.zeros((), jnp.float32)
        caches_list = []
        for i in range(cfg.n_periods):
            pp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["periods"])
            (x, aux), c = body_fn((x, aux), pp)
            caches_list.append(c)
        caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches_list) \
            if want_cache and caches_list else {}
    h = common.rmsnorm(x, params["final_norm"].value)
    return h, aux, caches


def train_loss(params: Params, batch: dict, cfg: ModelConfig, rt: Runtime,
               aux_weight: float = 0.01):
    h, aux, _ = forward(params, cfg, rt, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        positions=batch.get("positions"))
    xent = common.chunked_softmax_xent(h, _out_embed(params, cfg),
                                       batch["labels"], chunk=rt.xent_chunk)
    return xent + aux_weight * aux, {"xent": xent, "aux": aux}


# ------------------------------------------------------------------ serving
def pad_cache(cache: Params, cfg: ModelConfig, new_len: int) -> Params:
    """Grow attention KV caches (stacked: [P,B,S,KH,hd]) to ``new_len``."""
    def grow(path, a):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("k", "v") and a.ndim == 5 and a.shape[2] < new_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, new_len - a.shape[2])
            return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map_with_path(grow, cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    one = {f"l{i}": init_block_cache(layer, cfg, batch, max_len, dtype)
           for i, layer in enumerate(cfg.period)}
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), one)


def prefill(params: Params, cfg: ModelConfig, rt: Runtime, *, tokens=None,
            embeds=None, positions=None, last_positions=None):
    """Process the prompt; returns (last-token logits [B,V], caches).

    ``last_positions`` ([B] int32) gathers each row's logits at its *own*
    final prompt token instead of the padded batch's last column — the
    right-padded ragged-prompt case: a row whose prompt is shorter than the
    batch's ``max_len`` must be sampled from its true last token, not from
    a pad position (causality makes that gather exact: position ``len-1``
    never attends to the padding that follows it).
    """
    h, _, caches = forward(params, cfg, rt, tokens=tokens, embeds=embeds,
                           positions=positions, want_cache=True)
    if last_positions is None:
        last = h[:, -1]
    else:
        last = jnp.take_along_axis(
            h, last_positions.astype(jnp.int32)[:, None, None], axis=1)[:, 0]
    logits = common.top1_logits(last, _out_embed(params, cfg))
    return logits, caches


def decode_step(params: Params, cache: Params, tokens, pos, cfg: ModelConfig,
                rt: Runtime, positions=None):
    """One token for the whole batch. tokens: [B,1]; pos: scalar int."""
    x = _embed_in(params, cfg, tokens)

    def body(x, xs):
        pp, pc = xs
        new_c = {}
        for i, layer in enumerate(cfg.period):
            x, c = block_decode(pp[f"l{i}"], x, pc[f"l{i}"], pos, layer, cfg,
                                rt, positions)
            new_c[f"l{i}"] = c
        return x, new_c

    x, new_cache = lax.scan(body, x, (params["periods"], cache))
    h = common.rmsnorm(x, params["final_norm"].value)
    logits = common.top1_logits(h[:, 0], _out_embed(params, cfg))
    return logits, new_cache
