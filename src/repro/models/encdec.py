"""Encoder-decoder backbone (seamless-m4t text/speech transformer).

The speech frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, Se, D] to the encoder. The decoder is a
standard causal transformer with per-layer cross-attention to the encoder
memory; decode caches = self-attn KV + precomputed cross KV.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import blocks, common
from repro.models.config import ModelConfig, Runtime
from repro.parallel.sharding import Param, annotate, with_layer_axis

Params = dict[str, Any]


def init_encdec(key, cfg: ModelConfig) -> Params:
    assert cfg.n_encoder_layers > 0
    kk = jax.random.split(key, 6)

    def init_enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": blocks.init_attn(k1, cfg), "ffn": blocks.init_mlp(k2, cfg)}

    def init_dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self": blocks.init_attn(k1, cfg),
                "cross": blocks.init_attn(k2, cfg),
                "ffn": blocks.init_mlp(k3, cfg)}

    enc_keys = jax.random.split(kk[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(kk[1], cfg.n_layers)
    return {
        "embed": Param(common.trunc_normal(kk[2], (cfg.vocab_size, cfg.d_model),
                                           cfg.d_model ** -0.5, cfg.pdtype),
                       ("vocab", "embed")),
        "encoder": with_layer_axis(jax.vmap(init_enc_layer)(enc_keys)),
        "enc_norm": Param(jnp.ones((cfg.d_model,), cfg.pdtype), ("embed",)),
        "decoder": with_layer_axis(jax.vmap(init_dec_layer)(dec_keys)),
        "final_norm": Param(jnp.ones((cfg.d_model,), cfg.pdtype), ("embed",)),
    }


def encode(params: Params, cfg: ModelConfig, rt: Runtime, frames: jax.Array):
    """frames: [B,Se,D] precomputed frontend embeddings -> memory [B,Se,D]."""
    x = annotate(frames.astype(cfg.cdtype), "batch", "seq", None)
    b, se = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))

    def body(x, lp):
        x, _ = blocks.attn_train(lp["attn"], x, cfg, rt, positions, causal=False)
        x = blocks.mlp_apply(lp["ffn"], x, cfg)
        return x, None

    body_fn = jax.checkpoint(body) if rt.remat else body
    x, _ = lax.scan(body_fn, x, params["encoder"])
    return common.rmsnorm(x, params["enc_norm"].value)


def decode_train(params: Params, cfg: ModelConfig, rt: Runtime, memory,
                 tokens: jax.Array):
    x = params["embed"].value.astype(cfg.cdtype)[tokens]
    x = annotate(x, "batch", "seq", None)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        x, (k, v) = blocks.attn_train(lp["self"], x, cfg, rt, positions)
        x, (ck, cv) = blocks.attn_train(lp["cross"], x, cfg, rt, None, kv=memory)
        x = blocks.mlp_apply(lp["ffn"], x, cfg)
        return x, {"k": k.astype(cfg.cdtype), "v": v.astype(cfg.cdtype),
                   "ck": ck.astype(cfg.cdtype), "cv": cv.astype(cfg.cdtype)}

    body_fn = jax.checkpoint(body) if rt.remat else body
    x, caches = lax.scan(body_fn, x, params["decoder"])
    return common.rmsnorm(x, params["final_norm"].value), caches


def train_loss(params: Params, batch: dict, cfg: ModelConfig, rt: Runtime):
    memory = encode(params, cfg, rt, batch["frames"])
    h, _ = decode_train(params, cfg, rt, memory, batch["tokens"])
    xent = common.chunked_softmax_xent(h, params["embed"].value, batch["labels"],
                                       chunk=rt.xent_chunk)
    return xent, {"xent": xent}


def prefill(params: Params, cfg: ModelConfig, rt: Runtime, frames, tokens):
    """Encode + teacher-forced prompt pass; returns (logits, caches)."""
    memory = encode(params, cfg, rt, frames)
    h, caches = decode_train(params, cfg, rt, memory, tokens)
    logits = common.top1_logits(h[:, -1], params["embed"].value)
    return logits, caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int, dtype):
    kh, hd = cfg.n_kv_heads, cfg.hd
    one = {
        "k": jnp.zeros((batch, max_len, kh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kh, hd), dtype),
        "ck": jnp.zeros((batch, enc_len, kh, hd), dtype),
        "cv": jnp.zeros((batch, enc_len, kh, hd), dtype),
    }
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)


def decode_step(params: Params, cache: Params, tokens, pos, cfg: ModelConfig,
                rt: Runtime):
    """tokens: [B,1]; cache: stacked {k,v,ck,cv}."""
    x = params["embed"].value.astype(cfg.cdtype)[tokens]

    def body(x, xs):
        lp, lc = xs
        x, new_self = blocks.attn_decode(lp["self"], x, {"k": lc["k"], "v": lc["v"]},
                                         pos, cfg, rt)
        x = blocks.attn_cross_decode(lp["cross"], x, (lc["ck"], lc["cv"]), cfg)
        x = blocks.mlp_apply(lp["ffn"], x, cfg)
        return x, {"k": new_self["k"], "v": new_self["v"],
                   "ck": lc["ck"], "cv": lc["cv"]}

    x, new_cache = lax.scan(body, x, (params["decoder"], cache))
    h = common.rmsnorm(x, params["final_norm"].value)
    logits = common.top1_logits(h[:, 0], params["embed"].value)
    return logits, new_cache
