"""Shared model components: norms, rotary embeddings, attention, losses.

Attention comes in three implementations with one math:
  * ``plain``      — einsum + mask; short sequences / smoke tests.
  * ``blockwise``  — lax.scan online-softmax over KV blocks; memory-bounded,
                     used by full-size dry-runs (XLA-native flash equivalent),
                     and serves as the reference for the Pallas kernel.
  * ``pallas``     — kernels/flash_attention (real TPU path, opt-in).
GQA is native (kv heads broadcast over groups); CP (context parallelism) is
purely a matter of the logical-axis annotations callers apply.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.parallel.sharding import Param, annotate

NEG_INF = -1e30


def fit_chunk(s: int, preferred: int) -> int:
    """Largest divisor of ``s`` that is <= preferred (graceful chunking)."""
    c = max(min(preferred, s), 1)
    while s % c:
        c -= 1
    return c


# ---------------------------------------------------------------------- init
def trunc_normal(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_param(key, d_in: int, d_out: int, axes: tuple[str | None, ...],
                dtype, *, shape: tuple[int, ...] | None = None) -> Param:
    shape = shape or (d_in, d_out)
    return Param(trunc_normal(key, shape, (1.0 / max(d_in, 1)) ** 0.5, dtype), axes)


# ---------------------------------------------------------------------- norm
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(x.dtype) * w.astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: [B,S,H,D]; positions: [B,S] (int). Pairwise (x0,x1) rotation."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                 # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 1e4) -> jax.Array:
    """Qwen2-VL M-RoPE. x: [B,S,H,D]; positions: [3,B,S] (t,h,w streams).

    Each frequency band is driven by one of the three position streams,
    band widths given by ``sections`` (sum == D/2).
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = jnp.asarray(rope_freqs(d, theta))                  # [D/2]
    # section id per frequency -> which position stream drives it
    sec_id = np.repeat(np.arange(len(sections)), sections)     # [D/2]
    pos = positions.astype(jnp.float32)                        # [3,B,S]
    pos_per_freq = pos[sec_id]                                 # [D/2,B,S]
    angles = jnp.moveaxis(pos_per_freq, 0, -1) * freqs         # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def plain_attention(q, k, v, *, causal: bool, q_offset: jax.Array | int = 0,
                    kv_len: jax.Array | None = None) -> jax.Array:
    """q: [B,Sq,H,D]; k,v: [B,Sk,KH,D]. f32 softmax.

    ``kv_len``: optional [B] valid-cache lengths (ragged batches).
    """
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d) * (d ** -0.5)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None] + q_offset            # [sq, sk] broadcast
    kpos = jnp.arange(sk)[None, :]
    mask = (qpos >= kpos) if causal else jnp.ones((sq, sk), bool)
    mask = jnp.broadcast_to(mask[None, None, None], logits.shape)
    if kv_len is not None:
        valid = kpos[None] < jnp.asarray(kv_len).reshape(b, 1, 1)  # [B,1,sk]
        mask &= valid[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(jnp.float32)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, block_k: int = 1024,
                        q_offset: int = 0, p_dtype=jnp.float32) -> jax.Array:
    """Online-softmax over KV blocks via lax.scan: O(S·bk) live memory.

    This is what makes 32k-prefill dry-runs fit: scores are never
    materialized beyond [*, Sq, block_k].
    """
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    block_k = fit_chunk(sk, block_k)
    nk = sk // block_k
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d) * (d ** -0.5)
    kb = k.astype(jnp.float32).reshape(b, nk, block_k, kh, d)
    vb = v.astype(jnp.float32).reshape(b, nk, block_k, kh, d)
    qpos = jnp.arange(sq)[:, None] + q_offset

    def step(carry, inputs):
        m, l, acc = carry
        kblk, vblk, ki = inputs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kblk)
        if causal:
            kpos = ki * block_k + jnp.arange(block_k)[None, :]
            logits = jnp.where((qpos >= kpos)[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # p_dtype=bf16 halves the dominant HBM term of XLA blockwise
        # attention (the [.., sq, bk] prob tile written between the two dots)
        # at <=1e-3 softmax error — §Perf knob; f32 is the faithful default.
        acc = acc * alpha[..., 0, None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(p_dtype), vblk.astype(p_dtype)
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kh, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)
    out = jnp.moveaxis(out, 3, 1)          # [b, sq, kh, g, d]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q, k, v, kv_len) -> jax.Array:
    """One-token attention vs cache. q: [B,H,D]; k,v: [B,S,KH,D].

    Works transparently with a sequence-sharded KV cache: the softmax
    reduction over S lowers to partial-softmax + cross-shard combine under
    GSPMD (flash-decoding's LSE combine).
    """
    b, h, d = q.shape
    _, s, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, kh, g, d) * (d ** -0.5)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    valid = jnp.arange(s)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, impl: str = "auto",
              q_offset: int = 0, block_k: int = 1024,
              p_dtype=jnp.float32) -> jax.Array:
    sk = k.shape[1]
    if impl == "auto":
        impl = "blockwise" if sk >= 4096 else "plain"
    if impl == "plain":
        return plain_attention(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "blockwise":
        return blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   block_k=min(block_k, sk), p_dtype=p_dtype)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal)
    raise ValueError(impl)


# -------------------------------------------------------------------- losses
def chunked_softmax_xent(h: jax.Array, emb: jax.Array, labels: jax.Array,
                         *, chunk: int = 512, logit_dtype=jnp.float32
                         ) -> jax.Array:
    """Cross-entropy without materializing [tokens, vocab] logits.

    h: [B,S,D] final hidden; emb: [V,D] output embedding; labels: [B,S].
    Sequence is scanned in chunks; per-chunk logits live only transiently
    (and vocab stays sharded over the model axis under GSPMD).
    """
    b, s, d = h.shape
    v = emb.shape[0]
    chunk = fit_chunk(s, chunk)
    n = s // chunk
    hc = h.reshape(b, n, chunk, d)
    lc = labels.reshape(b, n, chunk)

    def step(tot, inputs):
        hx, lx = inputs
        logits = jnp.einsum("bcd,vd->bcv", hx.astype(logit_dtype),
                            emb.astype(logit_dtype))
        logits = annotate(logits, "batch", None, "act_vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = lax.scan(step, jnp.zeros((), logit_dtype),
                        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return total / (b * s)


def top1_logits(h_last: jax.Array, emb: jax.Array) -> jax.Array:
    """Decode-step logits: h_last [B,D] x emb [V,D] -> [B,V]."""
    logits = jnp.einsum("bd,vd->bv", h_last.astype(jnp.float32),
                        emb.astype(jnp.float32))
    return annotate(logits, "batch", "act_vocab")
