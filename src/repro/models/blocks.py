"""Attention / MLP / MoE blocks (init + apply), logical-axis annotated."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common
from repro.models.config import ModelConfig, Runtime
from repro.parallel.sharding import Param, annotate, gather_weight

Params = dict[str, Any]


# =========================================================== attention block
def init_attn(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "wq": common.dense_param(ks[0], d, h * hd, ("embed", "heads", "head_dim"),
                                 cfg.pdtype, shape=(d, h, hd)),
        "wk": common.dense_param(ks[1], d, kh * hd, ("embed", "kv_heads", "head_dim"),
                                 cfg.pdtype, shape=(d, kh, hd)),
        "wv": common.dense_param(ks[2], d, kh * hd, ("embed", "kv_heads", "head_dim"),
                                 cfg.pdtype, shape=(d, kh, hd)),
        "wo": common.dense_param(ks[3], h * hd, d, ("heads", "head_dim", "embed"),
                                 cfg.pdtype, shape=(h, hd, d)),
    }
    return p


def _w(p: Params, name: str, cd, rt: Runtime | None = None):
    val = p[name].value.astype(cd)
    if rt is not None and rt.fsdp_gather_weights:
        val = gather_weight(val, p[name].axes)
    return val


def _project_qkv(p: Params, x, cfg: ModelConfig, rt: Runtime | None = None):
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, _w(p, "wq", cd, rt))
    k = jnp.einsum("bsd,dhk->bshk", x, _w(p, "wk", cd, rt))
    v = jnp.einsum("bsd,dhk->bshk", x, _w(p, "wv", cd, rt))
    return q, k, v


def _rope(cfg: ModelConfig, q, k, positions):
    if positions is None:
        return q, k
    if cfg.mrope_sections is not None:
        q = common.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = common.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _annotate_qkv(cfg: ModelConfig, q, k, v):
    if cfg.attn_parallelism == "heads":
        q = annotate(q, "batch", "seq", "act_heads", None)
        k = annotate(k, "batch", "seq", "act_heads", None)
        v = annotate(v, "batch", "seq", "act_heads", None)
    else:  # context parallel: shard q rows, replicate kv heads
        q = annotate(q, "batch", "cp_seq", None, None)
        k = annotate(k, "batch", None, None, None)
        v = annotate(v, "batch", None, None, None)
    return q, k, v


def attn_train(p: Params, x, cfg: ModelConfig, rt: Runtime, positions,
               *, causal: bool = True, kv: jax.Array | None = None,
               kv_positions=None):
    """Full-sequence attention (train / prefill). x: [B,S,D].

    ``kv``: optional encoder memory for cross-attention (bidirectional).
    """
    h = common.rmsnorm(x, p["norm"].value) if cfg.norm == "rmsnorm" else x
    src = h if kv is None else kv
    q = jnp.einsum("bsd,dhk->bshk", h, _w(p, "wq", cfg.cdtype, rt))
    k = jnp.einsum("bsd,dhk->bshk", src, _w(p, "wk", cfg.cdtype, rt))
    v = jnp.einsum("bsd,dhk->bshk", src, _w(p, "wv", cfg.cdtype, rt))
    if kv is None:
        q, k = _rope(cfg, q, k, positions)
    q, k, v = _annotate_qkv(cfg, q, k, v)
    out = common.attention(q, k, v, causal=causal and kv is None,
                           impl=rt.attn_impl, block_k=rt.block_k,
                           p_dtype=jnp.dtype(rt.attn_p_dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, _w(p, "wo", cfg.cdtype, rt))
    return x + annotate(y, "batch", "seq", None), (k, v)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    kh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, kh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kh, hd), dtype),
    }


def attn_decode(p: Params, x, cache: Params, pos, cfg: ModelConfig, rt: Runtime,
                positions=None):
    """One-token step. x: [B,1,D]; cache k/v: [B,Smax,KH,hd].

    ``pos`` is either a scalar (the whole batch decodes in lockstep at one
    position — the static-batch path) or a ``[B]`` int32 array of *per-row*
    positions (the continuous-batching path: every slot sits at its own
    depth, so the KV write is a per-row scatter and the attention mask a
    per-row ``kv_len``).
    """
    b = x.shape[0]
    h = common.rmsnorm(x, p["norm"].value) if cfg.norm == "rmsnorm" else x
    q, k, v = _project_qkv(p, h, cfg)
    pos_arr = jnp.asarray(pos, jnp.int32)
    if positions is None:
        positions = (jnp.full((b, 1), pos_arr, jnp.int32)
                     if pos_arr.ndim == 0 else pos_arr[:, None])
    q, k = _rope(cfg, q, k, positions)
    if pos_arr.ndim == 0:
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    else:
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, pos_arr].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, pos_arr].set(v[:, 0].astype(cache["v"].dtype))
    if rt.cache_shard == "head_dim":
        # split-K layout: the in-place cache write stays shard-local (a DUS
        # into a seq-sharded buffer makes GSPMD all-gather the whole cache —
        # measured 16 GiB/step on jamba long_500k; §Perf).
        ck = annotate(ck, "batch", None, None, "kv_hd")
        cv = annotate(cv, "batch", None, None, "kv_hd")
    elif cfg.attn_parallelism == "heads":
        ck = annotate(ck, "batch", "kv_seq", "kv_heads", None)
        cv = annotate(cv, "batch", "kv_seq", "kv_heads", None)
    else:
        ck = annotate(ck, "batch", "kv_seq", None, None)
        cv = annotate(cv, "batch", "kv_seq", None, None)
    out = common.decode_attention(q[:, 0], ck, cv, kv_len=pos + 1)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].value.astype(cfg.cdtype))[:, None]
    return x + y, {"k": ck, "v": cv}


def attn_cross_decode(p: Params, x, mem_kv, cfg: ModelConfig):
    """Cross-attention decode step against precomputed encoder memory."""
    h = common.rmsnorm(x, p["norm"].value)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].value.astype(cfg.cdtype))
    k, v = mem_kv
    out = common.decode_attention(q[:, 0], k, v, kv_len=k.shape[1])
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].value.astype(cfg.cdtype))[:, None]
    return x + y


# ================================================================= MLP block
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "wg": common.dense_param(ks[0], d, f, ("embed", "mlp"), cfg.pdtype),
        "wu": common.dense_param(ks[1], d, f, ("embed", "mlp"), cfg.pdtype),
        "wd": common.dense_param(ks[2], f, d, ("mlp", "embed"), cfg.pdtype),
    }


def mlp_apply(p: Params, x, cfg: ModelConfig, rt: Runtime | None = None):
    h = common.rmsnorm(x, p["norm"].value)
    cd = cfg.cdtype
    g = jnp.einsum("bsd,df->bsf", h, _w(p, "wg", cd, rt))
    u = jnp.einsum("bsd,df->bsf", h, _w(p, "wu", cd, rt))
    g = annotate(jax.nn.silu(g) * u, "batch", "seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", g, _w(p, "wd", cd, rt))
    return x + annotate(y, "batch", "seq", None)


# ================================================================= MoE block
def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "router": common.dense_param(ks[0], d, e, ("embed", None), cfg.pdtype),
        "wg": common.dense_param(ks[1], d, f, ("experts", "embed", "expert_mlp"),
                                 cfg.pdtype, shape=(e, d, f)),
        "wu": common.dense_param(ks[2], d, f, ("experts", "embed", "expert_mlp"),
                                 cfg.pdtype, shape=(e, d, f)),
        "wd": common.dense_param(ks[3], f, d, ("experts", "expert_mlp", "embed"),
                                 cfg.pdtype, shape=(e, f, d)),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg)
    return p


def _dispatch_indices(expert_idx: jax.Array, n_experts: int, capacity: int):
    """Sort-based dispatch within each group. expert_idx: [G, N] -> slots.

    Returns (slot [G,N] in [0, E*C] with E*C == dropped, inv_order [G,N]).
    """
    g, n = expert_idx.shape
    order = jnp.argsort(expert_idx, axis=-1, stable=True)          # [G,N]
    sorted_e = jnp.take_along_axis(expert_idx, order, axis=-1)
    gi = jnp.arange(g)[:, None]
    counts = jnp.zeros((g, n_experts), jnp.int32).at[gi, expert_idx].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts                  # exclusive
    pos_in_e = jnp.arange(n)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    keep = pos_in_e < capacity
    slot_sorted = jnp.where(keep, sorted_e * capacity + pos_in_e, n_experts * capacity)
    # unsort the slot assignment back to token order
    slot = jnp.zeros((g, n), jnp.int32).at[gi, order].set(slot_sorted)
    return slot


def moe_apply(p: Params, x, cfg: ModelConfig, rt: Runtime):
    """Token-choice top-k MoE with sort-based capacity dispatch.

    Tokens are regrouped as [G, N/G] with G == data shards so routing stays
    shard-local; the dispatch scatter across the expert-sharded buffer is the
    EP boundary (GSPMD emits the all-to-all/all-gather there).
    """
    b, s, d = x.shape
    e, k, cd = cfg.n_experts, cfg.top_k, cfg.cdtype
    h = common.rmsnorm(x, p["norm"].value)
    n_tok = b * s
    if rt.moe_gather_decode and n_tok <= 256:
        return _moe_gather_few_tokens(p, x, h, cfg)
    g = rt.moe_groups if n_tok % max(rt.moe_groups, 1) == 0 else 1
    ng = n_tok // g
    xt = annotate(h.reshape(g, ng, d), "batch", None, None)

    logits = jnp.einsum("gnd,de->gne", xt.astype(jnp.float32),
                        p["router"].value.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(gates, k)                             # [G,N,k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    cap = max(int(cfg.capacity_factor * ng / e) // 8 * 8, 8)
    gi = jnp.arange(g)[:, None]
    out = jnp.zeros((g, ng, d), cd)
    for slot_k in range(k):
        slot = _dispatch_indices(top_e[..., slot_k], e, cap)       # [G,N]
        buf = jnp.zeros((g, e * cap + 1, d), cd)
        buf = buf.at[gi, slot].set(xt.astype(cd), mode="drop")
        ein = annotate(buf[:, :e * cap].reshape(g, e, cap, d),
                       "batch", "experts", None, None)
        hg = jnp.einsum("gecd,edf->gecf", ein, p["wg"].value.astype(cd))
        hu = jnp.einsum("gecd,edf->gecf", ein, p["wu"].value.astype(cd))
        hh = annotate(jax.nn.silu(hg) * hu, "batch", "experts", None, None)
        eout = jnp.einsum("gecf,efd->gecd", hh, p["wd"].value.astype(cd))
        if rt.moe_combine_reshard:
            # Reshard expert outputs back to token-major BEFORE the combine
            # gather: GSPMD then moves each token's row once (all-to-all
            # shaped) instead of all-gathering the whole [G,E,C,D] buffer to
            # every model shard — §Perf knob for the EP return path.
            eout = annotate(eout, "batch", None, None, None)
        flat = jnp.concatenate(
            [eout.reshape(g, e * cap, d), jnp.zeros((g, 1, d), cd)], axis=1)
        gathered = jnp.take_along_axis(flat, slot[..., None], axis=1)   # [G,N,D]
        out = out + gathered * top_w[..., slot_k, None].astype(cd)

    y = out.reshape(b, s, d)
    if "shared" in p:
        # shared expert runs densely on all tokens; reuse mlp without residual
        y = y + (mlp_apply(p["shared"], x, cfg) - x)
    aux = _load_balance_loss(gates, top_e, e)
    return x + annotate(y, "batch", "seq", None), aux


def _moe_gather_few_tokens(p: Params, x, h, cfg: ModelConfig):
    """Decode-path MoE: gather ONLY the routed experts' weights.

    Dense capacity dispatch reads every expert's FFN from HBM even for one
    token; at batch<=256 tokens it is strictly cheaper to move k expert
    weight slices per token than all E of them — this is what drops the
    long_500k/decode collective+memory terms (§Perf)."""
    b, s, d = x.shape
    k, cd = cfg.top_k, cfg.cdtype
    hf = h.reshape(b * s, d)
    logits = jnp.einsum("nd,de->ne", hf.astype(jnp.float32),
                        p["router"].value.astype(jnp.float32))
    top_w, top_e = lax.top_k(jax.nn.softmax(logits, -1), k)      # [N,k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    wg = p["wg"].value[top_e]        # [N,k,D,F] gathered slices
    wu = p["wu"].value[top_e]
    wd = p["wd"].value[top_e]
    hg = jnp.einsum("nd,nkdf->nkf", hf, wg.astype(cd))
    hu = jnp.einsum("nd,nkdf->nkf", hf, wu.astype(cd))
    eo = jnp.einsum("nkf,nkfd->nkd", jax.nn.silu(hg) * hu, wd.astype(cd))
    y = jnp.einsum("nk,nkd->nd", top_w.astype(cd), eo).reshape(b, s, d)
    if "shared" in p:
        y = y + (mlp_apply(p["shared"], x, cfg) - x)
    return x + y, jnp.zeros((), jnp.float32)


def _load_balance_loss(gates, top_e, n_experts: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss."""
    me = jnp.mean(gates, axis=(0, 1))                      # [E]
    g, n, k = top_e.shape
    gi = jnp.arange(g)[:, None, None]
    counts = jnp.zeros((g, n_experts), jnp.float32).at[
        jnp.broadcast_to(gi, top_e.shape), top_e].add(1.0)
    ce = jnp.mean(counts, axis=0) / (n * k)                # [E]
    return n_experts * jnp.sum(me * ce)
