from repro.models import blocks, common, encdec, ssm, transformer, xlstm
from repro.models.config import Layer, ModelConfig, Runtime

__all__ = ["blocks", "common", "encdec", "ssm", "transformer", "xlstm",
           "Layer", "ModelConfig", "Runtime"]
