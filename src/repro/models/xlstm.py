"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel train) and sLSTM
(scalar memory, sequential recurrence with exponential-gate stabilization).

mLSTM training uses the chunkwise linear-attention form: intra-chunk decayed
attention + inter-chunk [dh x dh] state carry (f32). The decode path is the
exact stabilized recurrence from the xLSTM paper (m-state tracked). The
chunked path omits the per-position m stabilizer (f32 + bounded random-init
gates keep it finite; tests compare against the recurrent reference).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common
from repro.models.config import ModelConfig, Runtime
from repro.parallel.sharding import Param, annotate

Params = dict[str, Any]


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "up": common.dense_param(ks[0], d, 2 * di, ("embed", "lstm_inner"), cfg.pdtype),
        "conv_w": Param(common.trunc_normal(ks[1], (di, 4), 0.5, cfg.pdtype),
                        ("lstm_inner", "conv")),
        "conv_b": Param(jnp.zeros((di,), cfg.pdtype), ("lstm_inner",)),
        "wq": common.dense_param(ks[2], di, di, ("lstm_inner", None), cfg.pdtype),
        "wk": common.dense_param(ks[3], di, di, ("lstm_inner", None), cfg.pdtype),
        "wv": common.dense_param(ks[4], di, di, ("lstm_inner", None), cfg.pdtype),
        "wi": common.dense_param(ks[5], di, h, ("lstm_inner", None), cfg.pdtype),
        "wf": common.dense_param(ks[6], di, h, ("lstm_inner", None), cfg.pdtype),
        "gn": Param(jnp.ones((di,), cfg.pdtype), ("lstm_inner",)),
        "down": common.dense_param(ks[7], di, d, ("lstm_inner", "embed"), cfg.pdtype),
    }


def _mlstm_qkvif(p: Params, x, cfg: ModelConfig):
    cd = cfg.cdtype
    h = common.rmsnorm(x, p["norm"].value)
    up = jnp.einsum("bsd,de->bse", h, p["up"].value.astype(cd))
    xm, z = jnp.split(up, 2, axis=-1)                       # [B,S,Di]
    xm = annotate(xm, "batch", "seq", "act_mlp")
    from repro.models.ssm import _causal_conv
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"].value.astype(cd),
                                  p["conv_b"].value.astype(cd)))
    nh = cfg.n_heads
    b, s, di = xc.shape
    dh = di // nh
    q = jnp.einsum("bsi,ij->bsj", xc, p["wq"].value.astype(cd)).reshape(b, s, nh, dh)
    k = jnp.einsum("bsi,ij->bsj", xc, p["wk"].value.astype(cd)).reshape(b, s, nh, dh)
    v = jnp.einsum("bsi,ij->bsj", xm, p["wv"].value.astype(cd)).reshape(b, s, nh, dh)
    ig = jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32),
                    p["wi"].value.astype(jnp.float32)) - 4.0   # small init inputs
    fg = jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32),
                    p["wf"].value.astype(jnp.float32)) + 4.0   # long memory init
    return q, k, v, ig, fg, z, xm


def _mlstm_chunked(q, k, v, ig, fg, chunk: int):
    """Chunkwise parallel mLSTM. q,k,v: [B,S,H,dh]; ig,fg: [B,S,H] (f32)."""
    b, s, nh, dh = q.shape
    lc = common.fit_chunk(s, chunk)
    nc = s // lc
    scale = dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg)                              # [B,S,H]

    def reshape_c(t, feat):
        return t.reshape((b, nc, lc) + feat)

    qc, kc, vc = (reshape_c(t, (nh, dh)) for t in (qf, kf, vf))
    ic, fc = reshape_c(ig, (nh,)), reshape_c(logf, (nh,))

    def chunk_step(carry, xs):
        c_state, n_state = carry                               # [B,H,dh,dh], [B,H,dh]
        qk, kk, vk, ik, fk = xs                                # [B,Lc,...]
        fcum = jnp.cumsum(fk, axis=1)                          # [B,Lc,H]
        ftot = fcum[:, -1]                                     # [B,H]
        # intra-chunk decayed attention
        di_ = fcum[:, :, None] - fcum[:, None, :] + ik[:, None, :]   # [B,i,j,H]
        tri = jnp.tril(jnp.ones((lc, lc), bool))
        dmat = jnp.where(tri[None, :, :, None], jnp.exp(di_), 0.0)
        sc = jnp.einsum("bihd,bjhd->bijh", qk, kk) * dmat
        h_intra = jnp.einsum("bijh,bjhd->bihd", sc, vk)
        norm_intra = jnp.sum(sc, axis=2)                       # [B,i,H]
        # inter-chunk contribution
        decay_i = jnp.exp(fcum)                                # [B,Lc,H]
        h_inter = jnp.einsum("bihd,bhde->bihe", qk * decay_i[..., None], c_state)
        norm_inter = jnp.einsum("bihd,bhd->bih", qk * decay_i[..., None], n_state)
        norm = jnp.maximum(jnp.abs(norm_intra + norm_inter), 1.0)
        h_out = (h_intra + h_inter) / norm[..., None]
        # state update
        dec_j = jnp.exp(ftot[:, None] - fcum + ik)             # [B,Lc,H]
        c_new = jnp.exp(ftot)[..., None, None] * c_state + \
            jnp.einsum("bjhd,bjhe->bhde", kk * dec_j[..., None], vk)
        n_new = jnp.exp(ftot)[..., None] * n_state + \
            jnp.sum(kk * dec_j[..., None], axis=1)
        return (c_new, n_new), h_out

    c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    (cf, nf), hs = lax.scan(chunk_step, (c0, n0),
                            tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, ic, fc)))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh * dh)
    return h, (cf, nf)


def mlstm_train(p: Params, x, cfg: ModelConfig, rt: Runtime):
    q, k, v, ig, fg, z, xm = _mlstm_qkvif(p, x, cfg)
    h, (cf, nf) = _mlstm_chunked(q, k, v, ig, fg, rt.mlstm_chunk)
    h = common.rmsnorm(h.astype(cfg.cdtype), p["gn"].value) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", h, p["down"].value.astype(cfg.cdtype))
    cache = {"c": cf, "n": nf, "m": jnp.zeros(cf.shape[:2], jnp.float32),
             "conv": xm[:, -3:].astype(jnp.float32)}
    return x + annotate(out, "batch", "seq", None), cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    dh = di // nh
    return {
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),
    }


def mlstm_decode(p: Params, x, cache: Params, cfg: ModelConfig):
    """Exact stabilized recurrence (one step). x: [B,1,D]."""
    cd = cfg.cdtype
    hN = common.rmsnorm(x, p["norm"].value)
    up = jnp.einsum("bsd,de->bse", hN, p["up"].value.astype(cd))
    xm, z = jnp.split(up, 2, axis=-1)
    hist = jnp.concatenate([cache["conv"], xm[:, 0][:, None].astype(jnp.float32)], axis=1)
    w = p["conv_w"].value.astype(jnp.float32)
    conv = jnp.einsum("bki,ik->bi", hist, w) + p["conv_b"].value.astype(jnp.float32)
    xc = jax.nn.silu(conv)                                     # [B,Di]
    nh = cfg.n_heads
    b, di = xc.shape
    dh = di // nh
    f32 = jnp.float32
    q = (xc @ p["wq"].value.astype(f32)).reshape(b, nh, dh) * dh ** -0.5
    k = (xc @ p["wk"].value.astype(f32)).reshape(b, nh, dh)
    v = (xm[:, 0].astype(f32) @ p["wv"].value.astype(f32)).reshape(b, nh, dh)
    ig = xc @ p["wi"].value.astype(f32) - 4.0                  # [B,H]
    fg = jax.nn.log_sigmoid(xc @ p["wf"].value.astype(f32) + 4.0)
    m_new = jnp.maximum(fg + cache["m"], ig)
    fs = jnp.exp(fg + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(ig - m_new)[..., None]
    c_new = fs[..., None] * cache["c"] + is_[..., None] * k[..., None] * v[..., None, :]
    n_new = fs * cache["n"] + is_ * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, di)
    h = common.rmsnorm(h.astype(cd), p["gn"].value) * jax.nn.silu(z[:, 0])
    out = (h @ p["down"].value.astype(cd))[:, None]
    return x + out, {"c": c_new, "n": n_new, "m": m_new, "conv": hist[:, 1:]}


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "w": common.dense_param(ks[0], d, 4 * d, ("embed", "lstm_inner"), cfg.pdtype),
        "r": Param(common.trunc_normal(ks[1], (nh, dh, 4 * dh), dh ** -0.5, cfg.pdtype),
                   (None, None, None)),
        "b": Param(jnp.zeros((4 * d,), cfg.pdtype), ("lstm_inner",)),
        "gn": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "out": common.dense_param(ks[2], d, d, ("embed", "embed2"), cfg.pdtype),
    }


def _slstm_cell(wx_t, state, r, nh, dh):
    """wx_t: [B,4D] precomputed input path; state: (c,n,h,m) each [B,D]."""
    c, n, h, m = state
    b = wx_t.shape[0]
    hh = h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, r).reshape(b, 4 * nh * dh)
    gates = wx_t + rec
    zt, it, ft, ot = jnp.split(gates, 4, axis=-1)              # [B,D] each
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * zt
    n_new = f_ * n + i_
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_train(p: Params, x, cfg: ModelConfig, rt: Runtime):
    cd = cfg.cdtype
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    hN = common.rmsnorm(x, p["norm"].value)
    wx = (jnp.einsum("bsd,de->bse", hN, p["w"].value.astype(cd))
          + p["b"].value.astype(cd)).astype(jnp.float32)
    r = p["r"].value.astype(jnp.float32)

    def step(state, wx_t):
        new = _slstm_cell(wx_t, state, r, nh, dh)
        return new, new[2]

    z = jnp.zeros((b, d), jnp.float32)
    init = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))
    (c, n, hS, m), hs = lax.scan(step, init, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(cd)                      # [B,S,D]
    h = common.rmsnorm(h, p["gn"].value)
    out = jnp.einsum("bsd,de->bse", h, p["out"].value.astype(cd))
    cache = {"c": c, "n": n, "h": hS, "m": m}
    return x + annotate(out, "batch", "seq", None), cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p: Params, x, cache: Params, cfg: ModelConfig):
    cd = cfg.cdtype
    nh = cfg.n_heads
    d = cfg.d_model
    dh = d // nh
    hN = common.rmsnorm(x, p["norm"].value)
    wx = (jnp.einsum("bsd,de->bse", hN, p["w"].value.astype(cd))
          + p["b"].value.astype(cd)).astype(jnp.float32)[:, 0]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(wx, state, p["r"].value.astype(jnp.float32), nh, dh)
    hx = common.rmsnorm(h.astype(cd), p["gn"].value)
    out = (hx @ p["out"].value.astype(cd))[:, None]
    return x + out, {"c": c, "n": n, "h": h, "m": m}
