"""Model & runtime configuration.

``ModelConfig`` is the *paper config* of an architecture (exact dims from the
assignment); ``Runtime`` holds execution knobs (attention impl, chunk sizes,
remat, MoE dispatch groups) that never change the math.

Layer heterogeneity is expressed as a repeating **period**: a tuple of
``(mixer, ffn)`` pairs cycled over the depth, scanned as one unit. Examples:
dense LM = ``(("attn","dense"),)``; Jamba = 1 attention + 7 mamba per 8 with
MoE every other layer; xLSTM[7:1] = 7 mLSTM + 1 sLSTM.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

Layer = tuple[str, str]          # (mixer, ffn)

MIXERS = ("attn", "mamba", "mlstm", "slstm")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    period: tuple[Layer, ...] = (("attn", "dense"),)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    shared_expert: bool = False
    # --- SSM (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model/16)
    # --- positions ---
    rope_theta: float = 1e4
    mrope_sections: tuple[int, int, int] | None = None
    # --- enc-dec (audio/seq2seq backbones) ---
    n_encoder_layers: int = 0    # >0 -> encoder-decoder w/ cross attention
    # --- numerics ---
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- distribution policy ---
    attn_parallelism: str = "heads"   # "heads" | "context" (CP when heads %% TP != 0)
    fsdp: bool = False                # shard big params over data axes too
    # --- modality frontend stub ---
    input_kind: str = "tokens"        # tokens | patch_embeddings | frame_embeddings

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {len(self.period)}"
        for mixer, ffn in self.period:
            assert mixer in MIXERS and ffn in FFNS, (mixer, ffn)

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def dt_r(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_list(self) -> list[Layer]:
        return list(self.period) * self.n_periods

    # ------------------------------------------------------- param counting
    def param_count(self) -> tuple[int, int]:
        """(total params, active params per token) — matches the init code
        exactly (asserted by tests); feeds 6ND."""
        d, f, hd = self.d_model, self.d_ff, self.hd
        di, n, dtr = self.ssm_inner, self.ssm_state, self.dt_r
        dh = d // max(self.n_heads, 1)
        total = active = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
            active += self.vocab_size * d
        total += d  # final norm
        active += d
        attn = d + d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        dense_ffn = d + 3 * d * f
        expert_ffn = 3 * d * f
        mamba = (d + d * 2 * di + di * self.ssm_conv + di
                 + di * (dtr + 2 * n) + dtr * di + di
                 + di * n + di + di * d)
        mlstm = (d + d * 2 * di + 5 * di + 3 * di * di
                 + 2 * di * self.n_heads + di * d)
        slstm = d + 4 * d * d + 4 * d * dh + 4 * d + d + d * d
        for mixer, ffn in self.layer_list():
            m = {"attn": attn, "mamba": mamba, "mlstm": mlstm, "slstm": slstm}[mixer]
            total += m
            active += m
            if ffn == "dense":
                total += dense_ffn
                active += dense_ffn
            elif ffn == "moe":
                total += d + d * self.n_experts + self.n_experts * expert_ffn
                active += d + d * self.n_experts + self.top_k * expert_ffn
                if self.shared_expert:
                    total += dense_ffn
                    active += dense_ffn
        if self.n_encoder_layers:
            enc = attn + dense_ffn
            cross = attn
            total += self.n_encoder_layers * enc + self.n_layers * cross + d
            active += self.n_encoder_layers * enc + self.n_layers * cross + d
        return int(total), int(active)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution knobs (never change the math)."""

    attn_impl: str = "auto"          # auto | plain | blockwise | pallas
    block_k: int = 1024
    remat: bool = True
    moe_groups: int = 1              # == number of data shards under pjit
    mamba_chunk: int = 64
    mlstm_chunk: int = 64
    xent_chunk: int = 512
    scan_layers: bool = True
    use_pallas: bool = False
    max_cache_len: int = 0           # decode cells set this
    # ---- perf knobs (§Perf hillclimb; defaults = paper-faithful baseline)
    attn_p_dtype: str = "float32"    # softmax-prob dtype for the PV matmul
    cache_shard: str = "seq"         # decode KV cache: "seq" | "head_dim"
    moe_combine_reshard: bool = False  # reshard expert outputs before gather
    moe_gather_decode: bool = False  # few-token MoE: gather top-k expert
                                     # weights instead of dense-all-experts
    infer_sharding: bool = False     # decode cells: drop FSDP (params stay
                                     # model-sharded, replicated over data)
    fsdp_gather_weights: bool = False  # ZeRO-3 JIT weight gather (vs GSPMD
                                       # activation partial-sum all-reduce)
