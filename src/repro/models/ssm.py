"""Mamba (S6 selective scan) block — chunked associative-scan training path,
O(1)-state decode path, Pallas kernel opt-in (kernels/mamba_scan).

The CUDA selective-scan kernel's insight (fuse the recurrence, never
materialize [B,S,D,N] in HBM) maps to TPU as: chunk the sequence, run
``lax.associative_scan`` on VMEM-sized [B,Lc,D,N] tiles inside a lax.scan
over chunks. Cost accounting of the chunk loop is handled by the HLO static
analyzer (trip-count corrected).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common
from repro.models.config import ModelConfig, Runtime
from repro.parallel.sharding import Param, annotate

Params = dict[str, Any]


def init_mamba(key, cfg: ModelConfig) -> Params:
    d, di, n, k, dtr = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_r
    ks = jax.random.split(key, 7)
    return {
        "norm": Param(jnp.ones((d,), cfg.pdtype), ("embed",)),
        "in_proj": common.dense_param(ks[0], d, 2 * di, ("embed", "ssm_inner"), cfg.pdtype),
        "conv_w": Param(common.trunc_normal(ks[1], (di, k), (1.0 / k) ** 0.5, cfg.pdtype),
                        ("ssm_inner", "conv")),
        "conv_b": Param(jnp.zeros((di,), cfg.pdtype), ("ssm_inner",)),
        "x_proj": common.dense_param(ks[2], di, dtr + 2 * n, ("ssm_inner", None), cfg.pdtype),
        "dt_w": common.dense_param(ks[3], dtr, di, (None, "ssm_inner"), cfg.pdtype),
        "dt_b": Param(jnp.full((di,), -4.6, cfg.pdtype), ("ssm_inner",)),  # softplus ~= 0.01
        "a_log": Param(jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                                (di, n))).astype(cfg.pdtype),
                       ("ssm_inner", "ssm_state")),
        "d_skip": Param(jnp.ones((di,), cfg.pdtype), ("ssm_inner",)),
        "out_proj": common.dense_param(ks[4], di, d, ("ssm_inner", "embed"), cfg.pdtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via K shifted adds. x: [B,S,Di]; w: [Di,K]."""
    k = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + pad[:, j:j + s] * w[:, j]
    return out + b


def _ssm_inputs(p: Params, h, cfg: ModelConfig):
    cd = cfg.cdtype
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].value.astype(cd))
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = annotate(x1, "batch", "seq", "act_mlp")
    return x1, z


def _ssm_params(p: Params, x1, cfg: ModelConfig):
    """Input-dependent dt/B/C from conv'd activations (f32 for the scan)."""
    cd = cfg.cdtype
    n, dtr = cfg.ssm_state, cfg.dt_r
    dbc = jnp.einsum("bsi,ie->bse", x1, p["x_proj"].value.astype(cd))
    dt_r, b_in, c_in = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jnp.einsum("bsr,ri->bsi", dt_r, p["dt_w"].value.astype(cd))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"].value.astype(jnp.float32))
    a = -jnp.exp(p["a_log"].value.astype(jnp.float32))          # [Di,N]
    return dt, a, b_in.astype(jnp.float32), c_in.astype(jnp.float32)


def _chunk_scan(dt, a, b_in, c_in, x1, chunk: int):
    """Chunked associative scan. Shapes: dt,x1 [B,S,Di]; b,c [B,S,N]."""
    bsz, s, di = x1.shape
    n = a.shape[1]
    from repro.models.common import fit_chunk
    lc = fit_chunk(s, chunk)
    nc = s // lc
    xf = x1.astype(jnp.float32)
    da = jnp.exp(dt[..., None] * a[None, None])                  # [B,S,Di,N]
    u = (dt * xf)[..., None] * b_in[:, :, None, :]               # [B,S,Di,N]
    da_c = da.reshape(bsz, nc, lc, di, n)
    u_c = u.reshape(bsz, nc, lc, di, n)
    c_c = c_in.reshape(bsz, nc, lc, n)

    def combine(left, right):
        a1, u1 = left
        a2, u2 = right
        return a1 * a2, a2 * u1 + u2

    def chunk_step(h, xs):
        da_k, u_k, c_k = xs                                      # [B,Lc,Di,N]
        u0 = u_k.at[:, 0].add(da_k[:, 0] * h)
        acc_a, acc_u = lax.associative_scan(combine, (da_k, u0), axis=1)
        y_k = jnp.einsum("bldn,bln->bld", acc_u, c_k)
        return acc_u[:, -1], y_k

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    h_final, y = lax.scan(chunk_step, h0,
                          (jnp.moveaxis(da_c, 1, 0), jnp.moveaxis(u_c, 1, 0),
                           jnp.moveaxis(c_c, 1, 0)))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, s, di)
    return y, h_final


def mamba_train(p: Params, x, cfg: ModelConfig, rt: Runtime):
    """x: [B,S,D] -> (residual output, decode cache {h, conv})."""
    h = common.rmsnorm(x, p["norm"].value)
    x1, z = _ssm_inputs(p, h, cfg)
    conv_tail = x1[:, -(cfg.ssm_conv - 1):]         # pre-conv inputs for decode
    x1 = jax.nn.silu(_causal_conv(x1, p["conv_w"].value.astype(cfg.cdtype),
                                  p["conv_b"].value.astype(cfg.cdtype)))
    dt, a, b_in, c_in = _ssm_params(p, x1, cfg)
    if rt.use_pallas:
        from repro.kernels.ops import mamba_scan
        # kernel consumes raw dt (applies softplus itself); pass pre-softplus
        y = mamba_scan(x1.astype(jnp.float32),
                       jnp.log(jnp.expm1(jnp.maximum(dt, 1e-6))), a, b_in, c_in,
                       p["d_skip"].value.astype(jnp.float32), chunk=rt.mamba_chunk)
        h_final = jnp.zeros((x.shape[0], cfg.ssm_inner, cfg.ssm_state), jnp.float32)
    else:
        y, h_final = _chunk_scan(dt, a, b_in, c_in, x1, rt.mamba_chunk)
        y = y + x1.astype(jnp.float32) * p["d_skip"].value.astype(jnp.float32)
    y = (y.astype(cfg.cdtype) * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].value.astype(cfg.cdtype))
    cache = {"h": h_final, "conv": conv_tail.astype(cfg.cdtype)}
    return x + annotate(out, "batch", "seq", None), cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "h": jnp.zeros((batch, cfg.ssm_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_inner), dtype),
    }


def mamba_decode(p: Params, x, cache: Params, cfg: ModelConfig):
    """One-token step. x: [B,1,D]."""
    cd = cfg.cdtype
    h = common.rmsnorm(x, p["norm"].value)
    x1, z = _ssm_inputs(p, h, cfg)                                # [B,1,Di]
    w = p["conv_w"].value.astype(cd)                              # [Di,K]
    hist = jnp.concatenate([cache["conv"], x1.astype(cache["conv"].dtype)], axis=1)
    conv = jnp.einsum("bki,ik->bi", hist.astype(cd), w) + p["conv_b"].value.astype(cd)
    x1s = jax.nn.silu(conv)[:, None]                              # [B,1,Di]
    dt, a, b_in, c_in = _ssm_params(p, x1s, cfg)
    dtq = dt[:, 0]                                                # [B,Di]
    da = jnp.exp(dtq[..., None] * a[None])                        # [B,Di,N]
    hn = da * cache["h"] + (dtq * x1s[:, 0].astype(jnp.float32))[..., None] \
        * b_in[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", hn, c_in[:, 0]) \
        + x1s[:, 0].astype(jnp.float32) * p["d_skip"].value.astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z[:, 0]))[:, None]
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].value.astype(cd))
    return x + out, {"h": hn, "conv": hist[:, 1:]}
