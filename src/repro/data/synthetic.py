"""Deterministic synthetic LM data pipeline.

Per-host sharding discipline matches a real multi-host loader: every host
computes only its shard of the global batch from a (seed, step, host) triple,
so restarts resume mid-stream exactly (tested), and no two hosts overlap.
A background prefetch thread keeps ``depth`` batches in flight.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: int = 97   # markov-ish period so loss is learnable, not pure noise


def philox_rng(seed: int, *counters: int) -> np.random.Generator:
    """Counter-based deterministic RNG: one stream per ``(seed, *counters)``.

    The sharding discipline of this module, exposed for reuse: a Philox
    generator keyed on ``seed`` with up to four counter words, so any
    consumer (the data loader's ``(step, host)`` streams, ``repro.traffic``'s
    replayable arrival traces) derives independent, restart-exact streams
    from pure coordinates — no sequential state to checkpoint.
    """
    if len(counters) > 4:
        raise ValueError(f"Philox has a 4-word counter, got {len(counters)}")
    counter = np.zeros(4, np.uint64)
    counter[:len(counters)] = counters
    return np.random.Generator(np.random.Philox(key=seed, counter=counter))


def _host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    assert cfg.global_batch % cfg.n_hosts == 0
    per_host = cfg.global_batch // cfg.n_hosts
    rng = philox_rng(cfg.seed, step, cfg.host_id)
    base = rng.integers(0, cfg.vocab_size, size=(per_host, cfg.seq_len + 1),
                        dtype=np.int64)
    # inject learnable structure: token[t] depends on token[t-1] mod `structure`
    ar = np.cumsum(base % cfg.structure, axis=1) % cfg.vocab_size
    tokens = ((base + ar) % cfg.vocab_size).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class SyntheticLoader:
    """Iterator of host-local batches with prefetch and exact resume."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = _host_batch(self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def close(self) -> None:
        self._stop.set()


def batch_for_step(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Pure function used by tests and the trainer's resume check."""
    return _host_batch(cfg, step)
