from repro.data.synthetic import DataConfig, SyntheticLoader, batch_for_step

__all__ = ["DataConfig", "SyntheticLoader", "batch_for_step"]
