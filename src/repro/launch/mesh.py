"""Production mesh definition (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 names explicit/auto axis types; older jax has Auto only
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 0) -> Mesh:
    """Small meshes for tests/examples: (data, model) over available devices."""
    model = model_parallel or (2 if devices % 2 == 0 and devices > 1 else 1)
    if model <= 0 or devices % model != 0:
        usable = [m for m in range(1, devices + 1) if devices % m == 0]
        shapes = [f"({devices // m}, {m})" for m in usable]
        raise ValueError(
            f"make_mesh_for({devices}, model_parallel={model}): {devices} "
            f"devices are not divisible by model_parallel={model}; usable "
            f"(data, model) shapes for {devices} devices: {', '.join(shapes)}")
    data = devices // model
    return make_mesh((data, model), ("data", "model"))


def data_shards(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n


def total_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
