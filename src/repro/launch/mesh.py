"""Production mesh definition (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int = 0) -> Mesh:
    """Small meshes for tests/examples: (data, model) over available devices."""
    model = model_parallel or (2 if devices % 2 == 0 and devices > 1 else 1)
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def data_shards(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n


def total_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
