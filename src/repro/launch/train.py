"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the arch's reduced smoke config end-to-end
(full configs are exercised by the dry-run); on a real TPU slice the same
entry point runs the full config on the production mesh (--full).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro import optim
from repro.configs.registry import all_arch_ids, get
from repro.models.config import Runtime
from repro.training import TrainConfig, train
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=all_arch_ids())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full (paper) config instead of smoke")
    ap.add_argument("--int8-opt", action="store_true")
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.config if args.full else spec.smoke
    rt = Runtime(remat=False, xent_chunk=32, moe_groups=1,
                 mamba_chunk=16, mlstm_chunk=16)
    from repro.data import DataConfig
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    ocfg = optim.AdamWConfig(
        lr=args.lr, state_dtype="int8" if args.int8_opt else "float32")
    res = train(cfg, rt, TrainConfig(
        steps=args.steps, checkpoint_dir=f"{args.checkpoint_dir}/{cfg.name}",
        checkpoint_every=args.checkpoint_every), ocfg, data=data)
    logger.info("done: %d steps, loss %.4f -> %.4f, %d stragglers, resumed@%d",
                len(res.losses), res.losses[0] if res.losses else float("nan"),
                res.losses[-1] if res.losses else float("nan"),
                res.stragglers, res.resumed_from)


if __name__ == "__main__":
    main()
