from repro.launch.mesh import (data_shards, make_mesh, make_mesh_for,
                               make_production_mesh, total_chips)

__all__ = ["data_shards", "make_mesh", "make_mesh_for", "make_production_mesh",
           "total_chips"]
