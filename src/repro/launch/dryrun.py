import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices let ``make_production_mesh`` build the real (16,16) and
(2,16,16) meshes; every cell must ``.lower().compile()`` cleanly; we record
``memory_analysis()`` (fits-in-HBM evidence), ``cost_analysis()``, and the
statically-corrected {FLOPs, bytes, collective-wire} for EXPERIMENTS.md
§Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
Results: benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json (incremental;
existing files are skipped unless --force).
"""
import argparse
import dataclasses
import sys
import time
import traceback

import jax

from repro.configs.registry import SHAPES, all_arch_ids, get
from repro.core import hlo_analysis, perfmodel
from repro.launch import cells
from repro.launch.mesh import make_production_mesh, total_chips
from repro.parallel import sharding as shd
from repro.utils import compiled_cost, dump_json, human_bytes, load_json, logger

RESULTS_DIR = "benchmarks/results/dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool, *, microbatch=None,
             rules=None, save: bool = True, tag: str = "",
             rt_overrides: dict | None = None, want_breakdown: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out_path = f"{RESULTS_DIR}/{arch}__{shape}__{mesh_name}{tag}.json"
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = get(arch)
    if shape in spec.skips:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skip", "reason": spec.skips[shape]}
        if save:
            dump_json(rec, out_path)
        return rec

    fsdp = spec.config.fsdp
    if rt_overrides and rt_overrides.get("infer_sharding"):
        fsdp = False   # inference: params model-sharded, replicated over data
    rules = rules or shd.lm_rules(
        fsdp=fsdp,
        context_parallel_seq=spec.config.attn_parallelism == "context")
    t0 = time.time()
    with shd.use_sharding(mesh, rules):
        cell = cells.build_cell(arch, shape, mesh, rules, microbatch=microbatch,
                                rt_overrides=rt_overrides)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    print(f"== {arch}/{shape}@{mesh_name} memory_analysis:")
    print(f"   args={human_bytes(ma.argument_size_in_bytes)} "
          f"out={human_bytes(ma.output_size_in_bytes)} "
          f"temp={human_bytes(ma.temp_size_in_bytes)} "
          f"peak={human_bytes(ma.peak_memory_in_bytes)} "
          f"alias={human_bytes(ma.alias_size_in_bytes)}")
    cost = compiled_cost(compiled)
    print(f"   cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")

    hlo = compiled.as_text()
    chips = total_chips(mesh)
    roof = perfmodel.Roofline().analyze(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips, cost=cost,
        hlo_text=hlo, model_flops=cell.model_flops,
        peak_memory_per_dev=float(ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes))
    print("   " + roof.bound_summary())
    breakdown = None
    if want_breakdown:
        breakdown = hlo_analysis.ModuleCost(hlo).breakdown()
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "chips": chips, "kind": cell.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_size_in_bytes": ma.argument_size_in_bytes,
            "output_size_in_bytes": ma.output_size_in_bytes,
            "temp_size_in_bytes": ma.temp_size_in_bytes,
            "peak_memory_in_bytes": ma.peak_memory_in_bytes,
            "alias_size_in_bytes": ma.alias_size_in_bytes,
        },
        "cost_analysis": {k: v for k, v in cost.items()
                          if k in ("flops", "bytes accessed",
                                   "optimal_seconds", "utilization")},
        "roofline": dataclasses.asdict(roof),
        "breakdown": breakdown,
    }
    if save:
        dump_json(rec, out_path)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(all_arch_ids())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = f"{RESULTS_DIR}/{arch}__{shape}__{mesh_name}.json"
                if not args.force and os.path.exists(path):
                    try:
                        if load_json(path).get("status") in ("ok", "skip"):
                            logger.info("cached %s", path)
                            continue
                    except Exception:  # noqa: BLE001
                        pass
                try:
                    run_cell(arch, shape, multi, microbatch=args.microbatch)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name, str(e)[:500]))
                    traceback.print_exc()
                    dump_json({"arch": arch, "shape": shape, "mesh": mesh_name,
                               "status": "fail", "error": str(e)[:2000]}, path)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f[:3], f[3][:200])
        return 1
    print("\nall requested dry-run cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
