"""Cell builders: one jit-able program per (arch x shape x mesh) dry-run cell.

``input_specs`` follows the mandated pattern: weak-type-correct
ShapeDtypeStruct stand-ins, shardable, no device allocation. ``build_cell``
returns the step function plus in/out shardings so dryrun.py can
``jit(...).lower(*specs).compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.registry import SHAPES, ArchSpec, get
from repro.launch.mesh import data_shards, total_chips
from repro.models import encdec, transformer
from repro.models.config import ModelConfig, Runtime
from repro.parallel import sharding as shd

BATCH_AXES = ("pod", "data")


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode
    fn: Callable                   # jit target
    specs: tuple                   # positional ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    model_flops: float
    cfg: ModelConfig
    rt: Runtime


def _batch_spec(mesh: Mesh, batch_size: int | None = None):
    axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
    if batch_size is not None:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch_size % n != 0:
            return None
    return axes


def runtime_for(cfg: ModelConfig, mesh: Mesh, seq: int, kind: str,
                microbatch: int = 0) -> Runtime:
    big = cfg.param_count()[0] > 5e10
    return Runtime(
        attn_impl="blockwise" if (kind != "decode" and seq >= 2048) else "auto",
        block_k=1024 if seq >= 8192 else 512,
        remat=kind == "train",
        moe_groups=data_shards(mesh),
        mamba_chunk=256 if seq >= 2048 else 64,
        mlstm_chunk=256 if seq >= 2048 else 64,
        xent_chunk=256,
        max_cache_len=seq,
    )


def microbatches_for(cfg: ModelConfig) -> int:
    n = cfg.param_count()[0]
    if n > 2e11:
        return 8
    if n > 5e10:
        return 4
    return 1


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    init = encdec.init_encdec if cfg.n_encoder_layers else transformer.init_lm
    return jax.eval_shape(lambda k: init(k, cfg), key)


# ----------------------------------------------------------------- shardings
def param_shardings(boxed, mesh: Mesh, rules: shd.ShardingRules):
    return shd.param_shardings(boxed, mesh, rules)


def opt_shardings(params_boxed, state_shapes, mesh: Mesh,
                  rules: shd.ShardingRules):
    """Optimizer-state shardings mirror the owning param's sharding."""
    def for_param(p: shd.Param, st):
        spec = rules.resolve(p.axes, p.value.shape, mesh)
        if isinstance(st, dict) and set(st) == {"q", "scale"}:
            # int8 moments: q has the param's shape (inherits its sharding);
            # scale is [..., nblocks] — keep the last-axis sharding only if
            # the block count still divides.
            entries = list(spec) + [None] * (len(p.value.shape) - len(list(spec)))
            s_entries = list(entries)
            last = s_entries[-1] if s_entries else None
            nb = st["scale"].shape[-1]
            if last is not None:
                size = 1
                for a in ((last,) if isinstance(last, str) else last):
                    size *= mesh.shape.get(a, 1)
                if nb % size != 0:
                    s_entries[-1] = None
            return {"q": NamedSharding(mesh, P(*entries)),
                    "scale": NamedSharding(mesh, P(*s_entries))}
        return NamedSharding(mesh, spec)

    leaf = lambda x: shd.is_param(x)
    is_qs = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    m_sh = jax.tree_util.tree_map(for_param, params_boxed, state_shapes["m"],
                                  is_leaf=leaf)
    v_sh = jax.tree_util.tree_map(for_param, params_boxed, state_shapes["v"],
                                  is_leaf=leaf)
    return {"m": m_sh, "v": v_sh,
            "count": NamedSharding(mesh, P())}


def cache_shardings(cache_shapes, mesh: Mesh, batch_axes,
                    mode: str = "seq") -> Any:
    """KV-cache shardings by leaf name: batch over data axes, SSM inner dims
    over 'model', and the KV cache either ``seq``-sharded over 'model'
    (flash-decode partial softmax) or ``head_dim``-sharded (split-K attention:
    the decode-step dynamic-update-slice stays shard-local — §Perf knob)."""
    def one(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(sds.shape)
        def div(i, ax):
            if ax is None:
                return False
            size = 1
            for a in ((ax,) if isinstance(ax, str) else ax):
                size *= mesh.shape.get(a, 1)
            return sds.shape[i] % size == 0 and size > 1
        spec: list = [None] * nd
        if nd >= 2 and div(1, batch_axes):
            spec[1] = batch_axes        # [layers, batch, ...]
        if name in ("k", "v", "ck", "cv") and nd == 5:
            if mode == "head_dim" and div(4, "model"):
                spec[4] = "model"       # split-K: local DUS, psum'd logits
            elif div(2, "model"):
                spec[2] = "model"       # cache seq (flash-decode combine)
        elif name == "h" and nd == 4 and div(2, "model"):
            spec[2] = "model"           # mamba inner
        elif name == "conv" and nd == 4 and div(3, "model"):
            spec[3] = "model"
        elif name == "c" and nd == 5 and div(4, "model"):
            spec[4] = "model"           # mlstm value dim
        elif name in ("n",) and nd == 4 and div(3, "model"):
            spec[3] = "model"
        elif name in ("c", "n", "h", "m") and nd == 3 and div(2, "model"):
            spec[2] = "model"           # slstm [layers,B,D]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# -------------------------------------------------------------- input specs
def input_specs(arch: str, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    spec = get(arch)
    cfg = spec.config
    seq, gbatch, kind = SHAPES[shape]
    f = jax.ShapeDtypeStruct
    i32, bf16 = jnp.int32, jnp.bfloat16
    d = cfg.d_model
    out: dict[str, Any] = {}
    if kind == "train":
        if cfg.n_encoder_layers:
            out["frames"] = f((gbatch, seq // 4, d), bf16)
            out["tokens"] = f((gbatch, seq), i32)
        elif cfg.input_kind == "patch_embeddings":
            out["embeds"] = f((gbatch, seq, d), bf16)
            out["positions"] = f((3, gbatch, seq), i32)
        else:
            out["tokens"] = f((gbatch, seq), i32)
        out["labels"] = f((gbatch, seq), i32)
    elif kind == "prefill":
        if cfg.n_encoder_layers:
            out["frames"] = f((gbatch, seq // 4, d), bf16)
            out["tokens"] = f((gbatch, seq), i32)
        elif cfg.input_kind == "patch_embeddings":
            out["embeds"] = f((gbatch, seq, d), bf16)
            out["positions"] = f((3, gbatch, seq), i32)
        else:
            out["tokens"] = f((gbatch, seq), i32)
    else:  # decode
        out["tokens"] = f((gbatch, 1), i32)
        if cfg.mrope_sections:
            out["positions"] = f((3, gbatch, 1), i32)
    return out


def _batch_shardings(specs: dict, mesh: Mesh) -> dict:
    b = _batch_spec(mesh)
    nb = 1
    for a in b:
        nb *= mesh.shape[a]
    out = {}
    for k, v in specs.items():
        bdim = 1 if k == "positions" else 0
        bspec = b if v.shape[bdim] % nb == 0 else None   # batch=1 cells replicate
        spec = [None] * len(v.shape)
        spec[bdim] = bspec
        out[k] = NamedSharding(mesh, P(*spec))
    return out


# -------------------------------------------------------------------- cells
def build_cell(arch: str, shape: str, mesh: Mesh, rules: shd.ShardingRules,
               *, microbatch: int | None = None,
               rt_overrides: dict | None = None) -> Cell:
    spec = get(arch)
    cfg = spec.config
    if shape in spec.skips:
        raise ValueError(f"{arch}/{shape} skipped: {spec.skips[shape]}")
    seq, gbatch, kind = SHAPES[shape]
    rt = runtime_for(cfg, mesh, seq, kind)
    if rt_overrides:
        rt = dataclasses.replace(rt, **rt_overrides)
    chips = total_chips(mesh)
    total, active = cfg.param_count()
    params = abstract_params(cfg)
    p_sh = param_shardings(params, mesh, rules)
    batch_specs = input_specs(arch, shape)
    b_sh = _batch_shardings(batch_specs, mesh)
    is_encdec = bool(cfg.n_encoder_layers)

    if kind == "train":
        mb = microbatch if microbatch is not None else microbatches_for(cfg)
        ocfg = optim.AdamWConfig(
            state_dtype="int8" if total > 2e11 else "float32")
        ostate = jax.eval_shape(lambda p: optim.init_state(p, ocfg), params)
        o_sh = opt_shardings(params, ostate, mesh, rules)

        def loss_fn(p, batch):
            if is_encdec:
                return encdec.train_loss(p, batch, cfg, rt)
            return transformer.train_loss(p, batch, cfg, rt)

        def train_step(p, ost, batch):
            def micro(g_acc, mbatch):
                (l, mets), g = jax.value_and_grad(loss_fn, has_aux=True)(p, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, l

            if mb > 1:
                split = jax.tree_util.tree_map(
                    lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                    if x.shape[0] == gbatch else
                    x.reshape((mb,) + (x.shape[0],) + (x.shape[1] // mb,) + x.shape[2:]),
                    batch)
                g0 = jax.tree_util.tree_map(
                    lambda v: jnp.zeros(v.shape, jnp.float32), p)
                grads, losses = jax.lax.scan(micro, g0, split)
                grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
                loss = jnp.mean(losses)
            else:
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            lr = optim.cosine_lr(ost["count"])
            new_p, new_o = optim.apply_update(p, grads, ost, ocfg, lr)
            return new_p, new_o, {"loss": loss}

        metrics_sh = {"loss": NamedSharding(mesh, P())}
        return Cell(arch=arch, shape=shape, kind=kind, fn=train_step,
                    specs=(params, ostate, batch_specs),
                    in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, metrics_sh),
                    donate_argnums=(0, 1),
                    model_flops=6.0 * active * gbatch * seq
                    * (1 if not is_encdec else 1.0),
                    cfg=cfg, rt=rt)

    if kind == "prefill":
        def prefill_fn(p, batch):
            if is_encdec:
                return encdec.prefill(p, cfg, rt, batch["frames"], batch["tokens"])
            return transformer.prefill(p, cfg, rt, tokens=batch.get("tokens"),
                                       embeds=batch.get("embeds"),
                                       positions=batch.get("positions"))

        # cache sharding from output shapes
        cache_shapes = jax.eval_shape(prefill_fn, params, batch_specs)[1]
        c_sh = cache_shardings(cache_shapes, mesh, _batch_spec(mesh),
                               mode=rt.cache_shard)
        logits_sh = NamedSharding(mesh, P(_batch_spec(mesh, gbatch), None))
        return Cell(arch=arch, shape=shape, kind=kind, fn=prefill_fn,
                    specs=(params, batch_specs),
                    in_shardings=(p_sh, b_sh),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(),
                    model_flops=2.0 * active * gbatch * seq, cfg=cfg, rt=rt)

    # decode
    bspec = _batch_spec(mesh, gbatch)
    if is_encdec:
        cache_shapes = jax.eval_shape(
            lambda: encdec.init_cache(cfg, gbatch, seq, seq // 4, cfg.cdtype))
    else:
        cache_shapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, gbatch, seq, cfg.cdtype))
    c_sh = cache_shardings(cache_shapes, mesh, bspec, mode=rt.cache_shard)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(p, cache, batch, pos):
        if is_encdec:
            return encdec.decode_step(p, cache, batch["tokens"], pos, cfg, rt)
        return transformer.decode_step(p, cache, batch["tokens"], pos, cfg, rt,
                                       positions=batch.get("positions"))

    logits_sh = NamedSharding(mesh, P(bspec, None))
    return Cell(arch=arch, shape=shape, kind=kind, fn=decode_fn,
                specs=(params, cache_shapes, input_specs(arch, shape), pos_spec),
                in_shardings=(p_sh, c_sh, _batch_shardings(input_specs(arch, shape), mesh),
                              NamedSharding(mesh, P())),
                out_shardings=(logits_sh, c_sh),
                donate_argnums=(1,),
                model_flops=2.0 * active * gbatch, cfg=cfg, rt=rt)
