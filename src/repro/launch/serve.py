"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Loads (or random-inits) the arch's reduced config and serves a batch of
synthetic requests through the prefill+decode engine.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import all_arch_ids, get
from repro.models import transformer
from repro.models.config import Runtime
from repro.serving import Engine
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=all_arch_ids())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get(args.arch).smoke
    rt = Runtime(remat=False, moe_groups=1, mamba_chunk=16, mlstm_chunk=16)
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir)
        step, (params, _) = mgr.restore((params, None))
        logger.info("restored step %d from %s", step, args.checkpoint_dir)

    eng = Engine(params, cfg, rt)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=rng.randint(4, 16)).tolist()
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    logger.info("%d requests x %d new tokens in %.0f ms (%.0f tok/s)",
                args.requests, args.max_new, dt * 1e3, out.tokens.size / dt)
    for i in range(min(3, args.requests)):
        print(f"req{i}: {out.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
