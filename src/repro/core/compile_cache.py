"""Persistent on-disk cache of compiled XLA executables (docs/performance.md).

Characterization spends most of its wall clock inside XLA: every probe lowers
and compiles its measurement callables before a single nanosecond is timed.
Those executables are pure functions of the probe identity, so re-runs and
resumed sweeps can skip XLA entirely. :class:`CompileCache` persists serialized
executables keyed like the :class:`~repro.core.latency_db.LatencyDB` —
``(device_kind, backend, jax_version, op, opt_level, dtype, fidelity)`` — where
``fidelity`` carries the compile-relevant measurement parameters (chain length,
chase steps, tile shape), exactly the axes the DB op names suffix.

Entries are stored one-per-file under the cache root (filename = SHA-256 of the
key), written atomically (unique temp + rename) so concurrent sessions —
`Session.fan_out` shard threads, parallel CLI runs — never observe a torn
entry. Serialization uses :mod:`jax.experimental.serialize_executable`; on
backends/jax versions where that is unavailable the cache degrades gracefully
to compile-always (every lookup is a miss, nothing is stored, measurement is
unaffected).

Eviction: the cache is bounded by ``max_entries``; when a store pushes it past
the bound, the oldest entries by mtime are removed (loads touch mtime, so the
policy is LRU-ish). The default bound comfortably holds several full-plan
sweeps.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile
import threading
from typing import Any, Callable

from repro.utils import logger

# Bump when the entry layout changes: old-format files then miss instead of
# failing to unpickle into the new shape.
_FORMAT = 1


def _serializer():
    """The (serialize, deserialize_and_load) pair, or None when unsupported."""
    try:
        from jax.experimental import serialize_executable as se

        return se.serialize, se.deserialize_and_load
    except Exception:  # noqa: BLE001 - jax too old / backend unsupported
        return None


@dataclasses.dataclass
class CacheStats:
    """Counters surfaced in ``ResultSet.summary()`` / the speed bench."""

    hits: int = 0
    misses: int = 0   # lookups that had to compile (and tried to store)
    stores: int = 0
    evictions: int = 0
    errors: int = 0   # entries that failed to (de)serialize (treated as miss)


class CompileCache:
    """On-disk executable cache; see module docstring.

    Thread-safe: counters and eviction run under a lock, entry files are
    written atomically. Safe to share across `fan_out` shard threads.
    """

    def __init__(self, root: str, max_entries: int = 1024):
        self.root = os.path.abspath(root)
        self.max_entries = int(max_entries)
        self.stats = CacheStats()
        self._lock = threading.Lock()
        os.makedirs(self.root, exist_ok=True)

    # ------------------------------------------------------------------ keys
    def entry_path(self, key: tuple) -> str:
        digest = hashlib.sha256(repr((_FORMAT,) + tuple(key)).encode()).hexdigest()
        return os.path.join(self.root, digest + ".xc")

    # ------------------------------------------------------------------- api
    def load(self, key: tuple) -> tuple[Any, Any] | None:
        """Deserialize the executable cached under ``key``.

        Returns ``(compiled, extra)`` or None on miss/unsupported. ``extra``
        is the caller-provided payload stored alongside (e.g. the optimized
        HLO text a consumer probe prices) — None when none was stored.
        """
        ser = _serializer()
        path = self.entry_path(key)
        if ser is None or not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
            compiled = ser[1](entry["blob"], entry["in_tree"], entry["out_tree"])
            os.utime(path)  # touch: keep hot entries out of eviction's way
        except Exception as e:  # noqa: BLE001 - stale/foreign entry: recompile
            with self._lock:
                self.stats.errors += 1
            logger.debug("compile cache entry %s unreadable (%s); recompiling",
                         path, type(e).__name__)
            return None
        return compiled, entry.get("extra")

    def peek_extra(self, key: tuple) -> Any:
        """Read only the ``extra`` sidecar stored under ``key``, or None.

        Unlike :meth:`load` this never deserializes the executable, so it is
        cheap enough for static consumers (``repro.audit`` reads the
        optimized-HLO text probes rode into the cache without touching XLA).
        """
        path = self.entry_path(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                return pickle.load(f).get("extra")
        except Exception:  # noqa: BLE001 - stale/foreign entry
            with self._lock:
                self.stats.errors += 1
            return None

    def store(self, key: tuple, compiled: Any, extra: Any = None) -> bool:
        """Serialize ``compiled`` under ``key``; False when unsupported."""
        ser = _serializer()
        if ser is None:
            return False
        try:
            blob, in_tree, out_tree = ser[0](compiled)
            payload = pickle.dumps({"key": tuple(key), "blob": blob,
                                    "in_tree": in_tree, "out_tree": out_tree,
                                    "extra": extra})
        except Exception as e:  # noqa: BLE001 - unpicklable executable: skip
            with self._lock:
                self.stats.errors += 1
            logger.debug("compile cache cannot serialize %s: %s: %s",
                         key, type(e).__name__, e)
            return False
        path = self.entry_path(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        with self._lock:
            self.stats.stores += 1
        self._evict()
        return True

    def load_or_compile(self, key: tuple, build: Callable[[], Any],
                        extra: Callable[[Any], Any] | None = None
                        ) -> tuple[Any, Any, bool]:
        """The one-call form probes use: ``(compiled, extra, was_hit)``.

        On a miss, ``build()`` compiles the executable, ``extra(compiled)``
        (when given) derives the sidecar payload, and both are stored for the
        next run.
        """
        cached = self.load(key)
        if cached is not None:
            with self._lock:
                self.stats.hits += 1
            return cached[0], cached[1], True
        compiled = build()
        with self._lock:
            self.stats.misses += 1
        side = extra(compiled) if extra is not None else None
        self.store(key, compiled, extra=side)
        return compiled, side, False

    # ------------------------------------------------------------- lifecycle
    def entries(self) -> list[str]:
        try:
            return [os.path.join(self.root, n) for n in os.listdir(self.root)
                    if n.endswith(".xc")]
        except OSError:
            return []

    def _evict(self) -> None:
        with self._lock:
            paths = self.entries()
            if len(paths) <= self.max_entries:
                return
            def mtime(p: str) -> float:
                try:
                    return os.stat(p).st_mtime
                except OSError:
                    return 0.0
            paths.sort(key=mtime)
            for p in paths[: len(paths) - self.max_entries]:
                try:
                    os.unlink(p)
                    self.stats.evictions += 1
                except OSError:
                    pass

    def clear(self) -> None:
        for p in self.entries():
            try:
                os.unlink(p)
            except OSError:
                pass

    def __len__(self) -> int:
        return len(self.entries())

    def __repr__(self) -> str:
        return (f"CompileCache({self.root!r}, entries={len(self)}, "
                f"hits={self.stats.hits}, misses={self.stats.misses})")


def fidelity_key(env: Any, op: str, opt_level: str, dtype: str,
                 fidelity: str) -> tuple:
    """Cache key layout: the DB record key plus a fidelity tail."""
    return (env["device_kind"], env["backend"], env["jax_version"],
            op, opt_level, dtype, fidelity)


def hlo_extra(compiled: Any) -> str | None:
    """Optimized-HLO text of a freshly compiled executable, or None.

    The standard ``extra`` payload measurement compiles ride into the cache:
    a deserialized executable cannot be asked for ``as_text()`` on every
    backend, but a *fresh* compile can — storing the text at compile time is
    what lets ``repro.audit`` statically verify warm artifacts without
    re-invoking XLA.
    """
    try:
        return compiled.as_text()
    except Exception:  # noqa: BLE001 - backend without HLO text access
        return None
