"""Compiler-optimization levels: the paper's -O0/-O1/-O2/-O3 axis, for XLA.

The paper compiles every instruction under all four nvcc levels and reports
Optimized (-O3) vs Non-Optimized (-O0). The JAX analog:

* ``O0`` — eager op-by-op dispatch: no XLA fusion/simplification across ops,
  every op pays full dispatch overhead (the "no optimization" execution mode).
* ``O1`` — ``jit`` with XLA's backend optimizations dialed down via per-compile
  ``compiler_options`` (whichever knobs the backend accepts; unknown options
  degrade gracefully to default jit and are recorded as such).
* ``O3`` — default ``jit``: the full XLA pipeline (fusion, algebraic
  simplification, strength reduction — the effects the paper attributes to
  `-O3`, e.g. div-by-pow2 becoming shifts, are performed here too).

The CUDA-9-vs-10 comparison (paper Table III) becomes a jax/XLA-version key in
the LatencyDB: run the same suite under two jaxlib versions and diff.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax

from repro.utils import logger

OPT_LEVELS = ("O0", "O1", "O3")

# Candidate per-compile knobs for the "reduced optimization" level. XLA accepts
# env-style option names through ``compiler_options``; unsupported names raise,
# and we fall back in order.
_O1_CANDIDATES: tuple[dict[str, Any], ...] = (
    {"xla_backend_optimization_level": 0},
    {"xla_cpu_enable_fast_math": False, "xla_llvm_disable_expensive_passes": True},
    {"xla_llvm_disable_expensive_passes": True},
)


@functools.cache
def _o1_options() -> dict[str, Any] | None:
    def probe(opts: dict[str, Any]) -> bool:
        try:
            jax.jit(lambda x: x * x + x).lower(1.0).compile(compiler_options=opts)
            return True
        except Exception:  # noqa: BLE001 - unsupported option names raise generic errors
            return False

    for opts in _O1_CANDIDATES:
        if probe(opts):
            return opts
    logger.warning("no supported O1 compiler options on this backend; O1 == O3")
    return None


def o1_option_string() -> str:
    opts = _o1_options()
    return "none(==O3)" if opts is None else ",".join(f"{k}={v}" for k, v in opts.items())


def compile_at_level(fn: Callable[..., Any], level: str, *args: Any) -> Callable[..., Any]:
    """Return an executable of ``fn`` at the requested optimization level."""
    if level == "O0":
        return fn  # eager dispatch
    if level == "O1":
        opts = _o1_options()
        lowered = jax.jit(fn).lower(*args)
        return lowered.compile(compiler_options=opts) if opts else lowered.compile()
    if level == "O3":
        return jax.jit(fn)
    raise ValueError(f"unknown opt level {level!r}; choose from {OPT_LEVELS}")
