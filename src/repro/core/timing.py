"""The paper's timing model, adapted to TPU/JAX (DESIGN.md section 4).

The paper samples the per-SM ``%clock`` register immediately before and after a
single PTX instruction, then subtracts a separately calibrated clock-read
overhead. TPUs expose no user-readable in-kernel cycle counter, so this module
implements the same *algebra* at the dispatch granularity:

* ``Timer.sandwich`` — time one jitted region, subtract the calibrated
  null-region overhead (the Fig. 5 "clock overhead" analog).
* ``Timer.slope`` — latency from the difference of two dependent-chain
  lengths: ``(T(n2) - T(n1)) / (n2 - n1)``. The chain carries a data
  dependence through every timed op, which is the paper's "dependent dummy
  operation" defence against the optimizer — XLA can neither dead-code nor
  reorder an op out of the timed region without breaking the dependence.

Both report robust statistics (median + MAD) over repetitions, because host
timers are noisy in a way ``%clock`` is not.
"""
from __future__ import annotations

import contextlib
import dataclasses
import statistics
import time
from typing import Any, Callable, Sequence

import jax

from repro.utils import block


class NoisySlopeError(RuntimeError):
    """A two-length slope came out non-positive: host noise exceeded the
    per-op signal at the given chain spread. Raised (after one widened-spread
    retry) instead of returning a bogus ``<= 0`` latency, so the session
    records a structured :class:`~repro.core.latency_db.ProbeFailure` rather
    than silently persisting a row that would later poison
    ``HloLatencyEstimator`` pricing."""


@dataclasses.dataclass(frozen=True)
class AdaptiveFidelity:
    """Adaptive repetition policy: stop repeating once the running MAD/median
    converges, spend the saved reps on rows that stay noisy.

    A measurement may stop as soon as ``min_reps`` samples are in and
    ``MAD <= rel_mad * median``; the unspent repetitions are banked on the
    Timer. A measurement that is still noisy at its nominal rep count may draw
    banked reps — up to ``(max_extra_factor - 1) * reps`` extra — so the total
    sample budget of a sweep is conserved but concentrated where the noise is.
    """

    rel_mad: float = 0.05
    min_reps: int = 4
    max_extra_factor: float = 2.0

    def converged(self, samples_ns: Sequence[float]) -> bool:
        if len(samples_ns) < max(self.min_reps, 2):
            return False
        med = statistics.median(samples_ns)
        if med <= 0:
            return False
        mad = statistics.median([abs(s - med) for s in samples_ns])
        return mad <= self.rel_mad * med


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Robust summary of repeated wall-clock timings (nanoseconds)."""

    median_ns: float
    mad_ns: float
    min_ns: float
    n: int

    def __sub__(self, other: "Measurement") -> "Measurement":
        return Measurement(
            median_ns=self.median_ns - other.median_ns,
            mad_ns=(self.mad_ns ** 2 + other.mad_ns ** 2) ** 0.5,
            min_ns=self.min_ns - other.min_ns,
            n=min(self.n, other.n),
        )

    def scaled(self, k: float) -> "Measurement":
        return Measurement(self.median_ns * k, self.mad_ns * k, self.min_ns * k, self.n)


def _summarize(samples_ns: Sequence[float]) -> Measurement:
    med = statistics.median(samples_ns)
    mad = statistics.median([abs(s - med) for s in samples_ns]) if len(samples_ns) > 1 else 0.0
    return Measurement(median_ns=med, mad_ns=mad, min_ns=min(samples_ns), n=len(samples_ns))


class Timer:
    """Calibrated wall-clock timer for device-complete executions.

    Parameters
    ----------
    warmup: executions before timing (compile + cache warm; the paper's
        first-sample discard).
    reps: timed repetitions per measurement.
    clock_hz: nominal device clock used to convert ns -> cycles, so tables can
        be reported in cycles like the paper's. Defaults to a calibrated
        estimate of the host clock (see ``calibrate_clock_hz``).
    device: pin every timed/warmed execution (and the compilations they
        trigger) to this jax device via ``jax.default_device``. ``None``
        keeps jax's process default — the pre-multi-device behavior.
        Re-pinning an already-used timer invalidates the null calibrations
        taken while it was unpinned (see the ``device`` property).
    adaptive: an :class:`AdaptiveFidelity` policy, or None (default) for
        fixed repetition counts. When set, ``time_callable`` may stop early
        on converged measurements and spend the banked reps on noisy ones.
    """

    def __init__(self, warmup: int = 3, reps: int = 30, clock_hz: float | None = None,
                 device: Any | None = None,
                 adaptive: "AdaptiveFidelity | None" = None):
        self.warmup = int(warmup)
        self.reps = int(reps)
        self.clock_hz = clock_hz
        self._null_cache: dict[Any, Measurement] = {}
        self._device: Any | None = None
        self.device = device
        self.adaptive = adaptive
        self._rep_bank = 0

    @property
    def device(self) -> Any | None:
        return self._device

    @device.setter
    def device(self, dev: Any | None) -> None:
        """Re-pinning invalidates unpinned-era null calibrations.

        ``_null_cache`` entries are keyed by the ``device`` attribute at
        calibration time. Entries keyed under a *concrete* device were
        measured on that device and stay valid. Entries keyed under ``None``
        were measured on "whatever the default device was then" — once the
        pin changes (a session adopting a shared timer, or an unpin), that
        provenance is no longer trustworthy, and serving them to the newly
        pinned/unpinned timer would hand a stale null measurement to every
        sandwich. They are dropped on any pin change.
        """
        old = self._device
        self._device = dev
        if old is not dev and old != dev:
            stale = [k for k in self._null_cache
                     if isinstance(k, tuple) and len(k) == 2 and k[1] is None]
            for k in stale:
                del self._null_cache[k]

    def device_ctx(self):
        """``jax.default_device`` scope for the pinned device (no-op if unpinned)."""
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    # ------------------------------------------------------------------ raw
    def time_callable(self, fn: Callable[..., Any], *args: Any,
                      warmup: int | None = None, reps: int | None = None) -> Measurement:
        """Median wall time of ``fn(*args)`` with device completion.

        With an :class:`AdaptiveFidelity` policy set, ``reps`` is the nominal
        budget: the loop stops as soon as the running MAD/median converges
        (banking the unspent reps on this timer), and a measurement still
        noisy at the nominal count may draw banked reps to keep sampling.
        ``Measurement.n`` always reports the repetitions actually taken.
        """
        warmup = self.warmup if warmup is None else warmup
        reps = self.reps if reps is None else reps
        adaptive = self.adaptive if (self.adaptive is not None
                                     and reps > self.adaptive.min_reps) else None
        max_total = reps
        if adaptive is not None:
            max_total = reps + min(
                int(reps * (adaptive.max_extra_factor - 1.0)), self._rep_bank)
        with self.device_ctx():
            for _ in range(warmup):
                block(fn(*args))
            samples: list[float] = []
            while len(samples) < max_total:
                t0 = time.perf_counter_ns()
                block(fn(*args))
                samples.append(time.perf_counter_ns() - t0)
                if adaptive is not None and adaptive.converged(samples):
                    break
        if adaptive is not None:
            self._rep_bank += reps - len(samples)  # bank savings / repay draws
        return _summarize(samples)

    # ----------------------------------------------------------- calibration
    def calibrate_null(self, make_null: Callable[[], Callable[..., Any]],
                       *args: Any, key: Any = "default") -> Measurement:
        """Measure the timing overhead itself (Fig. 5 'clock overhead' analog).

        ``make_null`` builds a region with the *same* dispatch path as the
        measured region but zero timed work (e.g. jitted identity on the chain
        carry). Cached per ``(key, pinned device)`` — a calibration taken on
        one device must never satisfy a lookup after re-pinning to another.
        """
        cache_key = (key, self.device)
        if cache_key not in self._null_cache:
            self._null_cache[cache_key] = self.time_callable(make_null(), *args)
        return self._null_cache[cache_key]

    # --------------------------------------------------------------- methods
    def sandwich(self, fn: Callable[..., Any], null_fn: Callable[..., Any],
                 *args: Any) -> Measurement:
        """Paper's clock-sandwich: T(region) - T(calibrated null region)."""
        t_fn = self.time_callable(fn, *args)
        t_null = self.time_callable(null_fn, *args)
        return t_fn - t_null

    def slope(self, fn_by_len: Callable[[int], Callable[..., Any]],
              n1: int, n2: int, *args: Any,
              warmup: int | None = None, reps: int | None = None,
              use_min: bool = True,
              retry_lens: tuple[int, int] | None = None) -> Measurement:
        """Per-op latency from two chain lengths (overhead cancels exactly).

        With ``use_min`` (default) the difference of per-length *minimum*
        times is used: the noise-floor estimator, far more robust on a shared
        host than medians (wall-clock noise is strictly additive).

        A non-positive estimate means host noise exceeded the signal at this
        chain spread (``min(T(n2)) <= min(T(n1))`` happens on loaded hosts
        when ``n2 - n1`` is small). Instead of returning the bogus value —
        which used to be silently persisted and later poisoned estimator
        pricing — the measurement is retried **once** with a widened spread
        (``retry_lens``; defaults to ``(n1, n2 + 3*(n2 - n1))``), and if the
        retry is still non-positive a :class:`NoisySlopeError` is raised so
        the caller records a structured failure. Callers whose chains have a
        length cap pass an explicitly capped ``retry_lens``; passing the
        original ``(n1, n2)`` disables the retry (raise immediately).
        """
        assert n2 > n1 >= 0
        diff = self._slope_once(fn_by_len, n1, n2, *args,
                                warmup=warmup, reps=reps, use_min=use_min)
        if diff.median_ns > 0:
            return diff
        widened = retry_lens if retry_lens is not None else (n1, n2 + 3 * (n2 - n1))
        if tuple(widened) != (n1, n2) and widened[1] > widened[0] >= 0:
            retry = self._slope_once(fn_by_len, widened[0], widened[1], *args,
                                     warmup=warmup, reps=reps, use_min=use_min)
            if retry.median_ns > 0:
                return retry
        raise NoisySlopeError(
            f"non-positive slope ({diff.median_ns:.3f} ns/op) at chain lens "
            f"({n1}, {n2}): host noise exceeded the per-op signal"
            + ("" if tuple(widened) == (n1, n2) else
               f"; widened retry at {tuple(widened)} was also non-positive"))

    def _slope_once(self, fn_by_len: Callable[[int], Callable[..., Any]],
                    n1: int, n2: int, *args: Any,
                    warmup: int | None = None, reps: int | None = None,
                    use_min: bool = True) -> Measurement:
        t1 = self.time_callable(fn_by_len(n1), *args, warmup=warmup, reps=reps)
        t2 = self.time_callable(fn_by_len(n2), *args, warmup=warmup, reps=reps)
        diff = (t2 - t1).scaled(1.0 / (n2 - n1))
        if use_min:
            est = (t2.min_ns - t1.min_ns) / (n2 - n1)
            diff = Measurement(median_ns=est, mad_ns=diff.mad_ns,
                               min_ns=est, n=diff.n)
        return diff

    # ----------------------------------------------------------------- units
    def calibrate_clock_hz(self) -> float:
        """Estimate an effective clock for ns->cycle conversion.

        On NVIDIA the paper reads cycles directly; here we report ns natively
        and convert with a calibrated clock so tables remain comparable.
        Uses a spin-loop of known iteration count as a rough frequency probe,
        falling back to 1 GHz (1 cycle == 1 ns) when unavailable.
        """
        if self.clock_hz:
            return self.clock_hz
        # Time a fixed number of perf_counter reads; their cost is a stable
        # few-ns quantity, giving a deterministic, platform-stable pseudo-clock.
        n = 200_000
        t0 = time.perf_counter_ns()
        x = 0
        for i in range(n):
            x += i
        dt = time.perf_counter_ns() - t0
        per_iter_ns = dt / n
        # one trivial ALU-ish python iteration ~ tens of ns; we only need a
        # stable constant. Clamp to a sane band.
        hz = 1e9 / max(min(per_iter_ns, 1000.0), 1.0) * 1.0
        self.clock_hz = max(min(hz, 5e9), 1e8)
        return self.clock_hz

    def to_cycles(self, m: Measurement) -> float:
        return m.median_ns * (self.calibrate_clock_hz() / 1e9)
