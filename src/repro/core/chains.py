"""Dependent-op chains: the paper's instruction table, at the JAX/StableHLO layer.

The paper (Table II) sweeps every PTX instruction in 8 categories, timing each
one inside a ``%clock`` sandwich with a *dependent dummy operation* so `-O3`
cannot optimize it away. Here each table entry is an :class:`OpSpec` whose
``step(x, ops)`` function maps the chain carry ``x`` to the next carry through
the measured primitive. Latency is extracted with :meth:`Timer.slope` between
two chain lengths, which cancels dispatch overhead exactly (the clock-overhead
subtraction of the paper, Fig. 5).

Anti-optimization discipline (mirrors Section IV-A of the paper):

* every operand is a **runtime argument**, so XLA's algebraic simplifier cannot
  constant-fold, strength-reduce, or identity-eliminate (``x*1.0``) the chain —
  except for the ``div``/``rem`` *regular/irregular* variants, where a constant
  power-of-two / non-power-of-two divisor is **deliberately** baked in to expose
  the compiler's strength reduction, exactly like the paper's divisor split;
* idempotent or involutive primitives (``abs``, ``not``, ``min``…) are guarded
  with one extra trivial op so consecutive applications cannot be collapsed;
  the guard count is recorded in ``OpSpec.guard`` and reporting subtracts
  ``guard × latency(add)``;
* fixed points of every step are numerically stable so 256-long chains neither
  overflow nor produce NaNs (validated by tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

Array = Any

CATEGORIES = (
    "int_arith",        # (1) Integer Arithmetic Instructions
    "logic_shift",      # (2) Logic and Shift Instructions
    "fp32",             # (3) Single Precision Instructions
    "fp64",             # (4) Double Precision Instructions
    "fp16",             # (5) Half Precision Instructions (f16 + bf16 on TPU)
    "multi_precision",  # (6) Multi/extended Precision (carry-chain analog: i64, widening mul)
    "special_math",     # (7) Special Mathematical Instructions (SFU -> TPU transcendental)
    "int_intrinsic",    # (8) Integer Intrinsic Instructions
)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One row of the latency table."""

    name: str
    category: str
    dtype: str                     # dtype of the chain carry
    step: Callable[..., Array]     # (x, *operands) -> next x (dependent!)
    init: float | int              # initial carry value
    operands: tuple[float | int, ...] = ()   # runtime operand values
    guard: int = 0                 # number of extra trivial ALU ops inside step
    notes: str = ""
    requires_x64: bool = False     # step uses 64-bit intermediates
    max_chain: int | None = None   # cap chain length (XLA compile-time pathologies)

    def carry(self) -> Array:
        return jnp.asarray(self.init, dtype=self.dtype)

    def operand_arrays(self) -> tuple[Array, ...]:
        return tuple(jnp.asarray(v, dtype=self.dtype) for v in self.operands)


def chain_fn(spec: OpSpec, n: int) -> Callable[..., Array]:
    """Straight-line (loop-free, like the paper's PTX bodies) chain of length n."""
    step = spec.step

    def chain(x: Array, *ops: Array) -> Array:
        for _ in range(n):
            x = step(x, *ops)
        return x

    return chain


# --------------------------------------------------------------------------
# Registry builders. Fixed-point stability of each step is covered by tests.
# --------------------------------------------------------------------------
def _f(name: str, cat: str, dt: str, step: Callable[..., Array], init: float,
       operands: tuple[float, ...] = (), guard: int = 0, notes: str = "",
       requires_x64: bool = False, max_chain: int | None = None) -> OpSpec:
    return OpSpec(name, cat, dt, step, init, operands, guard, notes, requires_x64, max_chain)


def _int_ops(dt: str = "int32", cat: str = "int_arith") -> list[OpSpec]:
    i = functools.partial(_f, cat=cat, dt=dt)
    sfx = "" if dt == "int32" else f".{dt}"
    # Integer +,-,* are reassociable: LLVM collapses a pure chain (x+a+a+...
    # -> x + n*a), which would report 0 ns — the exact failure mode the
    # paper's "dependent dummy operation" guards against. Each step therefore
    # pairs the measured op with a xor/add guard that blocks reassociation;
    # reporting subtracts guard x baseline (see measure.run_suite).
    ops = [
        i(f"add{sfx}", step=lambda x, a, b: (x + a) ^ b, init=1, operands=(3, 0x55),
          guard=1, notes="xor-guarded: int add chains reassociate"),
        i(f"sub{sfx}", step=lambda x, a, b: (x - a) ^ b, init=1, operands=(3, 0x55),
          guard=1, notes="xor-guarded"),
        i(f"mul{sfx}", step=lambda x, a, b: (x * a) ^ b, init=3, operands=(5, 0x55),
          guard=1, notes="xor-guarded"),
        i(f"mad{sfx}", step=lambda x, a, b: (x * a + b) ^ a, init=3, operands=(5, 1),
          guard=1, notes="xor-guarded"),
        i(f"min{sfx}", step=lambda x, a, b: jnp.minimum(x, a) + b, init=1,
          operands=(7, 1), guard=1, notes="guarded: min is idempotent"),
        i(f"max{sfx}", step=lambda x, a, b: jnp.maximum(x, a) - b, init=1,
          operands=(7, 1), guard=1, notes="guarded: max is idempotent"),
        i(f"abs{sfx}", step=lambda x, a: jnp.abs(x - a), init=0, operands=(1,),
          guard=1, notes="guarded: abs is idempotent"),
        # Divisor split, exactly the paper's regular/irregular/runtime taxonomy.
        # PTX div.s truncates like C, so lax.div (truncating) is the faithful
        # primitive; jnp's floor-div would add sign-correction ops.
        i(f"div.s.regular{sfx}", step=lambda x, a: lax.div(x, jnp.asarray(4, x.dtype)) + a,
          init=9, operands=(7,), guard=1,
          notes="const pow-2 divisor -> strength-reduced to shift"),
        i(f"div.s.irregular{sfx}", step=lambda x, a: lax.div(x, jnp.asarray(5, x.dtype)) + a,
          init=9, operands=(7,), guard=1, notes="const non-pow-2 divisor -> magic-number mul"),
        i(f"div.s.runtime{sfx}", step=lambda x, a, b: lax.div(x, a) + b, init=9,
          operands=(5, 7), guard=1, notes="runtime divisor -> true divide"),
        i(f"rem.s{sfx}", step=lambda x, a, b: lax.rem(x, a) + b, init=9, operands=(5, 7),
          guard=1),
    ]
    if dt == "int32":
        u = functools.partial(_f, cat=cat, dt="uint32")
        ops += [
            u("div.u.regular", step=lambda x, a: lax.div(x, jnp.asarray(8, x.dtype)) + a,
              init=9, operands=(7,), guard=1),
            u("div.u.irregular", step=lambda x, a: lax.div(x, jnp.asarray(6, x.dtype)) + a,
              init=9, operands=(7,), guard=1),
            u("div.u.runtime", step=lambda x, a, b: lax.div(x, a) + b, init=9,
              operands=(5, 7), guard=1),
            u("rem.u", step=lambda x, a, b: lax.rem(x, a) + b, init=9, operands=(5, 7),
              guard=1),
        ]
    return ops


def _logic_ops() -> list[OpSpec]:
    l = functools.partial(_f, cat="logic_shift", dt="int32")
    return [
        l("and", step=lambda x, a, b: (x & a) + b, init=0x55AA, operands=(0x0F0F, 3),
          guard=1, notes="add-guarded: and is idempotent/absorbing"),
        l("or", step=lambda x, a, b: (x | a) + b, init=0x55AA, operands=(0x0F0F, 3),
          guard=1, notes="add-guarded: or is idempotent/absorbing"),
        l("xor", step=lambda x, a, b: (x ^ a) + b, init=0x55AA, operands=(0x0F0F, 3),
          guard=1, notes="add-guarded: xor chains cancel pairwise"),
        l("not", step=lambda x, a: ~x + a, init=0x55AA, operands=(3,),
          guard=1, notes="add-guarded: not is involutive"),
        l("cnot", step=lambda x, a: (x == 0).astype(jnp.int32) + a, init=0, operands=(0,),
          guard=1, notes="PTX cnot: x==0 ? 1 : 0"),
        l("shl", step=lambda x, a, b: (x << a) | b, init=1, operands=(1, 1),
          guard=1, notes="or-guarded: shift-by-const chains merge"),
        l("shr", step=lambda x, a: (x >> a) | a, init=1 << 30, operands=(1,), guard=1),
    ]


def _float_ops(dt: str, cat: str) -> list[OpSpec]:
    f = functools.partial(_f, cat=cat, dt=dt)
    ops = [
        f(f"add.{dt}", step=lambda x, a: x + a, init=1.0, operands=(1e-3,)),
        f(f"sub.{dt}", step=lambda x, a: x - a, init=1.0, operands=(1e-3,)),
        f(f"mul.{dt}", step=lambda x, a: x * a, init=1.0, operands=(0.999,)),
        f(f"fma.{dt}", step=lambda x, a, b: x * a + b, init=1.0, operands=(0.5, 0.5)),
        f(f"min.{dt}", step=lambda x, a, b: jnp.minimum(x, a) + b, init=0.0,
          operands=(2.0, 0.125), guard=1),
        f(f"max.{dt}", step=lambda x, a, b: jnp.maximum(x, a) - b, init=4.0,
          operands=(2.0, 0.125), guard=1),
    ]
    if cat in ("fp32", "fp64"):
        ops += [
            f(f"div.regular.{dt}", step=lambda x, a: x / 4.0 + a, init=1.0, operands=(0.75,),
              guard=1, notes="const pow-2 divisor -> reciprocal multiply"),
            f(f"div.irregular.{dt}", step=lambda x, a: x / 3.0 + a, init=1.0, operands=(0.75,),
              guard=1, notes="const non-pow-2 divisor"),
            f(f"div.runtime.{dt}", step=lambda x, a, b: x / a + b, init=1.0, operands=(3.0, 0.75),
              guard=1, notes="runtime divisor -> true fdiv"),
        ]
    return ops


def _multi_precision_ops() -> list[OpSpec]:
    m = functools.partial(_f, cat="multi_precision", dt="int64")
    u = functools.partial(_f, cat="multi_precision", dt="uint32")

    def mul64hi(x, a):
        wide = x.astype(jnp.uint64) * a.astype(jnp.uint64)
        return (wide >> jnp.uint64(32)).astype(jnp.uint32) | jnp.uint32(1)

    return [
        m("add.cc", step=lambda x, a, b: (x + a) ^ b, init=1, operands=(3, 0x55), guard=1,
          notes="64-bit add == add-with-carry chain on 32-bit lanes; xor-guarded"),
        m("sub.cc", step=lambda x, a, b: (x - a) ^ b, init=1, operands=(3, 0x55), guard=1),
        m("mad.cc", step=lambda x, a, b: (x * a + b) ^ a, init=3, operands=(5, 1), guard=1),
        m("mul.wide", step=lambda x, a, b: (x * a) ^ b, init=3, operands=(5, 0x55), guard=1),
        u("mul64hi", step=mul64hi, init=0xDEADBEEF, operands=(0x9E3779B9,), guard=2,
          notes="widening u32*u32->u64 high half; convert+shift guards", requires_x64=True),
    ]


def _special_math_ops() -> list[OpSpec]:
    s = functools.partial(_f, cat="special_math", dt="float32")
    return [
        s("rcp", step=lambda x, a: 1.0 / x + a, init=2.0, operands=(0.5,), guard=1,
          notes="guarded: rcp is involutive"),
        s("sqrt", step=lambda x, a: jnp.sqrt(x) + a, init=1.0, operands=(0.25,), guard=1),
        s("rsqrt", step=lambda x, a: lax.rsqrt(x) + a, init=1.0, operands=(0.25,), guard=1),
        s("sin", step=lambda x, a: jnp.sin(x) + a, init=0.5, operands=(0.125,), guard=1),
        s("cos", step=lambda x: jnp.cos(x), init=0.5, notes="cos has a stable fixed point"),
        s("lg2", step=lambda x, a: jnp.log2(x + a), init=1.0, operands=(2.0,), guard=1),
        s("ex2", step=lambda x, a: jnp.exp2(x) - a, init=0.0, operands=(1.0,), guard=1,
          notes="fixed point 0; |f'(0)| = ln2 < 1"),
        s("tanh", step=lambda x, a: jnp.tanh(x) + a, init=0.0, operands=(0.125,), guard=1),
        s("copysign", step=lambda x, a, b: jnp.copysign(x, a) + b, init=1.0,
          operands=(1.0, 1e-3), guard=1, notes="guarded: copysign is idempotent"),
    ]


def _int_intrinsic_ops() -> list[OpSpec]:
    t = functools.partial(_f, cat="int_intrinsic", dt="int32")
    tu = functools.partial(_f, cat="int_intrinsic", dt="uint32")
    return [
        t("sad", step=lambda x, a, b: jnp.abs(x - a) + b, init=0, operands=(3, 1), guard=1,
          notes="PTX sad: |x-a|+b"),
        tu("popc", step=lambda x, a: lax.population_count(x) ^ a, init=0xF0F0F0F0,
           operands=(0xA5A5A5A5,), guard=1),
        tu("clz", step=lambda x, a: lax.clz(x) + a, init=1, operands=(3,), guard=1),
        t("bfe", step=lambda x, a, b: ((x >> a) & 0xFFFF) + b, init=0x7FFF00, operands=(3, 9),
          guard=2, notes="bitfield extract: shift+mask"),
        t("bfi", step=lambda x, a, b: (x & ~0xFF) | (a & 0xFF) | b, init=0x55AA55,
          operands=(0xC3, 0), guard=2,
          notes="bitfield insert emulation; (a & 0xFF) is loop-invariant and "
                "CSE'd out of the chain, so only 2 guard ops execute per step"),
        t("mul24", step=lambda x, a: ((x & 0xFFFFFF) * (a & 0xFFFFFF)) & 0x7FFFFFFF,
          init=3, operands=(5,), guard=2,
          notes="24-bit multiply emulation; (a & 0xFFFFFF) is loop-invariant "
                "and CSE'd out of the chain, so only 2 guard ops execute"),
    ]


@functools.cache
def default_registry(include_fp64: bool = True) -> tuple[OpSpec, ...]:
    """All table rows: the JAX analog of sweeping PTX ISA 6.4."""
    ops: list[OpSpec] = []
    ops += _int_ops("int32")
    ops += _logic_ops()
    ops += _float_ops("float32", "fp32")
    if include_fp64:
        ops += _float_ops("float64", "fp64")
    ops += _float_ops("bfloat16", "fp16")
    ops += _float_ops("float16", "fp16")
    ops += _multi_precision_ops()
    ops += _special_math_ops()
    ops += _int_intrinsic_ops()
    names = [o.name for o in ops]
    assert len(names) == len(set(names)), "duplicate op names in registry"
    return tuple(ops)


def by_category(cat: str, registry: Sequence[OpSpec] | None = None) -> list[OpSpec]:
    registry = registry or default_registry()
    return [o for o in registry if o.category == cat]
