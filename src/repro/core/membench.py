"""Memory-hierarchy probes (paper Section V-B3, Fig. 6, Table IV).

The paper measures device-memory latency with a cold load, then L1/L2 hit
latencies by re-loading a *different word of the same cache line* (so the
compiler cannot fold the load), toggling L1 via compile flags. The portable
analog used here is the classic **dependent pointer chase**: a permutation
ring ``p`` is walked as ``i = p[i]``; each load's address depends on the
previous load's *value*, so no prefetcher or compiler can overlap or elide
them. Latency-per-load as a function of working-set size exposes every level
of the hierarchy as a capacity cliff (CPU: L1/L2/L3/DRAM; TPU: VMEM vs HBM).

The Pallas ``chase`` kernel (kernels/chase.py) runs the same probe *inside* a
TPU kernel at a footprint-selected residency — BlockSpec-pinned in VMEM (the
shared-memory / Table IV analog) or streaming from HBM via ``memory_space=ANY``
(the Fig. 6 analog); ``build_ring`` below is the shared probe input for both
and for the host-level sweep, and ``repro.api.MemoryChaseProbe`` is its
scheduled front door (docs/memory.md).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.timing import Timer
from repro.utils import logger, parse_kv_notes


@dataclasses.dataclass(frozen=True)
class MemPoint:
    working_set_bytes: int
    latency_ns: float       # steady-state per-load latency (hit in whichever level fits)
    cold_latency_ns: float  # first-touch latency (the paper's 'global memory' number)
    stride_bytes: int


def _ring_permutation(n: int, seed: int = 0) -> np.ndarray:
    """Random single-cycle permutation, so the chase visits all slots.

    Threading *any* random visiting order into a pointer table yields a single
    n-cycle, so a vectorized shuffle suffices (the old element-wise sattolo
    loop made >16 MiB rings cost seconds of pure Python before measuring).
    """
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n).astype(np.int32)
    ring = np.empty(n, dtype=np.int32)
    ring[idx[:-1]] = idx[1:]
    ring[idx[-1]] = idx[0]
    return ring


def build_ring(working_set_bytes: int, line_bytes: int = 64, seed: int = 0
               ) -> tuple[jax.Array, jax.Array]:
    """Line-padded chase ring covering ``working_set_bytes``: ``(ring, start)``.

    One live slot per cache line (the paper's different-word-same-line trick
    inverted: we *want* misses beyond the level capacity, so slots are
    line-padded); slot values are absolute indices into the padded array, so
    the same ring drives the host chase (``chase_fn``), the in-kernel VMEM
    chase and the HBM-streaming chase (``kernels/chase.py``) — one probe
    input, three residencies.
    """
    n = max(working_set_bytes // line_bytes, 8)
    pad = line_bytes // 4
    ring_np = _ring_permutation(n, seed) * pad
    full = np.zeros(n * pad, dtype=np.int32)
    full[np.arange(n) * pad] = ring_np
    return jnp.asarray(full), jnp.asarray([0], jnp.int32)


def chase_fn(steps: int):
    """jit-able dependent pointer chase: i_{k+1} = ring[i_k]."""

    def chase(ring: jax.Array, start: jax.Array) -> jax.Array:
        def body(_, p):
            return ring[p]
        return lax.fori_loop(0, steps, body, start)

    return chase


def _cold_latency_ns(fn, ring: jax.Array, start: jax.Array, steps: int) -> float:
    """First-touch per-load latency of ``fn(ring, start)``, compile excluded.

    The jit cache is warmed with a *shape-only* call on a zeroed ring of the
    same shape, so the timed pass is the first execution touching ``ring``'s
    memory but never an XLA compile. (``fn.lower().compile()`` does NOT
    populate the jit dispatch cache: a sweep relying on it re-compiled inside
    the timed cold pass at every new working-set shape, conflating
    ``cold_latency_ns`` with ~40x its value of compile time.)
    """
    import time

    jax.block_until_ready(fn(jnp.zeros_like(ring), start))
    t0 = time.perf_counter_ns()
    jax.block_until_ready(fn(ring, start))
    return (time.perf_counter_ns() - t0) / steps


@dataclasses.dataclass
class PreparedChase:
    """Compiled host-chase callables (the XLA-bound half of
    :func:`measure_latency`); built off the timing thread by
    :func:`prepare_chase`, consumed by :func:`run_prepared_chase`."""

    working_set_bytes: int
    line_bytes: int
    steps: tuple[int, int]
    ring: jax.Array
    start: jax.Array
    f1: "jax.stages.Compiled"
    f2: "jax.stages.Compiled"


def chase_cache_key(ws: int, steps: int, line_bytes: int, env) -> tuple:
    """The CompileCache key one chase compile is stored under — shared with
    ``repro.audit`` so the auditor can peek the optimized-HLO sidecar."""
    from repro.core.compile_cache import fidelity_key

    return fidelity_key(env, f"mem.chase.ws{ws}", "O3", "int32",
                        f"steps{steps}.line{line_bytes}")


def _compile_chase(n: int, ring: jax.Array, start: jax.Array, ws: int,
                   line_bytes: int, cache=None, env=None):
    """One chase-length callable, AOT through the persistent cache if given.

    Without a cache this stays the legacy lazy ``jax.jit`` (compiled at the
    first warmup call), so the serial path's behavior is unchanged.
    """
    if cache is not None and env is not None:
        from repro.core.compile_cache import hlo_extra

        key = chase_cache_key(ws, n, line_bytes, env)
        compiled, _, _ = cache.load_or_compile(
            key, lambda: jax.jit(chase_fn(n)).lower(ring, start).compile(),
            extra=hlo_extra)
        return compiled
    return jax.jit(chase_fn(n))


def prepare_chase(working_set_bytes: int, line_bytes: int = 64,
                  steps: tuple[int, int] = (2048, 6144),
                  cache=None, env=None) -> PreparedChase:
    """Build the ring and compile both chase lengths; no device timing."""
    ring, _ = build_ring(working_set_bytes, line_bytes)
    start = jnp.asarray(0, jnp.int32)
    n1, n2 = steps
    f1 = _compile_chase(n1, ring, start, working_set_bytes, line_bytes,
                        cache=cache, env=env)
    f2 = _compile_chase(n2, ring, start, working_set_bytes, line_bytes,
                        cache=cache, env=env)
    return PreparedChase(working_set_bytes=working_set_bytes,
                         line_bytes=line_bytes, steps=(n1, n2),
                         ring=ring, start=start, f1=f1, f2=f2)


def run_prepared_chase(prepared: PreparedChase, timer: Timer | None = None
                       ) -> MemPoint:
    """Time a :class:`PreparedChase`: the device-serial half of the split."""
    timer = timer or Timer(warmup=2, reps=15)
    ring, start = prepared.ring, prepared.start
    n1, n2 = prepared.steps
    # Cold: first execution after transfer. The AOT-compiled f2 is warmed
    # shape-only on a zeroed ring, so no compile lands inside the timed pass.
    cold_ns = _cold_latency_ns(prepared.f2, ring, start, n2)
    m1 = timer.time_callable(prepared.f1, ring, start)
    m2 = timer.time_callable(prepared.f2, ring, start)
    per_load = max((m2.median_ns - m1.median_ns) / (n2 - n1), 0.0)
    return MemPoint(working_set_bytes=prepared.working_set_bytes,
                    latency_ns=per_load, cold_latency_ns=cold_ns,
                    stride_bytes=prepared.line_bytes)


def measure_latency(working_set_bytes: int, line_bytes: int = 64,
                    timer: Timer | None = None,
                    steps: tuple[int, int] = (2048, 6144)) -> MemPoint:
    """Per-load latency for a working set of the given size.

    Equivalent to ``run_prepared_chase(prepare_chase(...))`` — the serial
    form of the pipelined split.
    """
    return run_prepared_chase(
        prepare_chase(working_set_bytes, line_bytes, steps), timer)


def mempoint_from_record(rec) -> MemPoint:
    """Rebuild a MemPoint from its LatencyDB record (see api.MemoryProbe).

    The probe encodes the working set in the op name (``mem.chase.ws<N>``)
    and the cold/stride figures in the notes field.
    """
    fields = dict(kv.split("=", 1) for kv in rec.notes.split() if "=" in kv)
    return MemPoint(working_set_bytes=int(rec.op.rsplit("ws", 1)[1].split(".")[0]),
                    latency_ns=rec.latency_ns,
                    cold_latency_ns=float(fields.get("cold_ns", 0.0)),
                    stride_bytes=int(fields.get("stride", 64)))


@dataclasses.dataclass(frozen=True)
class ChasePoint:
    """One in-kernel memory row (see api.MemoryChaseProbe): per-load latency
    plus the working-set metadata persisted in the record's notes field."""

    working_set_bytes: int
    latency_ns: float
    memory_space: str   # residency the kernel ran under: "vmem" | "any"
    line_bytes: int


def chasepoint_from_record(rec) -> ChasePoint:
    """Rebuild a ChasePoint from an ``inkernel.mem.<bytes>`` LatencyDB record.

    The probe encodes the working set in the op name and the residency /
    line-size metadata as ``key=value`` pairs in the notes field.
    """
    fields = parse_kv_notes(rec.notes)
    return ChasePoint(
        working_set_bytes=int(fields["ws"]),
        latency_ns=rec.latency_ns,
        memory_space=fields.get("space", "vmem"),
        line_bytes=int(fields.get("line", 64)))


def sweep(working_sets: Sequence[int] | None = None, timer: Timer | None = None
          ) -> list[MemPoint]:
    """Deprecated shim (Fig. 6 analog): latency vs working-set size.

    Use ``Session().run(Plan.memory(...))`` instead — same probe with
    caching and resumability.
    """
    import warnings

    warnings.warn(
        "membench.sweep is deprecated; use "
        "repro.api.Session.run(Plan.memory(...))",
        DeprecationWarning, stacklevel=2)
    from repro.api import Plan, Session

    session = Session(timer=timer or Timer(warmup=2, reps=15))
    result = session.run(Plan.memory(working_sets), force=True)
    pts = [mempoint_from_record(r.record) for r in result.results
           if r.record is not None]
    for pt in pts:
        logger.info("chase ws=%-10d hit=%6.2fns cold=%6.2fns",
                    pt.working_set_bytes, pt.latency_ns, pt.cold_latency_ns)
    return pts


def detect_levels(points: Sequence[MemPoint], jump: float = 1.6) -> list[dict]:
    """Identify capacity cliffs: consecutive latency jumps >= ``jump``x."""
    levels, cur = [], []
    for prev, nxt in zip(points, points[1:]):
        cur.append(prev)
        if prev.latency_ns > 0 and nxt.latency_ns / max(prev.latency_ns, 1e-9) >= jump:
            levels.append(cur)
            cur = []
    cur.append(points[-1])
    levels.append(cur)
    out = []
    for i, grp in enumerate(levels):
        out.append({
            "level": i,
            "capacity_bytes_lower_bound": grp[-1].working_set_bytes,
            "hit_latency_ns": float(np.median([p.latency_ns for p in grp])),
        })
    return out


def bandwidth_probe(size_bytes: int = 1 << 26, timer: Timer | None = None) -> float:
    """Streaming-copy bandwidth in GB/s (paper Table I 'memory bandwidth' analog)."""
    timer = timer or Timer(warmup=2, reps=10)
    n = size_bytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda v: v * 2.0 + 1.0)
    m = timer.time_callable(f, x)
    return (2 * size_bytes) / max(m.median_ns, 1.0)  # read + write, bytes/ns == GB/s
