"""Per-op measurement (Section IV) + deprecation shims for the old suite API.

``measure_op`` / ``measure_op_full`` extract one instruction's latency with
the two-length slope method and remain the measurement core. The old suite
entry points (``run_suite``, ``clock_overhead``) are thin shims over
:mod:`repro.api` — new code should build a :class:`repro.api.Plan` and run it
through a :class:`repro.api.Session`, which adds caching, resumability and
structured failure records.
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Callable, Sequence

import jax

from repro.core.chains import OpSpec, chain_fn
from repro.core.latency_db import LatencyDB
from repro.core.optlevels import OPT_LEVELS, compile_at_level
from repro.core.timing import Measurement, Timer

# Chain lengths per opt level: eager dispatch is ~1e4x slower per op, so O0
# uses short chains (the paper's -O0 numbers are likewise dominated by
# unoptimized issue overhead). Long O3 chains push the per-op signal well
# above host-timer noise; the slope uses min-statistics (noise floor).
_CHAIN_LENS = {"O0": (2, 10), "O1": (64, 512), "O3": (64, 512)}
_REPS = {"O0": 5, "O1": 30, "O3": 30}


def _needs_x64(spec: OpSpec) -> bool:
    return spec.requires_x64 or spec.dtype in ("int64", "uint64", "float64")


def _x64_ctx(spec: OpSpec):
    if _needs_x64(spec):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def measure_op_full(spec: OpSpec, opt_level: str = "O3",
                    timer: Timer | None = None) -> Measurement:
    """Per-op latency at the given optimization level, with dispersion.

    Returns the full :class:`Measurement` (median + MAD + min) so callers can
    propagate the dispersion into :class:`LatencyRecord.mad_ns` instead of
    dropping it.
    """
    timer = timer or Timer()
    n1, n2 = _CHAIN_LENS[opt_level]
    if spec.max_chain is not None:
        n1, n2 = min(n1, spec.max_chain // 3), min(n2, spec.max_chain)
    reps = _REPS[opt_level]
    with _x64_ctx(spec):
        carry = spec.carry()
        operands = spec.operand_arrays()

        def fn_by_len(n: int) -> Callable:
            return compile_at_level(chain_fn(spec, n), opt_level, carry, *operands)

        return timer.slope(fn_by_len, n1, n2, carry, *operands, reps=reps)


def measure_op(spec: OpSpec, opt_level: str = "O3", timer: Timer | None = None) -> float:
    """Median per-op latency in ns at the given optimization level."""
    return max(measure_op_full(spec, opt_level, timer).median_ns, 0.0)


def run_suite(registry: Sequence[OpSpec] | None = None,
              opt_levels: Sequence[str] = OPT_LEVELS,
              db: LatencyDB | None = None,
              timer: Timer | None = None,
              categories: Sequence[str] | None = None) -> LatencyDB:
    """Deprecated shim: measure every op at every level into the LatencyDB.

    Use ``Session(db=...).run(Plan.instructions(...))`` instead — same
    measurements plus caching, resume and structured failures. This shim
    keeps the old always-re-measure semantics (``force=True``).
    """
    warnings.warn(
        "measure.run_suite is deprecated; use "
        "repro.api.Session.run(Plan.instructions(...))",
        DeprecationWarning, stacklevel=2)
    from repro.api import Plan, Session

    session = Session(db=db, timer=timer)
    session.run(Plan.instructions(registry=registry, opt_levels=opt_levels,
                                  categories=categories), force=True)
    return session.db


def clock_overhead(timer: Timer | None = None, opt_levels: Sequence[str] = OPT_LEVELS
                   ) -> dict[str, float]:
    """Deprecated shim (Fig. 5 analog): timed-region cost per opt level.

    Use ``Session().run(Plan.clock_overhead(...))`` instead.
    """
    warnings.warn(
        "measure.clock_overhead is deprecated; use "
        "repro.api.Session.run(Plan.clock_overhead(...))",
        DeprecationWarning, stacklevel=2)
    from repro.api import Plan, Session

    result = Session(timer=timer).run(Plan.clock_overhead(opt_levels), force=True)
    if result.failed:  # the old implementation raised; stay loud for callers
        f = result.failed[0].failure
        raise RuntimeError(
            f"clock_overhead@{f.opt_level} failed: {f.error_type}: {f.message}")
    return {r.record.opt_level: r.record.latency_ns for r in result.results
            if r.record is not None}
