"""Per-op measurement (Section IV) + deprecation shims for the old suite API.

``measure_op`` / ``measure_op_full`` extract one instruction's latency with
the two-length slope method and remain the measurement core. The measurement
is split in two (docs/performance.md):

* :func:`prepare_op` does everything XLA-bound — builds the chain callables at
  both lengths and compiles them (through a persistent
  :class:`~repro.core.compile_cache.CompileCache` when one is given), no
  device timing;
* :func:`run_prepared_op` does everything device-bound — the two-length
  :meth:`Timer.slope` over the prepared callables.

The split is what lets the session's compile-ahead thread lower probe N+1
while probe N times. ``measure_op_full`` remains the one-call form (prepare
then run) so serial callers are byte-identical to the pipelined path.

The old suite entry points (``run_suite``, ``clock_overhead``) are thin shims
over :mod:`repro.api` — new code should build a :class:`repro.api.Plan` and
run it through a :class:`repro.api.Session`, which adds caching, resumability
and structured failure records.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Callable, Mapping, Sequence

import jax

from repro.core.chains import OpSpec, chain_fn
from repro.core.latency_db import LatencyDB
from repro.core.optlevels import OPT_LEVELS, compile_at_level
from repro.core.timing import Measurement, Timer

# Chain lengths per opt level: eager dispatch is ~1e4x slower per op, so O0
# uses short chains (the paper's -O0 numbers are likewise dominated by
# unoptimized issue overhead). Long O3 chains push the per-op signal well
# above host-timer noise; the slope uses min-statistics (noise floor).
_CHAIN_LENS = {"O0": (2, 10), "O1": (64, 512), "O3": (64, 512)}
_REPS = {"O0": 5, "O1": 30, "O3": 30}

# Widened-spread retry factor when a slope comes out non-positive: the new
# upper length is n1 + _RETRY_WIDEN * (n2 - n1), capped at the spec's
# max_chain (see Timer.slope).
_RETRY_WIDEN = 4


def _needs_x64(spec: OpSpec) -> bool:
    return spec.requires_x64 or spec.dtype in ("int64", "uint64", "float64")


def _x64_ctx(spec: OpSpec):
    if _needs_x64(spec):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def retry_lens_for(spec: OpSpec, n1: int, n2: int) -> tuple[int, int]:
    """Capped widened chain spread for the noisy-slope retry.

    Returns the original ``(n1, n2)`` (which disables the retry) when the
    spec's ``max_chain`` leaves no room to widen.
    """
    widened = n1 + _RETRY_WIDEN * (n2 - n1)
    if spec.max_chain is not None:
        widened = min(widened, spec.max_chain)
    return (n1, widened) if widened > n2 else (n1, n2)


def compile_chain(spec: OpSpec, n: int, opt_level: str, *args: Any,
                  cache: Any = None, env: Mapping[str, str] | None = None
                  ) -> Callable:
    """One chain callable at length ``n``, compiled through the cache.

    ``O0`` is eager — nothing to compile or cache. ``O1``/``O3`` are
    AOT-compiled (``jit().lower().compile()``) so the resulting executable is
    a serializable object the :class:`CompileCache` can persist; without a
    cache the compile simply isn't stored.
    """
    fn = chain_fn(spec, n)
    if opt_level == "O0":
        return fn
    if cache is not None and env is not None:
        from repro.core.compile_cache import hlo_extra

        key = chain_cache_key(spec, n, opt_level, env)
        compiled, _, _ = cache.load_or_compile(
            key, lambda: _aot_compile(fn, opt_level, *args), extra=hlo_extra)
        return compiled
    # no cache: legacy per-level compilation (O3 stays a lazy jit, compiled
    # at the first warmup call), so the serial path's behavior is unchanged
    return compile_at_level(fn, opt_level, *args)


def chain_cache_key(spec: OpSpec, n: int, opt_level: str,
                    env: Mapping[str, str]) -> tuple:
    """The CompileCache key one chain compile is stored under — shared with
    ``repro.audit`` so the auditor can peek the optimized-HLO ``extra`` a
    measurement run rode into the cache instead of recompiling."""
    from repro.core.compile_cache import fidelity_key

    return fidelity_key(env, spec.name, opt_level, spec.dtype,
                        f"chain{n}" + (".x64" if _needs_x64(spec) else ""))


def _aot_compile(fn: Callable, opt_level: str, *args: Any) -> Callable:
    if opt_level == "O1":
        return compile_at_level(fn, "O1", *args)  # AOT with reduced options
    return jax.jit(fn).lower(*args).compile()


@dataclasses.dataclass
class PreparedOp:
    """Everything :func:`run_prepared_op` needs; produced off the timing
    thread by :func:`prepare_op`."""

    spec: OpSpec
    opt_level: str
    lens: tuple[int, int]
    retry_lens: tuple[int, int]
    reps: int
    carry: Any
    operands: tuple
    _fns: dict[int, Callable]
    _cache: Any = None
    _env: Mapping[str, str] | None = None

    def fn_by_len(self, n: int) -> Callable:
        """Memoized chain callable; the widened retry length compiles lazily."""
        if n not in self._fns:
            with _x64_ctx(self.spec):
                self._fns[n] = compile_chain(self.spec, n, self.opt_level,
                                             self.carry, *self.operands,
                                             cache=self._cache, env=self._env)
        return self._fns[n]


def prepare_op(spec: OpSpec, opt_level: str = "O3", cache: Any = None,
               env: Mapping[str, str] | None = None) -> PreparedOp:
    """Compile (or cache-load) the two chain callables for ``spec``; no
    device timing happens here, so it is safe to run on the compile-ahead
    thread while another probe times."""
    n1, n2 = _CHAIN_LENS[opt_level]
    if spec.max_chain is not None:
        n1, n2 = min(n1, spec.max_chain // 3), min(n2, spec.max_chain)
    with _x64_ctx(spec):
        carry = spec.carry()
        operands = spec.operand_arrays()
    prepared = PreparedOp(spec=spec, opt_level=opt_level, lens=(n1, n2),
                          retry_lens=retry_lens_for(spec, n1, n2),
                          reps=_REPS[opt_level], carry=carry,
                          operands=operands, _fns={}, _cache=cache, _env=env)
    prepared.fn_by_len(n1)
    prepared.fn_by_len(n2)
    return prepared


def run_prepared_op(prepared: PreparedOp, timer: Timer | None = None
                    ) -> Measurement:
    """Time a :class:`PreparedOp`: the device-serial half of the split."""
    timer = timer or Timer()
    with _x64_ctx(prepared.spec):
        return timer.slope(prepared.fn_by_len, *prepared.lens,
                           prepared.carry, *prepared.operands,
                           reps=prepared.reps,
                           retry_lens=prepared.retry_lens)


def measure_op_full(spec: OpSpec, opt_level: str = "O3",
                    timer: Timer | None = None) -> Measurement:
    """Per-op latency at the given optimization level, with dispersion.

    Returns the full :class:`Measurement` (median + MAD + min) so callers can
    propagate the dispersion into :class:`LatencyRecord.mad_ns` instead of
    dropping it. Equivalent to ``run_prepared_op(prepare_op(...))`` — the
    serial form of the pipelined split.
    """
    return run_prepared_op(prepare_op(spec, opt_level), timer)


def measure_op(spec: OpSpec, opt_level: str = "O3", timer: Timer | None = None) -> float:
    """Median per-op latency in ns at the given optimization level."""
    return max(measure_op_full(spec, opt_level, timer).median_ns, 0.0)


def run_suite(registry: Sequence[OpSpec] | None = None,
              opt_levels: Sequence[str] = OPT_LEVELS,
              db: LatencyDB | None = None,
              timer: Timer | None = None,
              categories: Sequence[str] | None = None) -> LatencyDB:
    """Deprecated shim: measure every op at every level into the LatencyDB.

    Use ``Session(db=...).run(Plan.instructions(...))`` instead — same
    measurements plus caching, resume and structured failures. This shim
    keeps the old always-re-measure semantics (``force=True``).
    """
    warnings.warn(
        "measure.run_suite is deprecated; use "
        "repro.api.Session.run(Plan.instructions(...))",
        DeprecationWarning, stacklevel=2)
    from repro.api import Plan, Session

    session = Session(db=db, timer=timer)
    session.run(Plan.instructions(registry=registry, opt_levels=opt_levels,
                                  categories=categories), force=True)
    return session.db


def clock_overhead(timer: Timer | None = None, opt_levels: Sequence[str] = OPT_LEVELS
                   ) -> dict[str, float]:
    """Deprecated shim (Fig. 5 analog): timed-region cost per opt level.

    Use ``Session().run(Plan.clock_overhead(...))`` instead.
    """
    warnings.warn(
        "measure.clock_overhead is deprecated; use "
        "repro.api.Session.run(Plan.clock_overhead(...))",
        DeprecationWarning, stacklevel=2)
    from repro.api import Plan, Session

    result = Session(timer=timer).run(Plan.clock_overhead(opt_levels), force=True)
    if result.failed:  # the old implementation raised; stay loud for callers
        f = result.failed[0].failure
        raise RuntimeError(
            f"clock_overhead@{f.opt_level} failed: {f.error_type}: {f.message}")
    return {r.record.opt_level: r.record.latency_ns for r in result.results
            if r.record is not None}
