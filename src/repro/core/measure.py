"""Suite runner: sweep the op registry across opt levels into a LatencyDB.

This is the main entry point of the paper's tool (Section IV): for every
instruction in the registry, build the dependent chain, compile it at each
optimization level, and extract the per-op latency with the slope method.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Sequence

import jax

from repro.core import chains
from repro.core.chains import OpSpec, chain_fn
from repro.core.latency_db import LatencyDB, LatencyRecord, current_environment
from repro.core.optlevels import OPT_LEVELS, compile_at_level
from repro.core.timing import Timer
from repro.utils import logger, timestamp

# Chain lengths per opt level: eager dispatch is ~1e4x slower per op, so O0
# uses short chains (the paper's -O0 numbers are likewise dominated by
# unoptimized issue overhead). Long O3 chains push the per-op signal well
# above host-timer noise; the slope uses min-statistics (noise floor).
_CHAIN_LENS = {"O0": (2, 10), "O1": (64, 512), "O3": (64, 512)}
_REPS = {"O0": 5, "O1": 30, "O3": 30}


def _needs_x64(spec: OpSpec) -> bool:
    return spec.requires_x64 or spec.dtype in ("int64", "uint64", "float64")


def _x64_ctx(spec: OpSpec):
    if _needs_x64(spec):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def measure_op(spec: OpSpec, opt_level: str = "O3", timer: Timer | None = None) -> float:
    """Median per-op latency in ns at the given optimization level."""
    timer = timer or Timer()
    n1, n2 = _CHAIN_LENS[opt_level]
    if spec.max_chain is not None:
        n1, n2 = min(n1, spec.max_chain // 3), min(n2, spec.max_chain)
    reps = _REPS[opt_level]
    with _x64_ctx(spec):
        carry = spec.carry()
        operands = spec.operand_arrays()

        def fn_by_len(n: int) -> Callable:
            return compile_at_level(chain_fn(spec, n), opt_level, carry, *operands)

        m = timer.slope(fn_by_len, n1, n2, carry, *operands, reps=reps)
    return max(m.median_ns, 0.0)


def run_suite(registry: Sequence[OpSpec] | None = None,
              opt_levels: Sequence[str] = OPT_LEVELS,
              db: LatencyDB | None = None,
              timer: Timer | None = None,
              categories: Sequence[str] | None = None) -> LatencyDB:
    """Measure every op at every level; returns/extends the LatencyDB."""
    registry = list(registry if registry is not None else chains.default_registry())
    if categories:
        registry = [o for o in registry if o.category in categories]
    db = db or LatencyDB()
    timer = timer or Timer()
    env = current_environment()
    clock = timer.calibrate_clock_hz()

    # Per-level 1-cycle-class baseline, used to net out guard ops. The add
    # spec is itself an (add ^ xor) pair (collapse-proof), and both halves are
    # in the same latency class, so baseline = measured_pair / 2.
    base = next((o for o in chains.default_registry() if o.name == "add"), None)
    add_ns = {lv: (measure_op(base, lv, timer) / (1 + base.guard) if base else 0.0)
              for lv in opt_levels}

    for spec in registry:
        for lv in opt_levels:
            try:
                ns = measure_op(spec, lv, timer)
            except Exception as e:  # noqa: BLE001 - record and continue the sweep
                logger.warning("measure %s@%s failed: %s", spec.name, lv, e)
                continue
            net = max(ns - spec.guard * add_ns.get(lv, 0.0), 0.0)
            db.add(LatencyRecord(
                op=spec.name, category=spec.category, dtype=spec.dtype, opt_level=lv,
                latency_ns=ns, mad_ns=0.0, cycles=ns * clock / 1e9, guard=spec.guard,
                net_latency_ns=net, n_samples=_REPS[lv], measured_at=timestamp(),
                notes=spec.notes, **env))
        logger.info("measured %-22s %s", spec.name,
                    " ".join(f"{lv}={db.lookup_ns(spec.name, lv, float('nan'), dtype=spec.dtype):8.1f}ns"
                             for lv in opt_levels))
    return db


def clock_overhead(timer: Timer | None = None, opt_levels: Sequence[str] = OPT_LEVELS
                   ) -> dict[str, float]:
    """Fig. 5 analog: the cost of the measurement region itself, per level."""
    timer = timer or Timer()
    import jax.numpy as jnp
    x = jnp.asarray(1.0, jnp.float32)
    out = {}
    for lv in opt_levels:
        fn = compile_at_level(lambda v: v, lv, x)
        out[lv] = timer.time_callable(fn, x, reps=_REPS[lv]).median_ns
    return out
