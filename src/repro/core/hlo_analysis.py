"""Static analysis of compiled HLO text: the framework's "instruction counter".

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body exactly
once (verified empirically), so any scan-based model (layer stacks, blockwise
attention, SSM scans) is undercounted by the trip count. XLA's optimized HLO
carries ``backend_config={"known_trip_count":{"n":...}}`` on while ops, so we
parse the module into its computation call graph and roll costs up with trip
multipliers — a *corrected* whole-program {FLOPs, bytes, collective-wire
bytes}. This mirrors how the paper's latency tables are meant to be consumed
(static instruction counts priced per-op; PPT-GPU-style), and it is what the
§Roofline terms are computed from.

Accounting conventions (matches XLA's):
  * dot FLOPs = 2 x prod(result dims) x prod(contracting dims);
  * elementwise/reduce FLOPs = result elements (transcendentals weighted by
    the LatencyDB in perfmodel.HloLatencyEstimator, not here);
  * bytes are counted at computation-op granularity (fusion internals are
    VMEM-resident and free; the fusion's operands + result are HBM traffic);
  * collective wire bytes use ring-algorithm factors over the result bytes;
  * while body costs x known_trip_count; conditional branches take max.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Iterable

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")

# opcodes that do no math worth counting
_ZERO_FLOP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "copy",
    "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "iota", "convert", "reverse",
    "gather", "scatter", "select", "compare", "and", "or", "not", "xor",
    "after-all", "custom-call", "rng", "rng-bit-generator", "copy-start",
    "copy-done", "partition-id", "replica-id", "reduce-precision", "domain",
    "get-dimension-size", "optimization-barrier", "send", "recv", "send-done",
    "recv-done", "infeed", "outfeed",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# Result types may be tuples containing /*index=N*/ comments; opcodes are the
# first lowercase word followed by '(' after the type (layout tiles are 'T(').
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.+\s*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def ring_factor(kind: str, group: int) -> float:
    """Ring-algorithm wire bytes per result byte for one collective kind.

    Shared convention between this parser, the measured collective ladder
    (``repro.parallel.ladders``) and the estimator's pricing ratio — all
    three must agree on what one "wire byte" means.
    """
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group
    if kind == "all-gather":
        return (group - 1) / group
    if kind == "reduce-scatter":
        return float(group - 1)
    if kind == "all-to-all":
        return (group - 1) / group
    if kind == "collective-permute":
        return 1.0
    raise ValueError(kind)


_ring_factor = ring_factor

# measured-ladder row kind (``coll.<kind>.*``) <-> HLO collective opcode kind;
# the jax primitives each ladder kind lowers to are what the names say
# (lax.psum -> all-reduce, lax.psum_scatter -> reduce-scatter, ...)
LADDER_TO_COLLECTIVE = {
    "psum": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "ppermute": "collective-permute",
}
COLLECTIVE_TO_LADDER = {v: k for k, v in LADDER_TO_COLLECTIVE.items()}


def _shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) of an HLO type string (tuples summed)."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class OpLine:
    name: str
    result_type: str
    opcode: str
    rest: str                 # text after the opening paren of operands
    operands: list[str]
    is_root: bool = False     # the computation's ROOT-marked op


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpLine] = dataclasses.field(default_factory=list)
    shapes: dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    wire_bytes: float
    executions: float = 1.0   # trip-count multiplier
    line: str = ""


@dataclasses.dataclass
class StaticCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    collectives: list[CollectiveOp] = dataclasses.field(default_factory=list)

    def __add__(self, o: "StaticCost") -> "StaticCost":
        return StaticCost(self.flops + o.flops, self.bytes + o.bytes,
                          self.wire_bytes + o.wire_bytes,
                          self.collectives + o.collectives)

    def scaled(self, k: float) -> "StaticCost":
        return StaticCost(self.flops * k, self.bytes * k, self.wire_bytes * k,
                          [dataclasses.replace(c, executions=c.executions * k)
                           for c in self.collectives])


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        close = rest.find(")")
        seg = rest if close < 0 else rest[:close]
        operands = re.findall(r"%([\w.\-]+)", seg)
        op = OpLine(name=name, result_type=rtype, opcode=opcode, rest=rest,
                    operands=operands,
                    is_root=line.lstrip().startswith("ROOT "))
        cur.ops.append(op)
        cur.shapes[name] = rtype
    return comps


def _group_size(rest: str, num_partitions: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    if "source_target_pairs" in rest:
        return 2
    return num_partitions


class ModuleCost:
    """Roll program cost up the computation call graph with trip counts."""

    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        mp = re.search(r"num_partitions=(\d+)", hlo_text)
        self.num_partitions = int(mp.group(1)) if mp else 1
        self._memo: dict[tuple[str, bool], StaticCost] = {}
        self._exec_memo: tuple[dict[str, float], set[str]] | None = None

    # ------------------------------------------------------------------ ops
    def _dot_flops(self, comp: Computation, op: OpLine) -> float:
        rdims = _dims_of(op.result_type)
        contracting = 1
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        if mc and op.operands:
            lhs_shape = comp.shapes.get(op.operands[0])
            if lhs_shape:
                ldims = _dims_of(lhs_shape)
                for i in (int(x) for x in mc.group(1).split(",") if x):
                    if i < len(ldims):
                        contracting *= ldims[i]
        return 2.0 * float(np.prod(rdims, dtype=np.float64) if rdims else 1.0) * contracting

    def _conv_flops(self, comp: Computation, op: OpLine) -> float:
        rdims = _dims_of(op.result_type)
        out = float(np.prod(rdims, dtype=np.float64)) if rdims else 1.0
        if len(op.operands) > 1:
            kshape = comp.shapes.get(op.operands[1])
            if kshape:
                kd = _dims_of(kshape)
                # flops = 2 * out_elems * kernel_spatial*in_features (rough)
                if len(kd) >= 2:
                    return 2.0 * out * float(np.prod(kd[:-1], dtype=np.float64))
        return 2.0 * out

    def _op_cost(self, comp: Computation, op: OpLine, in_fusion: bool) -> StaticCost:
        c = StaticCost()
        elems, rbytes = _shape_info(op.result_type)
        kind = next((k for k in COLLECTIVE_KINDS
                     if op.opcode == k or op.opcode.startswith(k + "-start")), None)
        if kind and not op.opcode.endswith("-done"):
            group = _group_size(op.rest, self.num_partitions)
            wire = _ring_factor(kind, group) * rbytes
            c.wire_bytes += wire
            c.collectives.append(CollectiveOp(kind=kind, result_bytes=rbytes,
                                              group_size=group, wire_bytes=wire))
            if kind in ("all-reduce", "reduce-scatter"):
                c.flops += elems
            if not in_fusion:
                c.bytes += rbytes * 2
            return c

        if op.opcode == "dot":
            c.flops += self._dot_flops(comp, op)
        elif op.opcode == "convolution":
            c.flops += self._conv_flops(comp, op)
        elif op.opcode in ("reduce", "reduce-window"):
            if op.operands:
                oshape = comp.shapes.get(op.operands[0])
                c.flops += _shape_info(oshape)[0] if oshape else elems
        elif op.opcode in ("fusion", "while", "call", "conditional", "map",
                           "sort", "scatter", "gather"):
            pass  # handled via call graph / zero-flop
        elif op.opcode not in _ZERO_FLOP:
            c.flops += elems  # elementwise & transcendental: 1/elem

        if not in_fusion and op.opcode not in ("parameter", "constant", "tuple",
                                               "get-tuple-element", "bitcast"):
            if op.opcode == "fusion":
                c.bytes += self._fusion_bytes(comp, op, rbytes)
            elif op.opcode == "dynamic-update-slice":
                # in-place on TPU: read the update + write the slice, not the
                # whole buffer (XLA's own bytes-accessed overcounts this).
                ub = (_shape_info(comp.shapes.get(op.operands[1], ""))[1]
                      if len(op.operands) > 1 else rbytes)
                c.bytes += 2 * ub
            elif op.opcode == "dynamic-slice":
                c.bytes += 2 * rbytes
            else:
                # HBM traffic at top level: operands + result
                ob = sum(_shape_info(comp.shapes.get(o, ""))[1] for o in op.operands)
                c.bytes += ob + rbytes
        return c

    def _fusion_bytes(self, comp: Computation, op: OpLine, rbytes: int) -> float:
        """Fusion HBM traffic = result + operands, EXCEPT operands that are
        consumed inside the fusion only through a dynamic-slice (XLA fuses the
        slice; the hardware streams the slice, not the whole buffer — without
        this, a scanned layer stack counts its full stacked weights once per
        iteration: ~100x phantom traffic, observed on llama3-405b)."""
        total = float(rbytes)
        callee_name = None
        m = re.search(r"calls=%?([\w.\-]+)", op.rest)
        if m:
            callee_name = m.group(1)
        callee = self.comps.get(callee_name) if callee_name else None
        params: dict[int, str] = {}
        uses: dict[str, list[OpLine]] = {}
        if callee is not None:
            for cop in callee.ops:
                if cop.opcode == "parameter":
                    mi = re.match(r"\s*(\d+)", cop.rest)
                    if mi:
                        params[int(mi.group(1))] = cop.name
                for o in cop.operands:
                    uses.setdefault(o, []).append(cop)
        for i, oname in enumerate(op.operands):
            full = _shape_info(comp.shapes.get(oname, ""))[1]
            pname = params.get(i)
            consumers = uses.get(pname, []) if pname else []
            if consumers and all(c.opcode == "dynamic-slice" for c in consumers):
                sliced = sum(_shape_info(c.result_type)[1] for c in consumers)
                total += min(sliced, full)
            else:
                total += full
        return total

    # ------------------------------------------------------------- rollup
    def _called(self, op: OpLine) -> list[tuple[str, float, str]]:
        """(computation, multiplier, kind) called by this op."""
        out = []
        if op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.rest)
            if m:
                out.append((m.group(1), 1.0, "fusion"))
        elif op.opcode == "while":
            m = re.search(r"body=%?([\w.\-]+)", op.rest)
            trip = 1.0
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trip = float(mt.group(1))
            if m:
                out.append((m.group(1), trip, "while"))
        elif op.opcode in ("call", "async-start"):
            m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.rest)
            if m:
                out.append((m.group(1), 1.0, "call"))
        elif op.opcode == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w.\-]+))", op.rest):
                names = (m.group(1) or m.group(2) or "")
                for n in re.findall(r"%?([\w.\-]+)", names):
                    out.append((n, 1.0, "cond"))
        return out

    def comp_cost(self, name: str, in_fusion: bool = False) -> StaticCost:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = StaticCost()
        if comp is None:
            return total
        self._memo[key] = total  # guard cycles
        cond_costs: list[StaticCost] = []
        for op in comp.ops:
            total += self._op_cost(comp, op, in_fusion)
            for callee, mult, kind in self._called(op):
                child_in_fusion = in_fusion or kind == "fusion"
                child = self.comp_cost(callee, child_in_fusion)
                if kind == "cond":
                    cond_costs.append(child.scaled(mult))
                else:
                    total += child.scaled(mult)
        if cond_costs:
            best = max(cond_costs, key=lambda c: c.flops + c.bytes)
            total += best
        self._memo[key] = total
        return total

    def total(self) -> StaticCost:
        return self.comp_cost("__entry__")

    # -------------------------------------------------------------- insight
    def _execution_counts(self) -> tuple[dict[str, float], set[str]]:
        """Dynamic executions per computation, rolled down the call graph.

        Returns ``(execs, fused)``: how many times each computation runs per
        entry invocation (while bodies x ``known_trip_count``), and which
        computations only ever run inside a fusion (their HBM bytes are
        free). Fixpoint passes; call graphs here are shallow. Memoized: an
        estimator call walks the graph for the histogram, the flops profile
        and the byte rollup, and the counts never change.
        """
        if self._exec_memo is not None:
            return self._exec_memo
        execs: dict[str, float] = {"__entry__": 1.0}
        fused: set[str] = set()
        for _ in range(8):
            changed = False
            for name, comp in self.comps.items():
                e = execs.get(name, 0.0)
                if not e:
                    continue
                for op in comp.ops:
                    for callee, mult, kind in self._called(op):
                        val = e * mult
                        if kind == "fusion" or name in fused:
                            if callee not in fused:
                                fused.add(callee)
                                changed = True
                        if execs.get(callee, 0.0) < val:
                            execs[callee] = val
                            changed = True
            if not changed:
                break
        self._exec_memo = (execs, fused)
        return self._exec_memo

    def _walk_dynamic(self):
        """Yield ``(comp, op, executions, in_fusion)`` for every op line,
        weighted by the call-graph execution counts (entry aliases skipped)."""
        execs, fused = self._execution_counts()
        entry = self.comps.get("__entry__")
        for name, comp in self.comps.items():
            if comp is entry and name != "__entry__":
                continue
            e = 1.0 if name == "__entry__" else execs.get(name, 0.0)
            if not e:
                continue
            in_fusion = name in fused
            for op in comp.ops:
                yield comp, op, e, in_fusion

    def breakdown(self, top: int = 12) -> dict[str, list]:
        """Where do the bytes/flops go? Executions-weighted per-op-kind and
        per-computation ranking — the §Perf iteration's 'profile'."""
        by_kind_bytes: dict[str, float] = {}
        by_kind_flops: dict[str, float] = {}
        for comp, op, e, in_fusion in self._walk_dynamic():
            c = self._op_cost(comp, op, in_fusion=in_fusion)
            by_kind_bytes[op.opcode] = by_kind_bytes.get(op.opcode, 0.0) + c.bytes * e
            by_kind_flops[op.opcode] = by_kind_flops.get(op.opcode, 0.0) + c.flops * e
        rank_b = sorted(by_kind_bytes.items(), key=lambda kv: -kv[1])[:top]
        rank_f = sorted(by_kind_flops.items(), key=lambda kv: -kv[1])[:top]
        return {"bytes_by_opcode": rank_b, "flops_by_opcode": rank_f}

    def dynamic_histogram(self) -> dict[tuple[str, int], float]:
        """Dynamic op counts: ``{(opcode, result elements): executions}``.

        The trip-count-aware analog of :func:`op_histogram` — an op inside a
        while body with ``known_trip_count n`` counts ``n`` times, nested
        loops multiply. This is what makes decode-step pricing see every
        layer of a scanned stack instead of one (the flat histogram's
        underpricing bug).
        """
        hist: dict[tuple[str, int], float] = {}
        for _, op, e, _ in self._walk_dynamic():
            key = (op.opcode, _shape_info(op.result_type)[0])
            hist[key] = hist.get(key, 0.0) + e
        return hist

    def dynamic_flops(self) -> dict[str, float]:
        """Executions-weighted FLOPs per opcode (dot FLOPs use contracting
        dims, matching :meth:`total`); feeds matmul pricing in perfmodel."""
        out: dict[str, float] = {}
        for comp, op, e, in_fusion in self._walk_dynamic():
            f = self._op_cost(comp, op, in_fusion=in_fusion).flops
            if f:
                out[op.opcode] = out.get(op.opcode, 0.0) + f * e
        return out

    def dynamic_custom_calls(self) -> list[tuple[str, float, float, str]]:
        """Every custom-call line, executions-weighted.

        Returns ``(target, hbm_bytes, executions, rest)`` per call site:
        the ``custom_call_target`` string, the call's operand+result bytes
        (its HBM footprint — the quantity the fused-row pricing scales by),
        the trip-count-weighted execution count, and the raw op tail so
        :func:`resolve_custom_call` can scan lowering payloads (Mosaic
        embeds the kernel name in the ``tpu_custom_call`` config, not the
        target)."""
        out = []
        for comp, op, e, _ in self._walk_dynamic():
            if op.opcode != "custom-call":
                continue
            m = _CC_TARGET_RE.search(op.rest)
            target = m.group(1) if m else ""
            _, rbytes = _shape_info(op.result_type)
            ob = sum(_shape_info(comp.shapes.get(o, ""))[1]
                     for o in op.operands)
            out.append((target, float(ob + rbytes), e, op.rest))
        return out


def static_cost(hlo_text: str) -> StaticCost:
    return ModuleCost(hlo_text).total()


# -------------------------------------------------------- simple interfaces
def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    return static_cost(hlo_text).collectives


def collective_wire_bytes(hlo_text: str) -> float:
    return static_cost(hlo_text).wire_bytes


def collective_summary(hlo_text: str) -> dict[str, dict[str, float]]:
    summ: dict[str, dict[str, float]] = {}
    for c in parse_collectives(hlo_text):
        d = summ.setdefault(c.kind, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += c.executions
        d["result_bytes"] += c.result_bytes * c.executions
        d["wire_bytes"] += c.wire_bytes * c.executions
    return summ


# ---------------------------------------------------------------- histogram
HLO_TO_TABLE = {
    "add": "add.float32", "subtract": "sub.float32", "multiply": "mul.float32",
    "divide": "div.runtime.float32", "maximum": "max.float32", "minimum": "min.float32",
    "exponential": "ex2", "exponential-minus-one": "ex2", "log": "lg2",
    "log-plus-one": "lg2", "tanh": "tanh", "rsqrt": "rsqrt",
    "sqrt": "sqrt", "sine": "sin", "cosine": "cos", "abs": "abs", "negate": "sub",
    "and": "and", "or": "or", "xor": "xor", "not": "not",
    "shift-left": "shl", "shift-right-logical": "shr", "shift-right-arithmetic": "shr",
    "popcnt": "popc", "count-leading-zeros": "clz", "remainder": "rem.s",
    "power": "ex2", "logistic": "tanh",
}

# Custom-call targets the characterization pipeline has measured rows for:
# target (or lowering-payload substring) -> fused-kernel name, i.e. the stem
# of an ``inkernel.fused.<name>`` LatencyDB row whose two-size slope priced
# one workload unit of that kernel (see repro.inkernel.fused.FUSED_KERNELS
# and repro.audit.dataflow.fused_registry — the dataflow certificates carry
# each row's unit-bytes denominator). The estimator prices a resolved call
# as ``executions * call_bytes / unit_bytes * row_ns``; unresolved targets
# stay unpriced and count against coverage (HLO_TO_TABLE's veil rule).
CUSTOM_CALL_TARGETS = {
    "flash_attention": "flash_attention",
    "flash_decode": "flash_decode",
    "mamba_scan": "mamba_scan",
    "rmsnorm": "rmsnorm",
}

_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def resolve_custom_call(target: str, rest: str = "") -> str | None:
    """Map one custom-call site to a measured fused-kernel row stem.

    Exact target match first (GPU lowerings name the kernel directly), then
    a registered-name substring scan over ``target`` + ``rest`` — TPU Pallas
    kernels all share the ``tpu_custom_call`` target and carry the kernel
    name only inside the serialized Mosaic config. Returns ``None`` for
    unknown targets: those must surface as ``custom-call:<target>`` in
    ``PricedReport.unpriced_opcodes``, never silently default-priced as a
    generic opcode."""
    if target in CUSTOM_CALL_TARGETS:
        return CUSTOM_CALL_TARGETS[target]
    hay = target + " " + rest
    for key, name in CUSTOM_CALL_TARGETS.items():
        if key in hay:
            return name
    return None


# Opcodes that are bookkeeping/data-movement, not issued arithmetic: excluded
# from the estimator's coverage denominator (an unmapped `multiply` lowers
# coverage; an unmapped `get-tuple-element` must not). Memory traffic they
# cause is captured by the byte rollup, i.e. the estimator's memory term.
# `custom-call` is deliberately NOT here: it is an opaque library/Pallas
# kernel of unknown — often dominant — cost, so it must count against
# coverage and show up in unpriced_opcodes rather than vanish.
STRUCTURAL_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "copy",
    "copy-start", "copy-done", "reshape", "transpose", "broadcast", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "iota",
    "reverse", "after-all", "domain", "get-dimension-size",
    "optimization-barrier", "send", "recv", "send-done", "recv-done",
    "infeed", "outfeed", "partition-id", "replica-id", "fusion", "while",
    "call", "conditional", "map", "async-start", "async-done",
    "async-update", "rng-get-and-update-state",
})


def dynamic_op_histogram(hlo_text: str) -> Counter:
    """Trip-count-aware counts of (opcode, result elements).

    Unlike :func:`op_histogram` (flat: every op line counts once), ops inside
    ``while`` bodies are weighted by ``known_trip_count`` — the dynamic
    instruction counts a PPT-GPU-style consumer needs. Counts are floats
    (conditional branches and unrooted computations may contribute 0).
    """
    hist: Counter = Counter()
    for key, e in ModuleCost(hlo_text).dynamic_histogram().items():
        hist[key] += e
    return hist


def op_histogram(hlo_text: str) -> Counter:
    """Counts of (opcode, result elements) over every computation (no rollup)."""
    hist: Counter = Counter()
    comps = parse_module(hlo_text)
    seen: set[int] = set()
    for comp in comps.values():
        if id(comp) in seen:
            continue
        seen.add(id(comp))
        for op in comp.ops:
            elems, _ = _shape_info(op.result_type)
            hist[(op.opcode, elems)] += 1
    return hist


def flop_ops(hlo_text: str) -> dict[str, int]:
    out: dict[str, int] = {}
    for (opcode, n), count in op_histogram(hlo_text).items():
        out[opcode] = out.get(opcode, 0) + n * count
    return out
