"""Analytical performance model fed by the characterization results.

This is the paper's *raison d'être* (Section I: accurate per-instruction
latencies make performance models like PPT-GPU accurate). Two models:

* :class:`Roofline` — the three-term roofline mandated by the assignment,
  computed per (arch × shape × mesh) from the compiled dry-run artifact:
  ``cost_analysis()`` (per-device FLOPs / bytes — verified per-device in
  probes) plus HLO-parsed collective traffic.
* :class:`HloLatencyEstimator` — prices a lowered HLO module with *measured*
  per-op latencies from the LatencyDB: the simulator-feeding use case.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import hlo_analysis
from repro.core.latency_db import LatencyDB
from repro.utils import human_bytes, human_flops


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip
    clock_hz: float = 0.0

    @property
    def arithmetic_intensity_knee(self) -> float:
        return self.peak_flops / self.hbm_bw


# Mandated target constants (assignment §Roofline).
TPU_V5E = HardwareSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9, hbm_bytes=16 * 2**30, clock_hz=1.7e9)
# For completeness / cross-checks when running measured benches on this host.
CPU_HOST = HardwareSpec(name="cpu-host", peak_flops=1e11, hbm_bw=2e10,
                        ici_bw=1e10, hbm_bytes=64 * 2**30, clock_hz=3e9)


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    collective_wire_bytes_per_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float           # 6ND (train) / 2ND (decode), active params
    useful_ratio: float          # model_flops / (flops_per_dev * chips)
    peak_memory_per_dev: float
    roofline_fraction: float     # T_dominant==T_compute ? t_comp/t_total : see note
    collectives: dict[str, dict[str, float]]
    notes: str = ""

    def bound_summary(self) -> str:
        return (f"{self.arch}/{self.shape}@{self.mesh}: comp={self.t_compute*1e3:.2f}ms "
                f"mem={self.t_memory*1e3:.2f}ms coll={self.t_collective*1e3:.2f}ms "
                f"-> {self.dominant}-bound, useful={self.useful_ratio:.2%}, "
                f"roofline={self.roofline_fraction:.2%}")


def _summary(collectives) -> dict[str, dict[str, float]]:
    summ: dict[str, dict[str, float]] = {}
    for c in collectives:
        d = summ.setdefault(c.kind, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += c.executions
        d["result_bytes"] += c.result_bytes * c.executions
        d["wire_bytes"] += c.wire_bytes * c.executions
    return summ


class Roofline:
    def __init__(self, hw: HardwareSpec = TPU_V5E):
        self.hw = hw

    def analyze(self, *, arch: str, shape: str, mesh: str, chips: int,
                cost: dict[str, Any], hlo_text: str, model_flops: float,
                peak_memory_per_dev: float = 0.0, notes: str = "") -> RooflineReport:
        # Corrected static costs: cost_analysis() counts while bodies once
        # (verified), so scan-based programs need the trip-count rollup of
        # hlo_analysis.ModuleCost. Take max with XLA's own numbers so a parse
        # miss can only under-correct, never under-report.
        st = hlo_analysis.static_cost(hlo_text)
        flops_dev = max(float(cost.get("flops", 0.0)), st.flops)
        # bytes: prefer the static rollup — XLA's bytes-accessed both
        # undercounts loops (body x1) and overcounts in-place dynamic-update-
        # slice; the static conventions are cross-checked in tests. Fall back
        # to XLA's number when no HLO text is supplied.
        bytes_dev = st.bytes if st.bytes > 0 else float(cost.get("bytes accessed", 0.0))
        wire_dev = st.wire_bytes
        t_comp = flops_dev / self.hw.peak_flops
        t_mem = bytes_dev / self.hw.hbm_bw
        t_coll = wire_dev / self.hw.ici_bw
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
        total_flops = flops_dev * max(chips, 1)
        useful = model_flops / total_flops if total_flops else 0.0
        # Roofline fraction: the fraction of the step's lower-bound time spent
        # on *useful model math at peak*: (model_flops/chips/peak) / max-term.
        t_ideal = (model_flops / max(chips, 1)) / self.hw.peak_flops
        frac = t_ideal / max(max(terms.values()), 1e-30)
        return RooflineReport(
            arch=arch, shape=shape, mesh=mesh, chips=chips,
            flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
            collective_wire_bytes_per_dev=wire_dev,
            t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
            dominant=dominant, model_flops=model_flops, useful_ratio=useful,
            peak_memory_per_dev=peak_memory_per_dev,
            roofline_fraction=min(frac, 1.0),
            collectives=_summary(st.collectives), notes=notes)

    @staticmethod
    def markdown_row(r: RooflineReport) -> list[str]:
        return [r.arch, r.shape, r.mesh, str(r.chips),
                human_flops(r.flops_per_dev), human_bytes(r.bytes_per_dev),
                human_bytes(r.collective_wire_bytes_per_dev),
                f"{r.t_compute*1e3:.3f}", f"{r.t_memory*1e3:.3f}",
                f"{r.t_collective*1e3:.3f}", r.dominant,
                f"{r.useful_ratio:.2%}", f"{r.roofline_fraction:.2%}",
                human_bytes(r.peak_memory_per_dev)]

    MD_HEADERS = ["arch", "shape", "mesh", "chips", "flops/dev", "bytes/dev",
                  "coll-wire/dev", "T_comp(ms)", "T_mem(ms)", "T_coll(ms)",
                  "bound", "useful", "roofline", "peak-mem/dev"]


class HloLatencyEstimator:
    """Price a lowered HLO module from measured per-op latencies.

    Serial-issue lower bound: Σ over op instances of table latency; elementwise
    ops additionally amortize over vector width via a measured throughput
    factor. This intentionally mirrors how PPT-GPU consumes the paper's tables
    (latency per instruction × dynamic count).
    """

    def __init__(self, db: LatencyDB, opt_level: str = "O3",
                 lanes: int = 8, default_ns: float = 5.0):
        self.db = db
        self.opt_level = opt_level
        self.lanes = lanes
        self.default_ns = default_ns

    def estimate_ns(self, hlo_text: str) -> float:
        total = 0.0
        for (opcode, n), count in hlo_analysis.op_histogram(hlo_text).items():
            table_op = hlo_analysis.HLO_TO_TABLE.get(opcode)
            if table_op is None:
                continue
            lat = self.db.lookup_ns(table_op, self.opt_level)
            if lat is None:
                base = table_op.split(".")[0]
                lat = self.db.lookup_ns(base, self.opt_level, self.default_ns)
            # one issue latency + per-element throughput amortized over lanes
            total += count * (lat + (max(n - 1, 0) / self.lanes) * 0.25 * lat)
        return total
