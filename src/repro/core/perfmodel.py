"""Analytical performance model fed by the characterization results.

This is the paper's *raison d'être* (Section I: accurate per-instruction
latencies make performance models like PPT-GPU accurate). Two models:

* :class:`Roofline` — the three-term roofline mandated by the assignment,
  computed per (arch × shape × mesh) from the compiled dry-run artifact:
  ``cost_analysis()`` (per-device FLOPs / bytes — verified per-device in
  probes) plus HLO-parsed collective traffic.
* :class:`HloLatencyEstimator` — prices a lowered HLO module with *measured*
  per-op latencies from the LatencyDB: the simulator-feeding use case.
  Dynamic (trip-count-rolled) instruction counts, a two-term
  ``max(compute, memory)`` estimate whose memory term comes from the measured
  pointer-chase ladder, and a :class:`PricedReport` diagnosis with an
  explicit coverage fraction. :class:`ServingPoint` parses the
  ``serving.<phase>.<cell>`` rows the ``repro.api.ServingCostProbe`` writes
  (predicted-vs-measured, docs/serving.md).
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any

from repro.core import hlo_analysis
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.utils import human_bytes, human_flops, parse_kv_notes


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip
    clock_hz: float = 0.0

    @property
    def arithmetic_intensity_knee(self) -> float:
        return self.peak_flops / self.hbm_bw


# Mandated target constants (assignment §Roofline).
TPU_V5E = HardwareSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9, hbm_bytes=16 * 2**30, clock_hz=1.7e9)
# For completeness / cross-checks when running measured benches on this host.
CPU_HOST = HardwareSpec(name="cpu-host", peak_flops=1e11, hbm_bw=2e10,
                        ici_bw=1e10, hbm_bytes=64 * 2**30, clock_hz=3e9)


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    collective_wire_bytes_per_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float           # 6ND (train) / 2ND (decode), active params
    useful_ratio: float          # model_flops / (flops_per_dev * chips)
    peak_memory_per_dev: float
    roofline_fraction: float     # T_dominant==T_compute ? t_comp/t_total : see note
    collectives: dict[str, dict[str, float]]
    notes: str = ""

    def bound_summary(self) -> str:
        return (f"{self.arch}/{self.shape}@{self.mesh}: comp={self.t_compute*1e3:.2f}ms "
                f"mem={self.t_memory*1e3:.2f}ms coll={self.t_collective*1e3:.2f}ms "
                f"-> {self.dominant}-bound, useful={self.useful_ratio:.2%}, "
                f"roofline={self.roofline_fraction:.2%}")


def _summary(collectives) -> dict[str, dict[str, float]]:
    summ: dict[str, dict[str, float]] = {}
    for c in collectives:
        d = summ.setdefault(c.kind, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += c.executions
        d["result_bytes"] += c.result_bytes * c.executions
        d["wire_bytes"] += c.wire_bytes * c.executions
    return summ


class Roofline:
    def __init__(self, hw: HardwareSpec = TPU_V5E):
        self.hw = hw

    def analyze(self, *, arch: str, shape: str, mesh: str, chips: int,
                cost: dict[str, Any], hlo_text: str, model_flops: float,
                peak_memory_per_dev: float = 0.0, notes: str = "") -> RooflineReport:
        # Corrected static costs: cost_analysis() counts while bodies once
        # (verified), so scan-based programs need the trip-count rollup of
        # hlo_analysis.ModuleCost. Take max with XLA's own numbers so a parse
        # miss can only under-correct, never under-report.
        st = hlo_analysis.static_cost(hlo_text)
        flops_dev = max(float(cost.get("flops", 0.0)), st.flops)
        # bytes: prefer the static rollup — XLA's bytes-accessed both
        # undercounts loops (body x1) and overcounts in-place dynamic-update-
        # slice; the static conventions are cross-checked in tests. Fall back
        # to XLA's number when no HLO text is supplied.
        bytes_dev = st.bytes if st.bytes > 0 else float(cost.get("bytes accessed", 0.0))
        wire_dev = st.wire_bytes
        t_comp = flops_dev / self.hw.peak_flops
        t_mem = bytes_dev / self.hw.hbm_bw
        t_coll = wire_dev / self.hw.ici_bw
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
        total_flops = flops_dev * max(chips, 1)
        useful = model_flops / total_flops if total_flops else 0.0
        # Roofline fraction: the fraction of the step's lower-bound time spent
        # on *useful model math at peak*: (model_flops/chips/peak) / max-term.
        t_ideal = (model_flops / max(chips, 1)) / self.hw.peak_flops
        frac = t_ideal / max(max(terms.values()), 1e-30)
        return RooflineReport(
            arch=arch, shape=shape, mesh=mesh, chips=chips,
            flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
            collective_wire_bytes_per_dev=wire_dev,
            t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
            dominant=dominant, model_flops=model_flops, useful_ratio=useful,
            peak_memory_per_dev=peak_memory_per_dev,
            roofline_fraction=min(frac, 1.0),
            collectives=_summary(st.collectives), notes=notes)

    @staticmethod
    def markdown_row(r: RooflineReport) -> list[str]:
        return [r.arch, r.shape, r.mesh, str(r.chips),
                human_flops(r.flops_per_dev), human_bytes(r.bytes_per_dev),
                human_bytes(r.collective_wire_bytes_per_dev),
                f"{r.t_compute*1e3:.3f}", f"{r.t_memory*1e3:.3f}",
                f"{r.t_collective*1e3:.3f}", r.dominant,
                f"{r.useful_ratio:.2%}", f"{r.roofline_fraction:.2%}",
                human_bytes(r.peak_memory_per_dev)]

    MD_HEADERS = ["arch", "shape", "mesh", "chips", "flops/dev", "bytes/dev",
                  "coll-wire/dev", "T_comp(ms)", "T_mem(ms)", "T_coll(ms)",
                  "bound", "useful", "roofline", "peak-mem/dev"]


@dataclasses.dataclass(frozen=True)
class ClassCost:
    """One op-class row of a :class:`PricedReport` breakdown."""

    ns: float = 0.0
    instances: float = 0.0       # dynamic op instances (trip-count weighted)
    elements: float = 0.0        # dynamic result elements across instances

    def _plus(self, ns: float, instances: float, elements: float) -> "ClassCost":
        return ClassCost(self.ns + ns, self.instances + instances,
                         self.elements + elements)


@dataclasses.dataclass(frozen=True)
class PricedReport:
    """Full diagnosis of one :meth:`HloLatencyEstimator.estimate` call.

    ``total_ns = max(compute_ns, memory_ns) + collective_ns``: the
    serial-issue instruction estimate and the measured-ladder memory estimate
    overlap on hardware, so the slower term bounds the on-chip module
    (two-term roofline over measured rows); the interconnect term — priced
    from the measured collective ladder — is serial with both (a dependent
    collective stalls the shard) and adds on top.
    ``coverage`` is the fraction of countable dynamic op instances priced
    from an actual DB row — instances priced at ``default_ns`` (no mapping,
    or mapping with no measured row) count against it, structural
    data-movement ops (:data:`hlo_analysis.STRUCTURAL_OPS`) count in neither
    direction.
    """

    total_ns: float
    compute_ns: float
    memory_ns: float
    coverage: float
    priced_instances: float
    unpriced_instances: float
    by_class: dict[str, ClassCost]
    unpriced_opcodes: tuple[tuple[str, float], ...]   # (opcode, dyn count)
    bytes_accessed: float
    opt_level: str
    # additive interconnect term from the measured collective ladder
    # (``coll.<kind>.d<N>.<bytes>`` rows); 0.0 for unsharded modules, so the
    # pre-collective report shape is unchanged
    collective_ns: float = 0.0

    @property
    def bound(self) -> str:
        if self.collective_ns > max(self.compute_ns, self.memory_ns):
            return "collective"
        return "compute" if self.compute_ns >= self.memory_ns else "memory"

    def summary(self) -> str:
        miss = ", ".join(f"{op}x{c:g}" for op, c in self.unpriced_opcodes[:4])
        coll = (f" coll={self.collective_ns:.1f}"
                if self.collective_ns else "")
        return (f"{self.total_ns:.1f}ns ({self.bound}-bound: "
                f"comp={self.compute_ns:.1f} mem={self.memory_ns:.1f}"
                f"{coll}), coverage={self.coverage:.1%}"
                + (f", unpriced: {miss}" if miss else ""))


@dataclasses.dataclass(frozen=True)
class MemoryRung:
    """One measured rung of the DB's pointer-chase ladder."""

    working_set_bytes: int
    ns_per_line: float
    line_bytes: int
    source: str                  # "inkernel" | "host"


@dataclasses.dataclass(frozen=True)
class CollectiveRung:
    """One measured rung of the DB's collective ladder, keyed by HLO kind."""

    kind: str                    # HLO opcode kind ("all-reduce", ...)
    devices: int                 # ladder mesh size (== HLO group size target)
    wire_bytes: float            # ring-convention wire bytes one step moved
    ns: float                    # measured slope: ns per chained collective


class _EstimatedNs(float):
    """A float that carries its :class:`PricedReport` (see ``estimate_ns``)."""

    report: PricedReport


_MEM_ROW_RE = re.compile(r"^(?:mem\.chase\.ws|inkernel\.mem\.)(\d+)$")
_COLL_ROW_RE = re.compile(
    r"^coll\.(psum|all_gather|reduce_scatter|ppermute)\.d(\d+)\.(\d+)$")


class HloLatencyEstimator:
    """Price a lowered HLO module from measured per-op latencies.

    The simulator-feeding use case (PPT-GPU-style): dynamic instruction
    counts x measured table latencies. Counts are **trip-count aware**
    (:meth:`hlo_analysis.ModuleCost.dynamic_histogram`): an op inside a
    scanned layer stack counts once per iteration, so decode-step modules are
    no longer underpriced by the layer count. The estimate has two terms:

    * **compute**: Σ over dynamic op instances of ``issue latency +
      (elements-1)/lanes x THROUGHPUT_FACTOR x latency`` — one issue plus
      lane-amortized per-element throughput. ``dot``/``convolution`` price
      their FLOPs/2 as fma-equivalents through the same formula. Opcodes with
      no mapped or measured row are priced at ``default_ns`` and reported in
      ``unpriced_opcodes`` instead of being silently skipped. Custom-calls
      resolving through :data:`hlo_analysis.CUSTOM_CALL_TARGETS` to a
      measured ``inkernel.fused.<name>`` row are priced by HBM footprint
      against the row's certified unit bytes; unresolved targets are
      reported per target as ``custom-call:<target>``.
    * **memory**: the module's rolled-up HBM bytes priced from the measured
      pointer-chase ladder (``inkernel.mem.<N>`` preferred over the host twin
      ``mem.chase.ws<N>``): the rung covering the module's footprint gives
      ns/line, amortized over ``mem_streams`` concurrent streams (a dependent
      chase measures pure latency; streamed traffic overlaps).
    * **collective**: each HLO-parsed
      :class:`~repro.core.hlo_analysis.CollectiveOp` priced from the covering
      measured ladder rung (``coll.<kind>.d<N>.<bytes>`` rows,
      :meth:`collective_ladder`): ``wire_bytes / rung_wire x rung_ns``, per
      kind, env-filtered. A kind with no measured rung is *never*
      default-priced — it counts against coverage as ``collective:<kind>``.

    ``total = max(compute, memory) + collective`` — the on-chip terms overlap
    in hardware; a dependent collective stalls the shard and adds on top.
    """

    THROUGHPUT_FACTOR = 0.25     # per-element cost fraction once issued

    def __init__(self, db: LatencyDB, opt_level: str = "O3",
                 lanes: int = 8, default_ns: float = 5.0,
                 mem_streams: int = 8, filters: dict[str, str] | None = None):
        self.db = db
        self.opt_level = opt_level
        self.lanes = lanes
        self.default_ns = default_ns
        self.mem_streams = mem_streams
        # env filters (device_kind/backend/jax_version): a DB accumulates
        # runs across devices, and pricing one device's module with another
        # device's rows would be meaningless (compare_markdown's rule)
        self.filters = dict(filters) if filters else {}

    # ------------------------------------------------------------- lookups
    def _table_latency(self, table_op: str) -> tuple[float, bool]:
        """(latency ns, was a measured row found). Falls back from the exact
        table row to its base row (``sub.float32`` -> ``sub``) before
        resorting to ``default_ns``."""
        lat = self.db.lookup_ns(table_op, self.opt_level, **self.filters)
        if lat is not None:
            return lat, True
        base = table_op.split(".")[0]
        if base != table_op:
            lat = self.db.lookup_ns(base, self.opt_level, **self.filters)
            if lat is not None:
                return lat, True
        return self.default_ns, False

    def _fused_row(self, name: str) -> tuple[float, float] | None:
        """``(ns_per_unit, unit_bytes)`` of a measured fused-kernel row.

        ``unit_bytes`` — the HBM footprint of one workload unit, certified
        by the dataflow audit — is the scaling denominator: a zoo-model
        custom-call moving ``B`` bytes costs ``B / unit_bytes`` row units.
        Preferred source is the row's own notes (FusedKernelProbe persists
        ``unit_bytes=N``); older rows fall back to re-deriving the
        certificate, and rows with neither are unusable for pricing."""
        recs = self.db.query(op=f"inkernel.fused.{name}",
                             opt_level=self.opt_level, **self.filters)
        if not recs:
            return None
        rec = sorted(recs, key=lambda r: r.measured_at)[-1]
        unit_bytes = float(parse_kv_notes(rec.notes).get("unit_bytes", 0.0)
                           or 0.0)
        if unit_bytes <= 0:
            try:
                from repro.audit.dataflow import fused_unit
                from repro.inkernel.fused import FUSED_LENS

                unit_bytes = float(fused_unit(name, FUSED_LENS)["bytes"])
            except Exception:  # noqa: BLE001 - uncertifiable row: no pricing
                return None
        if unit_bytes <= 0:
            return None
        return rec.latency_ns, unit_bytes

    def memory_ladder(self) -> list[MemoryRung]:
        """Measured chase rungs in the DB, ascending by working set.

        Only unsuffixed rows participate (``inkernel.mem.8192.vmem`` is a
        forced-residency experiment, not the hierarchy); where both the
        in-kernel row and its host twin exist at one working set, the
        in-kernel (device-side) number wins.
        """
        rungs: dict[int, MemoryRung] = {}
        for r in self.db.query(category="memory", **self.filters):
            m = _MEM_ROW_RE.match(r.op)
            if not m or r.opt_level != self.opt_level:
                continue
            ws = int(m.group(1))
            source = "inkernel" if r.op.startswith("inkernel.") else "host"
            if ws in rungs and rungs[ws].source == "inkernel" and source == "host":
                continue
            lm = re.search(r"(?:line|stride)=(\d+)", r.notes)
            line = int(lm.group(1)) if lm else 64
            rungs[ws] = MemoryRung(working_set_bytes=ws,
                                   ns_per_line=r.latency_ns,
                                   line_bytes=line, source=source)
        return sorted(rungs.values(), key=lambda g: g.working_set_bytes)

    def collective_ladder(self) -> dict[str, list[CollectiveRung]]:
        """Measured collective rungs in the DB, grouped by HLO kind and
        ascending by wire bytes.

        Only unsuffixed ``coll.<kind>.d<N>.<bytes>`` rows participate (a
        lens-suffixed row is a different fidelity experiment, exactly like
        the memory ladder's rule). The rung's wire bytes come from the
        probe's own notes (ring-convention, ``repro.parallel.ladders``);
        older rows without the note fall back to re-deriving them from the
        recorded payload, and rows with neither are unusable for pricing.
        """
        rungs: dict[str, list[CollectiveRung]] = {}
        for r in self.db.query(category="collective", **self.filters):
            m = _COLL_ROW_RE.match(r.op)
            if not m or r.opt_level != self.opt_level:
                continue
            kind = hlo_analysis.LADDER_TO_COLLECTIVE[m.group(1)]
            devices = int(m.group(2))
            kv = parse_kv_notes(r.notes)
            wire = float(kv.get("wire_bytes", 0.0) or 0.0)
            if wire <= 0:
                payload = float(kv.get("payload_bytes", m.group(3)) or 0.0)
                if m.group(1) == "all_gather":
                    result = payload * devices
                elif m.group(1) == "reduce_scatter":
                    result = payload / max(devices, 1)
                else:
                    result = payload
                wire = hlo_analysis.ring_factor(kind, devices) * result
            if wire <= 0:
                continue
            rungs.setdefault(kind, []).append(
                CollectiveRung(kind=kind, devices=devices, wire_bytes=wire,
                               ns=r.latency_ns))
        for kind in rungs:
            rungs[kind].sort(key=lambda g: g.wire_bytes)
        return rungs

    def _memory_ns(self, bytes_accessed: float) -> float:
        """Price HBM traffic off the chase ladder: the rung whose working set
        covers the module's footprint (else the deepest rung) gives ns/byte;
        ``mem_streams`` concurrent streams amortize the serial-chase latency."""
        if bytes_accessed <= 0:
            return 0.0
        ladder = self.memory_ladder()
        if not ladder:
            return 0.0
        rung = next((g for g in ladder if g.working_set_bytes >= bytes_accessed),
                    ladder[-1])
        ns_per_byte = rung.ns_per_line / rung.line_bytes
        return bytes_accessed * ns_per_byte / max(self.mem_streams, 1)

    # ------------------------------------------------------------- pricing
    def _instance_ns(self, latency: float, elements: float,
                     instances: float = 1.0) -> float:
        """Issue latency per instance + lane-amortized per-element throughput."""
        extra = max(elements - instances, 0.0)
        return instances * latency + (extra / self.lanes) * self.THROUGHPUT_FACTOR * latency

    def estimate(self, hlo_text: str) -> PricedReport:
        """Price a module; returns the full :class:`PricedReport` diagnosis."""
        mc = hlo_analysis.ModuleCost(hlo_text)
        hist = mc.dynamic_histogram()
        by_class: dict[str, ClassCost] = {}
        unpriced_ops: dict[str, float] = {}
        compute = priced = unpriced = 0.0
        matmul_instances = 0.0

        def account(cls: str, ns: float, count: float, elems: float) -> None:
            by_class[cls] = by_class.get(cls, ClassCost())._plus(ns, count, elems)

        for (opcode, elems), count in sorted(hist.items()):
            if count <= 0 or opcode in hlo_analysis.STRUCTURAL_OPS:
                continue
            if opcode == "custom-call":
                continue            # priced per call site below (fused rows)
            if opcode in ("dot", "convolution"):
                matmul_instances += count
                continue            # priced below from dynamic FLOPs
            table_op = hlo_analysis.HLO_TO_TABLE.get(opcode)
            if table_op is None:
                ns = count * self._instance_ns(self.default_ns, elems)
                compute += ns
                unpriced += count
                unpriced_ops[opcode] = unpriced_ops.get(opcode, 0.0) + count
                account("unpriced", ns, count, count * elems)
                continue
            lat, covered = self._table_latency(table_op)
            ns = count * self._instance_ns(lat, elems)
            compute += ns
            if covered:
                priced += count
                account(_table_category(table_op), ns, count, count * elems)
            else:
                unpriced += count
                unpriced_ops[opcode] = unpriced_ops.get(opcode, 0.0) + count
                account("unpriced", ns, count, count * elems)

        # Custom-calls: per call site, not per opcode. A site whose target
        # resolves through CUSTOM_CALL_TARGETS to a measured
        # ``inkernel.fused.<name>`` row is priced by HBM footprint —
        # ``executions x call_bytes / unit_bytes x row_ns`` — the two-size
        # slope already netted launch + DMA out of row_ns, so scaling by the
        # certified unit bytes is the same per-unit algebra the probe used.
        # Everything else stays default-priced and is reported per *target*
        # (``custom-call:<target>``), not lumped under one opaque opcode.
        for target, cbytes, execs, rest in mc.dynamic_custom_calls():
            if execs <= 0:
                continue
            name = hlo_analysis.resolve_custom_call(target, rest)
            row = self._fused_row(name) if name else None
            if row is not None:
                row_ns, unit_bytes = row
                ns = execs * (cbytes / unit_bytes) * row_ns
                compute += ns
                priced += execs
                account(f"fused:{name}", ns, execs, 0.0)
            else:
                ns = execs * self.default_ns
                compute += ns
                unpriced += execs
                label = f"custom-call:{target or '?'}"
                unpriced_ops[label] = unpriced_ops.get(label, 0.0) + execs
                account("unpriced", ns, execs, 0.0)

        if matmul_instances:
            dyn_flops = mc.dynamic_flops()
            fmas = (dyn_flops.get("dot", 0.0)
                    + dyn_flops.get("convolution", 0.0)) / 2.0
            lat, covered = self._table_latency("fma.float32")
            ns = self._instance_ns(lat, fmas, instances=matmul_instances)
            compute += ns
            account("matmul", ns, matmul_instances, fmas)
            if covered:
                priced += matmul_instances
            else:
                unpriced += matmul_instances
                unpriced_ops["dot"] = unpriced_ops.get("dot", 0.0) + matmul_instances

        # Collectives: each parsed (trip-weighted) CollectiveOp is priced
        # from the *covering* measured ladder rung of its kind — the first
        # rung whose wire bytes reach the op's, else the largest — scaled
        # linearly: ``executions x wire_bytes / rung_wire x rung_ns``. Rungs
        # measured at the op's group size are preferred; a kind with no
        # measured rung at all is NEVER default-priced — it counts against
        # coverage and is reported as ``collective:<kind>`` so a sharded
        # prediction can't look measurement-backed when its interconnect
        # term is fiction. Zero-wire ops (group size 1) are free and count
        # in neither direction.
        collective_ns = 0.0
        coll_ladder: dict[str, list[CollectiveRung]] | None = None
        for c in mc.total().collectives:
            if c.executions <= 0:
                continue
            if c.group_size <= 1 or c.wire_bytes <= 0:
                continue
            if coll_ladder is None:
                coll_ladder = self.collective_ladder()
            rungs = coll_ladder.get(c.kind, [])
            sized = [g for g in rungs if g.devices == c.group_size] or rungs
            rung = next((g for g in sized if g.wire_bytes >= c.wire_bytes),
                        sized[-1] if sized else None)
            if rung is not None:
                ns = c.executions * (c.wire_bytes / rung.wire_bytes) * rung.ns
                collective_ns += ns
                priced += c.executions
                account("collective", ns, c.executions, 0.0)
            else:
                unpriced += c.executions
                label = f"collective:{c.kind}"
                unpriced_ops[label] = unpriced_ops.get(label, 0.0) + c.executions
                account("unpriced", 0.0, c.executions, 0.0)

        bytes_accessed = mc.total().bytes
        memory_ns = self._memory_ns(bytes_accessed)
        if memory_ns:
            account("memory", memory_ns, 0.0, 0.0)
        countable = priced + unpriced
        return PricedReport(
            total_ns=max(compute, memory_ns) + collective_ns,
            compute_ns=compute, memory_ns=memory_ns,
            collective_ns=collective_ns,
            coverage=priced / countable if countable else 1.0,
            priced_instances=priced, unpriced_instances=unpriced,
            by_class=by_class,
            unpriced_opcodes=tuple(sorted(unpriced_ops.items(),
                                          key=lambda kv: (-kv[1], kv[0]))),
            bytes_accessed=bytes_accessed, opt_level=self.opt_level)

    def estimate_ns(self, hlo_text: str) -> float:
        """Total estimate as a float, with the :class:`PricedReport` attached
        as ``.report`` — callers that only compare magnitudes keep working,
        callers that need the diagnosis (what fraction was actually priced?)
        no longer have to re-run the analysis."""
        report = self.estimate(hlo_text)
        out = _EstimatedNs(report.total_ns)
        out.report = report
        return out


# ------------------------------------------------------------------ serving
@dataclasses.dataclass(frozen=True)
class ServingPoint:
    """One ``serving.<phase>.<cell>`` row, parsed back from its record.

    The record's ``latency_ns`` is the *measured* wall clock of the lowered
    prefill / decode-step executable; the estimator's prediction and its
    diagnosis ride along in the notes (``predicted_ns=... coverage=...``),
    so predicted-vs-measured never needs a second lookup.
    """

    phase: str                   # "prefill" | "decode"
    batch: int
    prompt_len: int
    measured_ns: float
    predicted_ns: float
    compute_ns: float
    memory_ns: float
    coverage: float
    model: str = ""
    # sharded (tp>1) cells: tensor-parallel degree, interconnect term and
    # the count of collective instances the estimator could NOT price from
    # a measured rung (0 = fully measurement-backed interconnect)
    tp: int = 1
    collective_ns: float = 0.0
    coll_unpriced: float = 0.0

    @property
    def ratio(self) -> float:
        """predicted / measured (1.0 = perfect model)."""
        return self.predicted_ns / self.measured_ns if self.measured_ns else 0.0

    @property
    def abs_log10_error(self) -> float:
        """|log10(predicted/measured)| — the CI tolerance metric: symmetric
        in over/under-prediction and stable across cell magnitudes."""
        import math

        if self.measured_ns <= 0 or self.predicted_ns <= 0:
            return float("inf")
        return abs(math.log10(self.predicted_ns / self.measured_ns))


def servingpoint_from_record(rec: LatencyRecord) -> ServingPoint:
    """Parse a ``serving.*`` :class:`LatencyRecord` back into its point."""
    kv = parse_kv_notes(rec.notes)
    parts = rec.op.split(".")
    assert parts[0] == "serving" and len(parts) >= 3, rec.op
    return ServingPoint(
        phase=kv.get("phase", parts[1]),
        batch=int(kv["batch"]), prompt_len=int(kv["prompt"]),
        measured_ns=rec.latency_ns,
        predicted_ns=float(kv["predicted_ns"]),
        compute_ns=float(kv.get("compute_ns", 0.0)),
        memory_ns=float(kv.get("memory_ns", 0.0)),
        coverage=float(kv.get("coverage", 0.0)),
        model=kv.get("model", ""),
        tp=int(kv.get("tp", 1)),
        collective_ns=float(kv.get("collective_ns", 0.0)),
        coll_unpriced=float(kv.get("coll_unpriced", 0.0)))


@dataclasses.dataclass(frozen=True)
class SloPoint:
    """One ``slo.r<rate>`` row: predicted-vs-measured serving SLOs at one
    arrival rate, parsed back from the record an :class:`~repro.api.SloProbe`
    persisted. The record's ``latency_ns`` is the measured p50 TTFT; the
    notes carry the full percentile set for both sides (ns), goodput (tok/s)
    and the estimator coverage of the priced prefill/decode modules.
    """

    rate_rps: float
    n_requests: int
    n_slots: int
    predicted: dict               # metric name -> value (pred_* keys, no prefix)
    measured: dict                # same metric names, measured side
    coverage: float
    model: str = ""

    METRICS = ("ttft_p50_ns", "ttft_p99_ns", "tpot_p50_ns", "tpot_p99_ns",
               "e2e_p50_ns", "goodput_tok_s")

    def abs_log10_error(self, metric: str) -> float:
        """|log10(pred/meas)| for one metric — same CI tolerance semantics
        as :attr:`ServingPoint.abs_log10_error`."""
        import math

        p, m = self.predicted.get(metric, 0.0), self.measured.get(metric, 0.0)
        if p is None or m is None or p <= 0 or m <= 0 \
                or math.isnan(p) or math.isnan(m):
            return float("inf")
        return abs(math.log10(p / m))


def slopoint_from_record(rec: LatencyRecord) -> SloPoint:
    """Parse an ``slo.*`` :class:`LatencyRecord` back into its point."""
    kv = parse_kv_notes(rec.notes)
    assert rec.op.split(".")[0] == "slo", rec.op

    def side(prefix: str) -> dict:
        return {m: float(kv[f"{prefix}_{m}"]) for m in SloPoint.METRICS
                if f"{prefix}_{m}" in kv}

    return SloPoint(
        rate_rps=float(kv["rate"]), n_requests=int(kv.get("n", 0)),
        n_slots=int(kv.get("slots", 0)),
        predicted=side("pred"), measured=side("meas"),
        coverage=float(kv.get("coverage", 0.0)),
        model=kv.get("model", ""))


def slo_markdown(points: "list[SloPoint]") -> str:
    """Markdown throughput-vs-latency table over :class:`SloPoint` rows —
    the ``serve-slo`` CLI's output. Latencies in ms, goodput in tok/s."""
    def ms(d: dict, key: str) -> str:
        v = d.get(key)
        return f"{v / 1e6:.3f}" if v is not None else "-"

    lines = ["| rate (req/s) | side | TTFT p50 | TTFT p99 | TPOT p50 "
             "| TPOT p99 | goodput (tok/s) | coverage |",
             "|---" * 8 + "|"]
    for pt in points:
        for side_name, d in (("predicted", pt.predicted),
                             ("measured", pt.measured)):
            good = d.get("goodput_tok_s")
            lines.append(
                f"| {pt.rate_rps:g} | {side_name} "
                f"| {ms(d, 'ttft_p50_ns')} | {ms(d, 'ttft_p99_ns')} "
                f"| {ms(d, 'tpot_p50_ns')} | {ms(d, 'tpot_p99_ns')} "
                f"| {good:.1f} | {pt.coverage:.1%} |"
                if good is not None else
                f"| {pt.rate_rps:g} | {side_name} | - | - | - | - | - "
                f"| {pt.coverage:.1%} |")
    return "\n".join(lines)


@functools.cache
def _table_category(table_op: str) -> str:
    """Registry category of a table row (``sub.float32`` -> ``fp32``);
    memory rows and unknown names fall back to sensible classes."""
    from repro.core import chains

    names = {o.name: o.category for o in chains.default_registry()}
    if table_op in names:
        return names[table_op]
    base = table_op.split(".")[0]
    return names.get(base, "uncategorized")
