"""The paper's contribution: low-overhead, portable latency characterization.

The characterization *front door* is :mod:`repro.api` (Session / Plan /
Probe / ResultSet) — build a Plan, run it through a Session, get cached,
resumable, failure-tracked sweeps. This package holds the measurement
machinery the probes wrap:

  - chains.default_registry(): the instruction table (8 categories)
  - measure.measure_op/_full(): one op's slope-method latency (+ dispersion)
  - membench.measure_latency(): memory-hierarchy chase (Fig. 6 analog)
  - optlevels: the -O0/-O1/-O3 compiler axis
  - latency_db.LatencyDB: persistent result tables + failures (Table II/III)
  - perfmodel.Roofline / HloLatencyEstimator: the model-feeding use case
  - hlo_analysis: collective traffic + op histograms from HLO text

Deprecated shims (kept for one release): measure.run_suite,
measure.clock_overhead, membench.sweep — all now route through repro.api.
"""
from repro.core import chains, hlo_analysis, latency_db, measure, membench, optlevels, perfmodel
from repro.core.chains import OpSpec, default_registry
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.core.perfmodel import CPU_HOST, TPU_V5E, HardwareSpec, HloLatencyEstimator, Roofline
from repro.core.timing import Measurement, Timer

__all__ = [
    "chains", "hlo_analysis", "latency_db", "measure", "membench", "optlevels",
    "perfmodel", "OpSpec", "default_registry", "LatencyDB", "LatencyRecord",
    "Measurement", "Timer", "Roofline", "HloLatencyEstimator", "HardwareSpec",
    "TPU_V5E", "CPU_HOST",
]
