"""The paper's contribution: low-overhead, portable latency characterization.

Public surface:
  - chains.default_registry(): the instruction table (8 categories)
  - measure.run_suite(): sweep registry x opt levels -> LatencyDB
  - measure.clock_overhead(): Fig. 5 analog
  - membench.sweep(): memory-hierarchy latency probe (Fig. 6 analog)
  - optlevels: the -O0/-O1/-O3 compiler axis
  - latency_db.LatencyDB: persistent result tables (Table II/III analogs)
  - perfmodel.Roofline / HloLatencyEstimator: the model-feeding use case
  - hlo_analysis: collective traffic + op histograms from HLO text
"""
from repro.core import chains, hlo_analysis, latency_db, measure, membench, optlevels, perfmodel
from repro.core.chains import OpSpec, default_registry
from repro.core.latency_db import LatencyDB, LatencyRecord
from repro.core.perfmodel import CPU_HOST, TPU_V5E, HardwareSpec, HloLatencyEstimator, Roofline
from repro.core.timing import Measurement, Timer

__all__ = [
    "chains", "hlo_analysis", "latency_db", "measure", "membench", "optlevels",
    "perfmodel", "OpSpec", "default_registry", "LatencyDB", "LatencyRecord",
    "Measurement", "Timer", "Roofline", "HloLatencyEstimator", "HardwareSpec",
    "TPU_V5E", "CPU_HOST",
]
