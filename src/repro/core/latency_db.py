"""Persistent database of measured latencies (the paper's published tables).

Records are keyed by (device_kind, backend, jax_version, opt_level, op, dtype)
so the same suite run on different hardware / jax versions accumulates into one
DB — that is how the paper's Table III (CUDA 9.0 vs 10.0) diff is produced.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import re
from typing import Iterable

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX host
    fcntl = None

import jax

from repro.utils import (dump_json, load_json, logger, markdown_table,
                         parse_kv_notes, timestamp)


@dataclasses.dataclass(frozen=True)
class LatencyRecord:
    op: str
    category: str
    dtype: str
    opt_level: str
    latency_ns: float
    mad_ns: float
    cycles: float            # ns * calibrated clock (comparability with paper tables)
    guard: int               # extra trivial ops included in the step
    net_latency_ns: float    # latency minus guard * add-latency
    device_kind: str
    backend: str
    jax_version: str
    n_samples: int
    measured_at: str = ""
    notes: str = ""

    def key(self) -> tuple:
        return (self.device_kind, self.backend, self.jax_version,
                self.opt_level, self.op, self.dtype)


@dataclasses.dataclass(frozen=True)
class ProbeFailure:
    """Structured record of a probe that raised instead of measuring.

    Keyed identically to :class:`LatencyRecord` so a later successful
    measurement of the same probe supersedes the failure.
    """

    op: str
    dtype: str
    opt_level: str
    device_kind: str
    backend: str
    jax_version: str
    error_type: str
    message: str
    failed_at: str = ""

    def key(self) -> tuple:
        return (self.device_kind, self.backend, self.jax_version,
                self.opt_level, self.op, self.dtype)


def current_environment(device=None) -> dict[str, str]:
    """Environment fingerprint for ``device`` (default: the first device).

    The fingerprint is what every record/cache key starts with, so a session
    pinned to ``jax.devices()[3]`` must fingerprint *that* device — deriving
    it from ``jax.devices()[0]`` regardless of target was the root cause of
    mis-keyed records on multi-device hosts.
    """
    dev = device if device is not None else jax.devices()[0]
    return {
        "device_kind": dev.device_kind,
        "backend": dev.platform,
        "jax_version": jax.__version__,
    }


@contextlib.contextmanager
def _flush_lock(path: str):
    """Inter-process lock serializing read-merge-write cycles on one DB path.

    Uses ``flock`` on a sidecar ``<path>.lock`` file so two sessions flushing
    to the same DB never interleave their read-merge-write critical sections
    (the rename itself is atomic, but without the lock both could read the
    same stale state and the second rename would drop the first's records).
    No-op where ``fcntl`` is unavailable.
    """
    if fcntl is None:  # non-POSIX: atomic rename still holds, merge races don't
        yield
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    with open(path + ".lock", "a") as lockf:
        fcntl.flock(lockf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lockf, fcntl.LOCK_UN)


def _journal_path(path: str) -> str:
    return path + ".journal"


class LatencyDB:
    def __init__(self, path: str | None = None):
        self.path = path
        self._records: dict[tuple, LatencyRecord] = {}
        self._failures: dict[tuple, ProbeFailure] = {}
        self._disk_state: tuple | None = None
        self._dirty_records: set[tuple] = set()
        self._dirty_failures: set[tuple] = set()
        if path and os.path.exists(path):
            self.load(path)
        elif path and os.path.exists(_journal_path(path)):
            # Crashed before the first compaction: the journal is all there is.
            self._replay_journal(path)

    # ----------------------------------------------------------------- CRUD
    def add(self, rec: LatencyRecord) -> None:
        self._records[rec.key()] = rec
        self._failures.pop(rec.key(), None)  # a success supersedes a failure
        self._dirty_records.add(rec.key())
        self._dirty_failures.discard(rec.key())

    def extend(self, recs: Iterable[LatencyRecord]) -> None:
        for r in recs:
            self.add(r)

    def annotate(self, key: tuple, **kv: str | None) -> LatencyRecord | None:
        """Merge ``key=value`` tokens into a record's notes, in place.

        Existing tokens with the same key are replaced; a value of ``None``
        deletes the token. ``measured_at`` is untouched, so on a concurrent
        ``save`` the annotated copy wins merge ties against the un-annotated
        on-disk copy of itself (ties keep the in-memory value). Used by
        ``repro.audit`` to persist ``audit=...`` verdicts. Returns the
        updated record, or None when the key is absent.
        """
        rec = self._records.get(tuple(key))
        if rec is None:
            return None
        drop = set(kv)
        kept = [tok for tok in rec.notes.split()
                if tok.partition("=")[0] not in drop]
        added = [f"{k}={v}" for k, v in kv.items() if v is not None]
        rec = dataclasses.replace(rec, notes=" ".join(kept + added))
        self.add(rec)
        return rec

    def records(self) -> list[LatencyRecord]:
        return list(self._records.values())

    def get(self, key: tuple) -> LatencyRecord | None:
        return self._records.get(tuple(key))

    def __contains__(self, key: tuple) -> bool:
        return tuple(key) in self._records

    def __len__(self) -> int:
        return len(self._records)

    # ------------------------------------------------------------- failures
    def add_failure(self, failure: ProbeFailure) -> None:
        self._failures[failure.key()] = failure
        self._dirty_failures.add(failure.key())

    def failures(self) -> list[ProbeFailure]:
        return list(self._failures.values())

    def query(self, **filters: str) -> list[LatencyRecord]:
        out = []
        for r in self._records.values():
            if all(getattr(r, k) == v for k, v in filters.items()):
                out.append(r)
        return out

    def lookup_ns(self, op: str, opt_level: str = "O3", default: float | None = None,
                  **filters: str) -> float | None:
        recs = self.query(op=op, opt_level=opt_level, **filters)
        if not recs:
            return default
        return sorted(recs, key=lambda r: r.measured_at)[-1].latency_ns

    # ---------------------------------------------------------------- merge
    def merge(self, *others: "LatencyDB") -> "LatencyDB":
        """Merge other DBs into this one (in place); returns self.

        Conflict rules, applied per key:

        * record vs record — newest ``measured_at`` wins; ties keep the
          current value (so a just-measured in-memory record is never
          replaced by an equally-timestamped on-disk copy of itself);
        * failure vs failure — newest ``failed_at`` wins, same tie rule;
        * record vs failure — the success supersedes the failure regardless
          of timestamps: one shard measuring an op beats another shard's
          crash on it.
        """
        for other in others:
            for key, rec in other._records.items():
                mine = self._records.get(key)
                if mine is None or rec.measured_at > mine.measured_at:
                    self._records[key] = rec
                    self._dirty_records.add(key)
            for key, fail in other._failures.items():
                mine = self._failures.get(key)
                if mine is None or fail.failed_at > mine.failed_at:
                    self._failures[key] = fail
                    self._dirty_failures.add(key)
        for key in list(self._failures):
            if key in self._records:
                del self._failures[key]
                self._dirty_failures.discard(key)
        return self

    # ------------------------------------------------------------------- IO
    def flush(self, path: str | None = None) -> str:
        """Append only the dirty (not-yet-persisted) entries to the journal.

        This is the cheap per-probe durability point: an N-probe sweep used
        to rewrite the whole DB after every probe — O(N²) JSON serialization
        plus N flock read-merge-write cycles. ``flush`` instead appends each
        new record/failure once to a ``<path>.journal`` JSONL sidecar
        (fsync'd, under the same inter-process lock) and nothing when there
        is nothing new. Crash-resume is preserved: :meth:`load` and the
        constructor replay the journal on top of the main file. ``save``
        compacts journal + main file back into one atomic write.
        """
        path = path or self.path
        assert path, "no path for LatencyDB.flush"
        if not self._dirty_records and not self._dirty_failures:
            return path
        lines = []
        for key in sorted(self._dirty_records):
            rec = self._records.get(key)
            if rec is not None:
                lines.append(json.dumps({"r": dataclasses.asdict(rec)}))
        for key in sorted(self._dirty_failures):
            fail = self._failures.get(key)
            if fail is not None:
                lines.append(json.dumps({"f": dataclasses.asdict(fail)}))
        with _flush_lock(path):
            with open(_journal_path(path), "a") as f:
                f.write("".join(line + "\n" for line in lines))
                f.flush()
                os.fsync(f.fileno())
        self._dirty_records.clear()
        self._dirty_failures.clear()
        return path

    def _replay_journal(self, path: str) -> None:
        """Apply journal lines in append order; damaged tails are dropped."""
        jpath = _journal_path(path)
        try:
            text = open(jpath).read()
        except OSError:
            return
        replayed_recs, replayed_fails = set(), set()
        for line in text.splitlines():
            if not line.strip():
                continue
            try:  # a crash mid-append leaves at most one torn final line
                obj = json.loads(line)
                if "r" in obj:
                    rec = LatencyRecord(**obj["r"])
                    self.add(rec)
                    replayed_recs.add(rec.key())
                elif "f" in obj:
                    fail = ProbeFailure(**obj["f"])
                    self.add_failure(fail)
                    replayed_fails.add(fail.key())
            except Exception:  # noqa: BLE001 - torn/foreign line: skip
                continue
        if replayed_recs or replayed_fails:
            logger.debug("replayed %d journal entries from %s",
                         len(replayed_recs) + len(replayed_fails), jpath)
        # Replayed entries live on disk already — they are not dirty.
        self._dirty_records -= replayed_recs
        self._dirty_failures -= replayed_fails

    def save(self, path: str | None = None, merge_on_disk: bool = True) -> str:
        """Compact to ``path``: read-merge the on-disk state (main file plus
        any journal), write atomically, then drop the journal.

        Concurrent writers (sharded sessions flushing to one DB) are safe:
        the read-merge-write cycle runs under an inter-process lock, the
        merge keeps every other writer's records (:meth:`merge` rules), and
        the write is a unique-temp-file + rename, so an interrupted save
        leaves the previous file intact rather than a truncated one.
        ``merge_on_disk=False`` restores plain overwrite semantics (still
        atomic) for callers that want the file to mirror memory exactly.
        """
        path = path or self.path
        assert path, "no path for LatencyDB.save"
        with _flush_lock(path):
            on_disk = os.path.exists(path) or os.path.exists(_journal_path(path))
            if merge_on_disk and on_disk and not self._disk_unchanged(path):
                try:
                    disk = LatencyDB(path)
                except Exception:  # noqa: BLE001 - salvage, never clobber, a corrupt file
                    disk = LatencyDB.recover(path)
                self.merge(disk)
            dump_json({"saved_at": timestamp(),
                       "records": [dataclasses.asdict(r) for r in self._records.values()],
                       "failures": [dataclasses.asdict(f) for f in self._failures.values()]},
                      path)
            try:
                os.unlink(_journal_path(path))
            except OSError:
                pass
            self._remember_disk_state(path)
        self._dirty_records.clear()
        self._dirty_failures.clear()
        return path

    def _disk_unchanged(self, path: str) -> bool:
        """True when ``path`` still holds exactly what we last wrote/read —
        lets repeated compactions of long sweeps skip re-parsing their own
        output. A pending journal always counts as changed. Checked under
        the flush lock."""
        if os.path.exists(_journal_path(path)):
            return False
        try:
            st = os.stat(path)
        except OSError:
            return False
        return self._disk_state == (path, st.st_mtime_ns, st.st_size)

    def _remember_disk_state(self, path: str) -> None:
        try:
            st = os.stat(path)
            self._disk_state = (path, st.st_mtime_ns, st.st_size)
        except OSError:
            self._disk_state = None

    def load(self, path: str) -> None:
        blob = load_json(path)
        loaded_recs, loaded_fails = set(), set()
        for raw in blob["records"]:
            rec = LatencyRecord(**raw)
            self.add(rec)
            loaded_recs.add(rec.key())
        for raw in blob.get("failures", ()):  # absent in pre-1.1 DB files
            fail = ProbeFailure(**raw)
            self.add_failure(fail)
            loaded_fails.add(fail.key())
        # What came off disk is by definition already persisted.
        self._dirty_records -= loaded_recs
        self._dirty_failures -= loaded_fails
        self._remember_disk_state(path)
        if os.path.exists(_journal_path(path)):
            self._replay_journal(path)

    @classmethod
    def recover(cls, path: str) -> "LatencyDB":
        """Salvage a truncated/corrupt DB file instead of raising.

        A sweep killed mid-``save`` (or a partial copy) leaves a file that
        strict :meth:`load` rejects wholesale. Measurements are expensive, so
        this decodes every complete record object individually and drops only
        the damaged tail. Returns a DB bound to ``path`` (a subsequent
        ``save`` rewrites it whole); on an intact file it is identical to the
        normal constructor.
        """
        db = cls()
        db.path = path
        if not os.path.exists(path):
            db._replay_journal(path)
            return db
        try:
            db.load(path)
            return db
        except Exception:  # noqa: BLE001 - fall through to per-record salvage
            pass
        text = open(path).read()
        decoder = json.JSONDecoder()
        rec_fields = {f.name for f in dataclasses.fields(LatencyRecord)}
        rec_required = rec_fields - {"measured_at", "notes"}
        fail_fields = {f.name for f in dataclasses.fields(ProbeFailure)}
        fail_required = fail_fields - {"failed_at"}
        pos = text.find("{", text.find("{") + 1)  # skip the top-level object
        while pos >= 0:
            try:
                obj, end = decoder.raw_decode(text, pos)
            except json.JSONDecodeError:
                pos = text.find("{", pos + 1)
                continue
            if isinstance(obj, dict):
                keys = set(obj)
                try:  # recovery must never raise on damaged objects
                    if rec_required <= keys <= rec_fields:
                        db.add(LatencyRecord(**obj))
                    elif fail_required <= keys <= fail_fields:
                        db.add_failure(ProbeFailure(**obj))
                except Exception:  # noqa: BLE001 - e.g. wrong value types
                    pass
            pos = text.find("{", max(end, pos + 1))
        db._replay_journal(path)  # journal entries survive main-file damage
        logger.warning("recovered %d records + %d failures from corrupt DB %s",
                       len(db), len(db.failures()), path)
        return db

    # -------------------------------------------------------------- reports
    def table_markdown(self, opt_levels: tuple[str, ...] = ("O3", "O0")) -> str:
        """Table II analog: rows = ops, columns = Optimized / Non-Optimized."""
        by_op: dict[tuple[str, str, str], dict[str, LatencyRecord]] = {}
        for r in self._records.values():
            by_op.setdefault((r.category, r.op, r.dtype), {})[r.opt_level] = r
        rows = []
        for (cat, op, dt), levels in sorted(
                by_op.items(),
                key=lambda kv: (kv[0][0], self._natural(kv[0][1]), kv[0][2])):
            row = [cat, op, dt]
            for lv in opt_levels:
                rec = levels.get(lv)
                if rec is None:
                    row.append("—")
                else:
                    disp = f"±{rec.mad_ns:.1f}" if rec.mad_ns else ""
                    row.append(f"{rec.latency_ns:.1f}{disp}ns ({rec.cycles:.0f}cy)")
            rows.append(row)
        headers = ["category", "op", "dtype"] + [
            {"O3": "Optimized", "O0": "Non-Optimized"}.get(lv, lv) for lv in opt_levels]
        return markdown_table(headers, rows)

    def audit_status(self) -> dict[str, list[LatencyRecord]]:
        """Records grouped by audit verdict status (from the ``audit=``
        notes token; records never audited group under ``unaudited``)."""
        groups: dict[str, list[LatencyRecord]] = {}
        for r in sorted(self._records.values(),
                        key=lambda r: (self._natural(r.op), r.opt_level)):
            tok = parse_kv_notes(r.notes).get("audit", "unaudited")
            groups.setdefault(tok.partition(":")[0], []).append(r)
        return groups

    def audit_markdown(self) -> str:
        """Audit-verdict table surfacing failed and unaudited rows first."""
        order = {"transformed": 0, "opaque": 1, "unaudited": 2, "ok": 3}
        rows = []
        for status, recs in sorted(self.audit_status().items(),
                                   key=lambda kv: order.get(kv[0], 9)):
            for r in recs:
                kv = parse_kv_notes(r.notes)
                tok = kv.get("audit", "unaudited")
                cause = (tok.partition(":")[2] or
                         kv.get("audit_transform", "") or "—")
                rows.append([r.op, r.opt_level, r.dtype, status, cause,
                             f"{r.net_latency_ns:.1f}"])
        return markdown_table(
            ["op", "opt", "dtype", "audit", "cause/transform", "net ns"],
            rows)

    @staticmethod
    def _host_twin(base: str) -> str:
        """Host-level row an in-kernel row pairs with.

        Op-chain rows pair by identical name (``inkernel.add`` <-> ``add``);
        the memory rows follow their own naming on each side, so
        ``inkernel.mem.<N>`` pairs with the host chase at the same working
        set, ``mem.chase.ws<N>``. Fidelity-suffixed variants fall through
        unchanged (and therefore stay unpaired — a different experiment).
        """
        if base.startswith("mem.") and base[4:].isdigit():
            return f"mem.chase.ws{base[4:]}"
        return base

    @staticmethod
    def _natural(op: str) -> tuple:
        """Sort key ordering embedded integers numerically, so the memory
        ladder reads ws4096 < ws65536 < ws1048576 instead of lexically."""
        return tuple(int(p) if p.isdigit() else p
                     for p in re.split(r"(\d+)", op))

    def _serving_markdown(self, opt_level: str) -> str:
        """Predicted-vs-measured over the ``serving.*`` rows.

        Each row is self-paired: the ``ServingCostProbe`` persists the
        estimator's prediction (and its coverage diagnosis) in the record's
        notes next to the measured wall clock, so the table needs no
        cross-record twin lookup. Rows sort by environment then cell
        (numerically: b2p64 after b2p16, not lexically).
        """
        rows = []
        recs = sorted(
            (r for r in self._records.values()
             if r.op.startswith("serving.") and r.opt_level == opt_level),
            key=lambda r: (r.device_kind, r.backend, r.jax_version,
                           self._natural(r.op)))
        for r in recs:
            kv = parse_kv_notes(r.notes)
            pred = float(kv.get("predicted_ns", 0.0))
            meas = r.latency_ns
            ratio = f"{pred / meas:.3f}" if meas > 0 else "—"
            cov = kv.get("coverage", "—")
            rows.append([r.op, kv.get("phase", "—"), kv.get("batch", "—"),
                         kv.get("prompt", "—"), kv.get("model", "—"),
                         f"{pred:.0f}", f"{meas:.0f}", ratio, cov,
                         kv.get("bound", "—")])
        return markdown_table(
            ["cell", "phase", "batch", "prompt", "model", "predicted (ns)",
             "measured (ns)", "pred/meas", "coverage", "bound"], rows)

    def _collective_markdown(self, opt_level: str) -> str:
        """The collective ladder: one row per ``coll.<kind>.d<N>.<bytes>``
        rung, sorted kind-major then payload. Bytes columns come from the
        probe's notes (the *actual* local shard bytes — payload rounding to a
        devices-multiple can exceed the nominal rung in the op name — and the
        ring-model wire traffic one chain step moves)."""
        rows = []
        recs = sorted(
            (r for r in self._records.values()
             if r.op.startswith("coll.") and r.opt_level == opt_level),
            key=lambda r: (r.device_kind, r.backend, r.jax_version,
                           self._natural(r.op)))
        for r in recs:
            kv = parse_kv_notes(r.notes)
            rows.append([r.op, kv.get("kind", "—"), kv.get("devices", "—"),
                         kv.get("payload_bytes", "—"),
                         kv.get("wire_bytes", "—"),
                         f"{r.latency_ns:.0f}±{r.mad_ns:.0f}",
                         kv.get("audit", "—")])
        return markdown_table(
            ["row", "kind", "devices", "payload (B)", "wire (B/step)",
             "step (ns)", "audit"], rows)

    def compare_markdown(self, prefix: str = "inkernel.",
                         opt_level: str = "O3") -> str:
        """Host-vs-in-kernel pairing: ops measured both ways, side by side.

        ``prefix="serving."`` renders the serving-path pairing instead:
        predicted (estimator over the cell's lowered HLO) vs measured
        (wall clock of the compiled executable), one row per
        ``serving.<phase>.<cell>`` record — see :meth:`_serving_markdown`.
        ``prefix="coll."`` renders the collective-ladder rungs
        (:meth:`_collective_markdown`).

        Pairs every host-level record with its ``<prefix>``-named twin at the
        same dtype, opt level **and environment** — the DB accumulates runs
        from multiple devices/jax versions (that is how Table III diffs are
        made), and a CPU-dispatch vs TPU-in-kernel ratio would be
        meaningless. Twin naming is per-family (:meth:`_host_twin`):
        ``inkernel.add`` <-> dispatch ``add`` (Fig. 3), ``inkernel.mem.<N>``
        <-> host chase ``mem.chase.ws<N>`` (Table IV / Fig. 6).
        Fidelity-suffixed variants like ``inkernel.add.l4-32`` are a
        different experiment and are *not* paired. The ratio column is the
        in-pipeline fraction of the host-level number — the launch/dispatch
        blur the paper's in-pipeline sampling removes.
        """
        if prefix == "serving.":
            return self._serving_markdown(opt_level)
        if prefix == "coll.":
            return self._collective_markdown(opt_level)
        plain: dict[tuple, LatencyRecord] = {}
        inker: dict[tuple, LatencyRecord] = {}
        for r in self._records.values():
            if r.opt_level != opt_level:
                continue
            env = (r.device_kind, r.backend, r.jax_version)
            if r.op.startswith(prefix):
                inker[env + (self._host_twin(r.op[len(prefix):]), r.dtype)] = r
            else:
                plain[env + (r.op, r.dtype)] = r
        rows = []
        for k in sorted(set(plain) & set(inker), key=lambda k: (
                plain[k].category,) + k[:3] + (self._natural(k[3]), k[4])):
            d, ik = plain[k], inker[k]
            ratio = (f"{ik.latency_ns / d.latency_ns:.3f}"
                     if d.latency_ns > 0 else "—")
            rows.append([d.category, k[3], k[4],
                         f"{d.latency_ns:.2f}±{d.mad_ns:.2f}",
                         f"{ik.latency_ns:.2f}±{ik.mad_ns:.2f}", ratio])
        return markdown_table(
            ["category", "op", "dtype", f"dispatch {opt_level} (ns)",
             "in-kernel (ns)", "in-kernel/dispatch"], rows)

    def diff_markdown(self, key_a: str, key_b: str, field: str = "jax_version",
                      opt_level: str = "O3", rel_threshold: float = 0.10) -> str:
        """Table III analog: ops whose latency changed between two versions."""
        a = {(r.op, r.dtype): r for r in self.query(opt_level=opt_level)
             if getattr(r, field) == key_a}
        b = {(r.op, r.dtype): r for r in self.query(opt_level=opt_level)
             if getattr(r, field) == key_b}
        rows = []
        for k in sorted(set(a) & set(b)):
            ra, rb = a[k], b[k]
            if ra.latency_ns <= 0:
                continue
            rel = (rb.latency_ns - ra.latency_ns) / max(ra.latency_ns, 1e-9)
            if abs(rel) >= rel_threshold:
                rows.append([k[0], k[1], f"{ra.latency_ns:.1f}", f"{rb.latency_ns:.1f}",
                             f"{100*rel:+.1f}%"])
        return markdown_table(["op", "dtype", key_a, key_b, "delta"], rows)
