"""Small shared utilities used across the framework."""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import math
import os
import tempfile
import time
from typing import Any, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[repro %(levelname)s %(asctime)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(os.environ.get("REPRO_LOGLEVEL", "INFO"))


def block(tree: Any) -> Any:
    """Block until every array in a pytree is ready; returns the tree."""
    return jax.block_until_ready(tree)


def compiled_cost(compiled: Any) -> dict[str, Any]:
    """``compiled.cost_analysis()`` normalized to a dict.

    jax < 0.5 returns a one-element list of dicts (one per computation);
    newer jax returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays/ShapeDtypeStructs in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves)


def tree_params(tree: Any) -> int:
    """Total element count of all leaves in a pytree."""
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EiB"


def human_flops(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}FLOP"
        n /= 1000.0
    return f"{n:.2f}ZFLOP"


class _JsonEncoder(json.JSONEncoder):
    def default(self, o: Any) -> Any:  # noqa: D102
        if dataclasses.is_dataclass(o) and not isinstance(o, type):
            return dataclasses.asdict(o)
        if isinstance(o, (np.integer,)):
            return int(o)
        if isinstance(o, (np.floating,)):
            return float(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
        return super().default(o)


def dump_json(obj: Any, path: str) -> None:
    """Atomically serialize ``obj`` to ``path``.

    The temp file is uniquely named (two concurrent writers never share one)
    and renamed over the target only after a successful write + fsync, so a
    crash mid-write leaves the previous file intact and no truncated JSON is
    ever observable at ``path``.
    """
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=os.path.basename(path) + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2, cls=_JsonEncoder)
            f.flush()
            os.fsync(f.fileno())
        # mkstemp creates 0600; restore the umask-derived mode a plain
        # open() would have produced so saved DBs stay readable by others
        umask = os.umask(0)
        os.umask(umask)
        os.chmod(tmp, 0o666 & ~umask)
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def load_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def timestamp() -> str:
    return time.strftime("%Y-%m-%dT%H:%M:%S")


def parse_kv_notes(notes: str) -> dict[str, str]:
    """Parse the space-separated ``key=value`` convention of record notes.

    Probes persist structured metadata in ``LatencyRecord.notes`` as
    ``ws=8192 line=64 space=vmem``; this is the single inverse for every
    consumer (membench chase points, serving predicted-vs-measured rows).
    Free-text fragments without ``=`` are ignored.
    """
    out: dict[str, str] = {}
    for tok in notes.split():
        if "=" in tok:
            k, _, v = tok.partition("=")
            if k:
                out[k] = v
    return out


def percentiles(samples: Iterable[float],
                ps: Iterable[float] = (50, 90, 99)) -> dict[float, float]:
    """Exact-rank (nearest-rank) percentiles of ``samples``.

    ``percentiles(xs, (50, 90, 99))[99]`` is the smallest element with at
    least 99% of the samples at or below it: ``sorted(xs)[ceil(p/100 * n) - 1]``
    (``p == 0`` gives the minimum). Every returned value is an actual sample —
    no interpolation — so a p99 over latencies is a latency some request
    really saw, and small-sample tails aren't invented by midpoint averaging
    (the ad-hoc ``np.quantile`` default's behavior). This is the single
    percentile implementation for SLO reporting (``traffic.metrics``,
    ``benchmarks/report.py``).
    """
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError("percentiles() of empty sample set")
    out: dict[float, float] = {}
    for p in ps:
        p = float(p)
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        rank = math.ceil(p / 100.0 * len(xs))
        out[p] = xs[max(rank, 1) - 1]
    return out


def markdown_table(headers: Iterable[str], rows: Iterable[Iterable[Any]]) -> str:
    headers = list(headers)
    lines = ["| " + " | ".join(str(h) for h in headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(lines)


def flatten_dict(d: Mapping[str, Any], prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out
