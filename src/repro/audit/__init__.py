"""Static integrity auditing of characterization artifacts.

The measurement pipeline *times* compiled chains; this package *inspects*
them, closing the loop on the method's two unstated assumptions:

1. a timed chain of length ``n`` really contains ``n`` dependent target ops
   (``Timer.slope``'s denominator) — :mod:`repro.audit.chain_check`;
2. the declared ``guard`` count matches the ops actually in the chain
   (``net_latency_ns``'s subtraction) — same module, plus the static lints
   in :mod:`repro.audit.lint`.

When a count is wrong, :mod:`repro.audit.transforms` names the XLA pass
family responsible (folded, strength-reduced, CSE'd, hoisted, ...) — the
paper's Table III taxonomy — and generates ``results/opt_attribution.md``.

Pallas rows are not opaque: :mod:`repro.audit.dataflow` opens each kernel's
closed jaxpr and certifies serialization (the carry chain is one dependent
path), residency (every ref in its declared memory space), and signature
(per-invocation op multiset + HBM bytes) — verdicts carry the ``audited``
status and the fused-kernel signature registry feeds custom-call pricing
in ``core.perfmodel``.

Entry points: ``python -m repro audit`` (CLI), ``Session(audit=True)``
(verdicts attached to records as they are measured), or :func:`audit_db`
(verify an existing DB in place). Verdicts persist in record notes as
``audit=ok`` / ``audit=audited`` / ``audit=transformed:<cause>`` /
``audit=opaque:<reason>`` / ``audit=unaudited:<reason>`` and round-trip
through :func:`repro.utils.parse_kv_notes`. See docs/audit.md.
"""
from __future__ import annotations

from typing import Any, Mapping

from repro.audit.chain_check import (ChainVerdict, audit_chase,
                                     audit_clock_overhead, audit_kernel,
                                     audit_spec, audit_target, expected_step,
                                     path_counts)
from repro.audit.dataflow import (ChainCert, KernelCert, RefCert,
                                  audit_fused, fused_registry, kernel_cert,
                                  kernel_certs)
from repro.audit.lint import LintFinding, run_lints
from repro.audit.transforms import classify, write_attribution

__all__ = [
    "ChainCert", "ChainVerdict", "KernelCert", "LintFinding", "RefCert",
    "audit_chase", "audit_clock_overhead", "audit_db", "audit_fused",
    "audit_kernel", "audit_record", "audit_spec", "audit_target", "classify",
    "expected_step", "fused_registry", "kernel_cert", "kernel_certs",
    "path_counts", "run_lints", "write_attribution",
]


def audit_record(rec, *, cache: Any = None,
                 env: Mapping[str, str] | None = None) -> ChainVerdict:
    """Audit one LatencyRecord's artifact. Records measured under a different
    environment fingerprint than the current process cannot be re-derived
    here and come back ``unaudited:environment-mismatch``."""
    if env is not None and (rec.device_kind, rec.backend, rec.jax_version) != (
            env.get("device_kind"), env.get("backend"),
            env.get("jax_version")):
        return ChainVerdict(
            rec.op, rec.opt_level, "unaudited", cause="environment-mismatch",
            detail=f"record from {rec.device_kind}/{rec.jax_version}, "
                   f"auditing on {env.get('device_kind')}/"
                   f"{env.get('jax_version')}")
    return audit_target(rec.op, rec.opt_level, cache=cache, env=env)


def audit_db(db, *, cache: Any = None, env: Mapping[str, str] | None = None,
             recheck: bool = False, annotate: bool = True
             ) -> list[ChainVerdict]:
    """Audit every record in ``db``; returns verdicts in record order.

    Verdicts are persisted into each record's notes (``annotate=False`` for
    a dry run); existing verdicts are kept unless ``recheck``. Environment-
    mismatched records are reported but never annotated — their artifacts
    are not reconstructible in this process and a previously attached
    verdict from the measuring environment stays authoritative.
    """
    from repro.audit.chain_check import _verdict_from_note
    from repro.core.latency_db import current_environment

    if env is None:
        env = current_environment()
    verdicts = []
    for rec in db.records():
        existing = _verdict_from_note(rec.op, rec.opt_level, rec.notes)
        mismatch = (rec.device_kind, rec.backend, rec.jax_version) != (
            env["device_kind"], env["backend"], env["jax_version"])
        if mismatch and existing is not None:
            verdicts.append(existing)
            continue
        if existing is not None and not recheck:
            verdicts.append(existing)
            continue
        v = audit_record(rec, cache=cache, env=env)
        verdicts.append(v)
        if annotate and not mismatch:
            kv = {"audit": v.status if not v.cause or v.status == "ok"
                  else f"{v.status}:{v.cause}",
                  "audit_transform": v.cause if (v.status == "ok" and v.cause)
                  else None}
            db.annotate(rec.key(), **kv)
    return verdicts
