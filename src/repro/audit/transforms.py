"""Classify *why* a chain count is wrong, and attribute opt-level deltas.

When :mod:`repro.audit.chain_check` finds the optimized artifact's opcode
delta differing from the jaxpr-derived expectation, :func:`classify` names
the XLA pass family responsible by comparing what went missing against what
appeared — the same taxonomy the paper uses for nvcc's O1/O3 effects
(Table III): constant folding, dead-code elimination, strength reduction,
algebraic simplification, loop-invariant CSE/hoisting.

:func:`write_attribution` generates ``results/opt_attribution.md``, the
ROADMAP's per-pass attribution of the O0->O1->O3 latency deltas: for each
registry op it compiles one short chain at every level, diffs the per-step
opcode multisets stage by stage, names the transform class for each stage,
and joins the measured latencies from a LatencyDB when one is given.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, Mapping, TextIO

# Ordered, documented cause taxonomy (values are the note-safe token — no
# spaces; ``parse_kv_notes`` splits notes on whitespace).
CAUSES = (
    "folded-to-constant",       # whole chain evaluated at compile time
    "dead-code-eliminated",     # ops vanished but root still reads inputs
    "strength-reduction",       # op replaced by cheaper equivalents
    "algebraic-simplification", # ops removed by identities, nothing added
    "rematerialized",           # extra copies of expected ops appeared
    "loop-invariant-cse",       # per-step op shared across steps
    "hoisted",                  # right count, but off the dependent path
    "guard-mismatch",           # declared guard algebra inconsistent
    "plumbing-nonlinear",       # convert traffic not linear in chain length
    "unknown",
)


def classify(expected: Counter, observed: Counter,
             hlo_text: str | None = None) -> str:
    """Name the pass family that best explains ``observed != expected``.

    Both counters are *positive* per-delta opcode counts (expected per-step x
    ``dn`` vs measured histogram delta). ``hlo_text`` (the longer lens'
    module) sharpens the empty-observation case: a root with no parameter
    ancestors means the chain folded to a literal, while a root still reading
    inputs means the ops were dead-code-eliminated.
    """
    if not +observed:
        if not +expected:
            return "unknown"
        if hlo_text is not None:
            from repro.audit.chain_check import root_is_constant

            if root_is_constant(hlo_text):
                return "folded-to-constant"
            return "dead-code-eliminated"
        return "folded-to-constant"
    missing = expected - observed
    gained = observed - expected
    if missing and gained:
        return "strength-reduction"
    if missing:
        return "algebraic-simplification"
    if gained:
        return "rematerialized"
    return "unknown"


# ------------------------------------------------------------- attribution
# Short lens for attribution compiles: per-step deltas are length-invariant
# (verified against the plan lens), and 4->12 keeps a full-registry sweep to
# seconds rather than minutes.
ATTR_LENS = (4, 12)


def _per_step(spec, opt_level: str, lens=ATTR_LENS) -> dict[str, float]:
    """Per-step countable-opcode multiset of ``spec`` at ``opt_level``."""
    from repro.audit import chain_check as cc

    n1, n2 = lens
    if spec.max_chain is not None:
        n1, n2 = min(n1, max(spec.max_chain // 3, 1)), min(n2, spec.max_chain)
    if opt_level == "O0":
        from repro.core import measure
        from repro.core.chains import chain_fn

        with measure._x64_ctx(spec):
            args = (spec.carry(), *spec.operand_arrays())
            c1 = cc.prim_counts(chain_fn(spec, n1), *args)
            c2 = cc.prim_counts(chain_fn(spec, n2), *args)
        mapped: Counter = Counter()
        for prim, k in (c2 - c1).items():
            for opcode in cc.PRIM_TO_HLO.get(prim, (f"<{prim}>",)):
                if opcode not in cc.PLUMBING_OPS:
                    mapped[opcode] += k
        return {k: v / (n2 - n1) for k, v in mapped.items()}
    c1, _ = cc.hist_counts(cc.chain_hlo_text(spec, n1, opt_level))
    c2, _ = cc.hist_counts(cc.chain_hlo_text(spec, n2, opt_level))
    return {k: (c2.get(k, 0) - c1.get(k, 0)) / (n2 - n1)
            for k in set(c1) | set(c2)
            if c2.get(k, 0) != c1.get(k, 0)}


def _stage_cause(before: Mapping[str, float], after: Mapping[str, float]
                 ) -> str:
    """Transform class for one opt-level stage; ``none`` when the per-step
    multiset is unchanged (any latency delta is pure dispatch overhead)."""
    b = Counter({k: round(v * 12) for k, v in before.items()})
    a = Counter({k: round(v * 12) for k, v in after.items()})
    if b == a:
        return "none"
    cause = classify(b, a)
    return cause


def _fmt_multiset(ms: Mapping[str, float]) -> str:
    if not ms:
        return "(empty)"
    return ", ".join(f"{k} x{v:g}" for k, v in sorted(ms.items()))


def attribution_rows(ops: Iterable[str] | None = None,
                     db=None) -> list[dict]:
    """One attribution row per op: per-step multisets at O0/O1/O3, the named
    transform class per stage, and measured net latencies when ``db`` has
    them (keys are matched on ``(op, opt_level)`` across environments)."""
    from repro.audit import chain_check as cc
    from repro.core.chains import default_registry

    registry = {s.name: s for s in default_registry()}
    names = list(ops) if ops is not None else list(registry)
    measured: dict[tuple[str, str], float] = {}
    if db is not None:
        for rec in db.records():
            measured.setdefault((rec.op, rec.opt_level), rec.net_latency_ns)
    rows = []
    for name in names:
        spec = registry.get(name)
        if spec is None:
            continue
        o0 = _per_step(spec, "O0")
        o1 = _per_step(spec, "O1")
        o3 = _per_step(spec, "O3")
        declared = cc._lookup(cc.EXPECTED_TRANSFORMS, name)
        rows.append({
            "op": name,
            "o0": o0, "o1": o1, "o3": o3,
            "stage_o0_o1": _stage_cause(o0, o1),
            "stage_o1_o3": _stage_cause(o1, o3),
            "declared": declared[0] if declared else "",
            "lat_o0": measured.get((name, "O0")),
            "lat_o3": measured.get((name, "O3")),
        })
    return rows


def write_attribution(out: TextIO, ops: Iterable[str] | None = None,
                      db=None) -> int:
    """Render the O1/O3 attribution table as markdown; returns row count."""
    rows = attribution_rows(ops, db=db)
    out.write("# Opt-level attribution (O0 -> O1 -> O3)\n\n")
    out.write(
        "Per-step opcode multisets of each registry chain at every opt\n"
        "level, with the transform class responsible for each stage delta\n"
        "(`none` = multiset unchanged; the latency delta at that stage is\n"
        "pure dispatch overhead, the paper's clock-read analog). Generated\n"
        "by `python -m repro audit --attribution`; see docs/audit.md.\n\n")
    out.write("| op | O0 per-step (jaxpr->HLO) | O1 per-step | O3 per-step "
              "| O0->O1 | O1->O3 | declared | O0 ns | O3 ns |\n")
    out.write("|---|---|---|---|---|---|---|---|---|\n")
    for r in rows:
        lat0 = f"{r['lat_o0']:.1f}" if r["lat_o0"] is not None else "-"
        lat3 = f"{r['lat_o3']:.1f}" if r["lat_o3"] is not None else "-"
        out.write(
            f"| `{r['op']}` | {_fmt_multiset(r['o0'])} "
            f"| {_fmt_multiset(r['o1'])} | {_fmt_multiset(r['o3'])} "
            f"| {r['stage_o0_o1']} | {r['stage_o1_o3']} "
            f"| {r['declared'] or '-'} | {lat0} | {lat3} |\n")
    return len(rows)
