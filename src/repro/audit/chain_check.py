"""Static chain-integrity verification of compiled probe artifacts.

The paper's validity claim — and this repo's — is that a timed chain of
length ``n`` really executes ``n`` dependent instances of the target
instruction. The measurement machinery *times* compiled artifacts but this
module *inspects* them: given a probe's two compiled lens it

1. derives the **expected per-step opcode multiset** from the spec's jaxpr
   (the semantic program, before XLA optimizes), mapped through
   :data:`PRIM_TO_HLO` and adjusted by the declared compiler transforms in
   :data:`EXPECTED_TRANSFORMS` (div-by-pow2 becoming shifts, reciprocal
   multiplies, loop-invariant CSE, ... — the paper's Table III effects);
2. checks the **two-lens histogram delta**: the optimized-HLO opcode counts
   at ``n2`` minus those at ``n1`` must be exactly ``(n2-n1)`` x the expected
   per-step multiset — the unstated denominator assumption of
   ``Timer.slope``. ``convert``/``bitcast-convert`` are dtype plumbing
   (bfloat16 chains upcast on CPU backends) and are only required to scale
   *linearly* with the length, never matched against the jaxpr;
3. checks the **guard identity**: the declared guard opcodes
   (:data:`GUARDS`) must sum to ``spec.guard`` and be contained in the
   expected multiset — what makes ``net_latency_ns``'s ``guard x baseline``
   subtraction sound;
4. walks the **dependent-use chain** from the carry parameter to the root
   (inlining fusion/call computations) and asserts every expected op sits on
   that path ``count x n`` times — an op with the right histogram count but
   off the chain was hoisted or parallelized and is not serialized by the
   measurement.

Verdicts are :class:`ChainVerdict`\\ s whose :meth:`~ChainVerdict.note`
serializes into LatencyDB record notes (``audit=ok`` /
``audit=transformed:<cause>`` / ``audit=opaque:...`` /
``audit=unaudited:...``). See docs/audit.md.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Any, Iterable, Mapping

from repro.core import measure
from repro.core.chains import OpSpec, chain_fn, default_registry
from repro.core.hlo_analysis import (STRUCTURAL_OPS, Computation,
                                     dynamic_op_histogram, op_histogram,
                                     parse_module)

# dtype/bit plumbing, never measured arithmetic: required to be linear in the
# chain length but never matched against the jaxpr expectation (XLA CPU
# upcasts bfloat16 chains, inserting converts the jaxpr doesn't have)
PLUMBING_OPS = frozenset({"convert", "bitcast-convert"})

# jax primitive -> HLO opcode(s) it lowers to. Multi-op values are lowering
# *expansions* (exp2 becomes exp(x * log 2)), not optimizations.
PRIM_TO_HLO: dict[str, tuple[str, ...]] = {
    "add": ("add",), "sub": ("subtract",), "mul": ("multiply",),
    "div": ("divide",), "rem": ("remainder",), "neg": ("negate",),
    "abs": ("abs",), "max": ("maximum",), "min": ("minimum",),
    "and": ("and",), "or": ("or",), "xor": ("xor",), "not": ("not",),
    "shift_left": ("shift-left",),
    "shift_right_logical": ("shift-right-logical",),
    "shift_right_arithmetic": ("shift-right-arithmetic",),
    "eq": ("compare",), "ne": ("compare",), "lt": ("compare",),
    "le": ("compare",), "gt": ("compare",), "ge": ("compare",),
    "select_n": ("select",), "convert_element_type": ("convert",),
    "bitcast_convert_type": ("bitcast-convert",),
    "sqrt": ("sqrt",), "rsqrt": ("rsqrt",), "sin": ("sine",),
    "cos": ("cosine",), "log": ("log",), "exp": ("exponential",),
    "exp2": ("exponential", "multiply"), "tanh": ("tanh",),
    "logistic": ("logistic",), "sign": ("sign",),
    "population_count": ("popcnt",), "clz": ("count-leading-zeros",),
    "integer_pow": ("multiply",), "square": ("multiply",),
    "floor": ("floor",), "ceil": ("ceil",),
    "round": ("round-nearest-even",), "is_finite": ("is-finite",),
}

# Declared guard opcodes per spec (with multiplicity). Keyed by the spec name
# with any trailing dtype component stripped (``add.float32`` -> ``add``);
# specs with ``guard == 0`` never consult this table. The guard identity —
# sum of multiplicities == ``spec.guard`` and every guard opcode present in
# the expected per-step multiset — is what licenses the ``guard x baseline``
# subtraction in ``Probe._record``.
GUARDS: dict[str, tuple[str, ...]] = {
    "add": ("xor",), "sub": ("xor",), "mul": ("xor",), "mad": ("xor",),
    "min": ("add",), "max": ("subtract",), "abs": ("subtract",),
    "div.s.regular": ("add",), "div.s.irregular": ("add",),
    "div.s.runtime": ("add",), "div.u.regular": ("add",),
    "div.u.irregular": ("add",), "div.u.runtime": ("add",),
    "rem.s": ("add",), "rem.u": ("add",),
    "and": ("add",), "or": ("add",), "xor": ("add",), "not": ("add",),
    "cnot": ("add",), "shl": ("or",), "shr": ("or",),
    "div.regular": ("add",), "div.irregular": ("add",),
    "div.runtime": ("add",),
    "add.cc": ("xor",), "sub.cc": ("xor",), "mad.cc": ("xor",),
    "mul.wide": ("xor",), "mul64hi": ("or", "shift-right-logical"),
    "rcp": ("add",), "sqrt": ("add",), "rsqrt": ("add",), "sin": ("add",),
    "lg2": ("add",), "ex2": ("subtract",), "tanh": ("add",),
    "copysign": ("add",), "sad": ("add",), "popc": ("xor",),
    "clz": ("add",), "bfe": ("and", "add"), "bfi": ("and", "or"),
    "mul24": ("and", "and"),
}

# Compiler transforms the auditor *expects* at O1/O3, with a named cause:
# (cause, removed per-step opcodes, added per-step opcodes). These encode the
# paper's Table III effects for XLA — a spec matching its transformed
# expectation audits ``ok`` with the cause annotated; anything else is a
# ``transformed:<cause>`` integrity failure.
EXPECTED_TRANSFORMS: dict[str, tuple[str, dict[str, int], dict[str, int]]] = {
    # div by constant pow-2: signed needs a round-toward-zero fixup
    "div.s.regular": ("strength-reduction", {"divide": 1},
                      {"shift-right-logical": 1, "select": 2, "negate": 2,
                       "compare": 1}),
    "div.u.regular": ("strength-reduction", {"divide": 1},
                      {"shift-right-logical": 1}),
    # float div by any constant: reciprocal multiply
    "div.regular": ("strength-reduction", {"divide": 1}, {"multiply": 1}),
    "div.irregular": ("strength-reduction", {"divide": 1}, {"multiply": 1}),
    # log2(x) traces as log(x)/log(2); XLA folds 1/log(2) into a multiply
    "lg2": ("strength-reduction", {"log": 1, "divide": 1}, {"multiply": 1}),
    # the sign-bit test and one of the two |x| lowerings simplify away
    "copysign": ("algebraic-simplification",
                 {"shift-right-arithmetic": 1, "abs": 1}, {}),
    # the (a & mask) operand-side masks are loop-invariant and CSE'd
    "bfi": ("loop-invariant-cse", {"and": 1}, {}),
    "mul24": ("loop-invariant-cse", {"and": 1}, {}),
}

_DTYPE_TOKENS = frozenset({"float32", "float64", "float16", "bfloat16",
                           "int32", "int64", "uint32", "uint64"})


def base_name(op: str) -> str:
    """Spec name with trailing dtype components stripped
    (``div.regular.float32`` -> ``div.regular``)."""
    parts = op.split(".")
    while len(parts) > 1 and parts[-1] in _DTYPE_TOKENS:
        parts.pop()
    return ".".join(parts)


def _lookup(table: Mapping[str, Any], op: str) -> Any:
    for key in (op, base_name(op)):
        if key in table:
            return table[key]
    return None


# --------------------------------------------------------------- jaxpr side
def _count_eqns(jaxpr, counts: Counter) -> None:
    for eqn in jaxpr.eqns:
        sub = [v for k, v in eqn.params.items()
               if k in ("jaxpr", "call_jaxpr") and v is not None]
        if sub:
            for s in sub:
                _count_eqns(getattr(s, "jaxpr", s), counts)
        else:
            counts[eqn.primitive.name] += 1


def prim_counts(fn, *args) -> Counter:
    """Primitive histogram of ``fn``'s jaxpr (recursing through pjit/call)."""
    import jax

    counts: Counter = Counter()
    _count_eqns(jax.make_jaxpr(fn)(*args).jaxpr, counts)
    return counts


def step_prim_counts(spec: OpSpec) -> Counter:
    """One chain step's primitive histogram — the semantic program."""
    with measure._x64_ctx(spec):
        return prim_counts(spec.step, spec.carry(), *spec.operand_arrays())


@dataclasses.dataclass(frozen=True)
class ExpectedStep:
    """Per-step opcode expectation for one spec at one opt level."""

    counts: Counter              # countable HLO opcodes per step (optimized)
    guards: Counter              # declared guard opcodes (subset of counts)
    transform: str = ""          # named expected-transform cause, "" if none
    unknown: tuple[str, ...] = ()  # jaxpr primitives with no HLO mapping

    @property
    def targets(self) -> Counter:
        return self.counts - self.guards


def expected_step(spec: OpSpec, opt_level: str) -> ExpectedStep:
    """Derive the expected optimized per-step multiset for ``spec``.

    jaxpr primitives -> :data:`PRIM_TO_HLO` -> :data:`EXPECTED_TRANSFORMS`
    (O1/O3 only; eager dispatch executes the jaxpr as-is and cannot fold).
    """
    counts: Counter = Counter()
    unknown: list[str] = []
    for prim, k in step_prim_counts(spec).items():
        hlo = PRIM_TO_HLO.get(prim)
        if hlo is None:
            unknown.append(prim)
            continue
        for opcode in hlo:
            if opcode not in PLUMBING_OPS:
                counts[opcode] += k
    transform = ""
    if opt_level in ("O1", "O3"):
        override = _lookup(EXPECTED_TRANSFORMS, spec.name)
        if override is not None:
            cause, remove, add = override
            removed = Counter(remove)
            if removed - counts:
                # the declared transform doesn't apply to this program shape
                unknown.append(f"transform:{cause}")
            else:
                counts = counts - removed + Counter(add)
                transform = cause
    guards = Counter(_lookup(GUARDS, spec.name) or ()) if spec.guard else Counter()
    return ExpectedStep(counts=counts, guards=guards, transform=transform,
                        unknown=tuple(unknown))


# ----------------------------------------------------------------- HLO side
def chain_hlo_text(spec: OpSpec, n: int, opt_level: str, *,
                   cache: Any = None, env: Mapping[str, str] | None = None
                   ) -> str:
    """Optimized HLO of one chain compile; cache sidecars are peeked first.

    A measurement run through a :class:`CompileCache` rides the HLO text into
    the entry's ``extra`` payload (``measure.compile_chain``), so auditing a
    warm cache never re-invokes XLA.
    """
    import jax

    if cache is not None and env is not None:
        text = cache.peek_extra(measure.chain_cache_key(spec, n, opt_level, env))
        if text:
            return text
    with measure._x64_ctx(spec):
        fn = chain_fn(spec, n)
        lowered = jax.jit(fn).lower(spec.carry(), *spec.operand_arrays())
        if opt_level == "O1":
            from repro.core.optlevels import _o1_options

            opts = _o1_options()
            compiled = (lowered.compile(compiler_options=opts) if opts
                        else lowered.compile())
        else:
            compiled = lowered.compile()
        return compiled.as_text()


def hist_counts(hlo_text: str) -> tuple[Counter, Counter]:
    """Flat ``(countable, plumbing)`` opcode histograms of a module."""
    countable: Counter = Counter()
    plumbing: Counter = Counter()
    for (opcode, _elems), cnt in op_histogram(hlo_text).items():
        if opcode in PLUMBING_OPS:
            plumbing[opcode] += cnt
        elif opcode not in STRUCTURAL_OPS:
            countable[opcode] += cnt
    return countable, plumbing


# ------------------------------------------------------ dependent-path walk
_PARAM_IDX_RE = re.compile(r"\s*(\d+)")
_CALLEE_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")


def _inline_graph(comps: dict[str, Computation]
                  ) -> tuple[dict[str, tuple[str, list[str]]],
                             str | None, dict[int, str], bool]:
    """Flatten fusion/call computations reachable from the entry into one SSA
    graph: ``(graph, root, entry_params, has_loop)`` where graph maps
    qualified op name -> (opcode, global operand names) in program order."""
    entry = comps.get("__entry__")
    graph: dict[str, tuple[str, list[str]]] = {}
    entry_params: dict[int, str] = {}
    has_loop = False

    def emit(comp: Computation, prefix: str,
             param_names: list[str] | None) -> str | None:
        nonlocal has_loop
        rename: dict[str, str] = {}
        root = last = None
        for op in comp.ops:
            if op.opcode == "parameter":
                m = _PARAM_IDX_RE.match(op.rest)
                idx = int(m.group(1)) if m else -1
                if param_names is not None and 0 <= idx < len(param_names):
                    rename[op.name] = param_names[idx]
                else:
                    rename[op.name] = prefix + op.name
                    if prefix == "":
                        entry_params[idx] = op.name
                last = rename[op.name]
                continue
            qn = prefix + op.name
            operands = [rename.get(o, o) for o in op.operands]
            if op.opcode in ("fusion", "call"):
                m = _CALLEE_RE.search(op.rest)
                sub = comps.get(m.group(1)) if m else None
                if sub is not None:
                    sub_root = emit(sub, qn + "/", operands)
                    if sub_root is not None:
                        rename[op.name] = sub_root
                        last = sub_root
                        if op.is_root:
                            root = sub_root
                        continue
            if op.opcode == "while":
                has_loop = True
            graph[qn] = (op.opcode, operands)
            rename[op.name] = qn
            last = qn
            if op.is_root:
                root = qn
        return root if root is not None else last

    if entry is None:
        return graph, None, entry_params, has_loop
    root = emit(entry, "", None)
    return graph, root, entry_params, has_loop


def path_counts(hlo_text: str, source_param: int = 0) -> Counter:
    """Opcode counts on the dependent path carry-parameter -> root.

    Forward reach from entry parameter ``source_param`` intersected with
    backward reach from the ROOT op, fusion/call computations inlined. An op
    is *on the path* when it both consumes the carry (transitively) and
    feeds the result — exactly the ops ``Timer.slope`` serializes.
    """
    graph, root, entry_params, _ = _inline_graph(parse_module(hlo_text))
    src = entry_params.get(source_param)
    if root is None or src is None:
        return Counter()
    reach = {src}
    for name, (_opcode, operands) in graph.items():  # SSA order: one pass
        if any(o in reach for o in operands):
            reach.add(name)
    needed = {root}
    for name in reversed(list(graph)):
        if name in needed:
            for o in graph[name][1]:
                needed.add(o)
    counts: Counter = Counter()
    for name in reach & needed:
        if name in graph:
            opcode = graph[name][0]
            if opcode not in STRUCTURAL_OPS and opcode not in PLUMBING_OPS:
                counts[opcode] += 1
    return counts


def root_is_constant(hlo_text: str) -> bool:
    """True when the entry ROOT does not depend on any entry parameter —
    the whole chain folded to a compile-time constant."""
    graph, root, entry_params, _ = _inline_graph(parse_module(hlo_text))
    if root is None:
        return False
    params = set(entry_params.values())
    needed = {root}
    for name in reversed(list(graph)):
        if name in needed:
            for o in graph[name][1]:
                needed.add(o)
    return not (needed & params)


# ------------------------------------------------------------------ verdict
@dataclasses.dataclass(frozen=True)
class ChainVerdict:
    """Outcome of one static integrity check.

    ``status``: ``ok`` (chain count + guard accounting exact), ``audited``
    (the Pallas kernel jaxpr itself was opened and certified by
    ``repro.audit.dataflow`` — serialization + residency + signature),
    ``transformed`` (the compiler broke the chain assumption; ``cause``
    names the pass family), ``opaque`` (artifact is not inspectable),
    ``unaudited`` (no checker covers this record family or the environment
    doesn't match).
    """

    op: str
    opt_level: str
    status: str
    cause: str = ""
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "audited")

    @property
    def failed(self) -> bool:
        return self.status == "transformed"

    def note(self) -> str:
        """The ``audit=...`` token persisted into LatencyDB record notes."""
        if self.status == "ok":
            tok = "audit=ok"
            if self.cause:
                tok += f" audit_transform={self.cause}"
            return tok
        if self.cause:
            return f"audit={self.status}:{self.cause}"
        return f"audit={self.status}"


def _verdict_from_note(op: str, opt_level: str, notes: str
                       ) -> ChainVerdict | None:
    """Parse a persisted ``audit=`` token back into a verdict, or None."""
    from repro.utils import parse_kv_notes

    kv = parse_kv_notes(notes)
    tok = kv.get("audit")
    if not tok:
        return None
    status, _, cause = tok.partition(":")
    if status == "ok":
        cause = kv.get("audit_transform", "")
    return ChainVerdict(op=op, opt_level=opt_level, status=status, cause=cause)


def _delta(c2: Counter, c1: Counter) -> dict[str, int]:
    return {k: c2.get(k, 0) - c1.get(k, 0)
            for k in set(c2) | set(c1)
            if c2.get(k, 0) != c1.get(k, 0)}


def _fmt(counts: Mapping[str, int]) -> str:
    return " ".join(f"{k}:{v}" for k, v in sorted(counts.items())) or "(none)"


# ----------------------------------------------------------- spec auditing
def audit_spec(spec: OpSpec, opt_level: str, *, cache: Any = None,
               env: Mapping[str, str] | None = None,
               lens: tuple[int, int] | None = None) -> ChainVerdict:
    """Full chain-integrity check of one registry spec at one opt level."""
    if lens is None:
        n1, n2 = measure._CHAIN_LENS[opt_level]
        if spec.max_chain is not None:
            n1, n2 = min(n1, spec.max_chain // 3), min(n2, spec.max_chain)
    else:
        n1, n2 = lens
    if opt_level == "O0":
        return _audit_spec_eager(spec, (n1, n2))

    exp = expected_step(spec, opt_level)
    if exp.unknown:
        return ChainVerdict(spec.name, opt_level, "unaudited",
                            cause="unmapped-primitive",
                            detail=f"no HLO mapping for {exp.unknown}")
    # guard identity: declared guard count must equal the declared guard
    # opcodes, and those opcodes must exist in the expected multiset
    if sum(exp.guards.values()) != spec.guard or (exp.guards - exp.counts):
        return ChainVerdict(
            spec.name, opt_level, "transformed", cause="guard-mismatch",
            detail=f"spec.guard={spec.guard} but declared guard ops "
                   f"[{_fmt(exp.guards)}] vs expected step [{_fmt(exp.counts)}]")

    texts = {n: chain_hlo_text(spec, n, opt_level, cache=cache, env=env)
             for n in (n1, n2)}
    c1, p1 = hist_counts(texts[n1])
    c2, p2 = hist_counts(texts[n2])
    if c1.get("custom-call") or c2.get("custom-call"):
        return ChainVerdict(spec.name, opt_level, "opaque",
                            cause="custom-call",
                            detail="artifact contains an opaque custom-call")
    dn = n2 - n1
    observed = _delta(c2, c1)
    expected = {k: v * dn for k, v in exp.counts.items()}
    if observed != expected:
        from repro.audit.transforms import classify

        cause = classify(Counter(expected), Counter({k: v for k, v
                                                     in observed.items()
                                                     if v > 0}),
                         hlo_text=texts[n2])
        return ChainVerdict(
            spec.name, opt_level, "transformed", cause=cause,
            detail=f"lens {n1}->{n2}: expected delta [{_fmt(expected)}], "
                   f"got [{_fmt(observed)}]")
    # plumbing (convert) must scale linearly: a constant per-step count
    for opcode in set(p1) | set(p2):
        d = p2.get(opcode, 0) - p1.get(opcode, 0)
        if d < 0 or d % dn != 0:
            return ChainVerdict(
                spec.name, opt_level, "transformed", cause="plumbing-nonlinear",
                detail=f"{opcode} delta {d} over {dn} steps is not an "
                       f"integer per-step count")
    # dependent-path walk: every expected op must sit ON the carry->root
    # chain count x n2 times (right histogram but off the path => hoisted)
    pc = path_counts(texts[n2])
    want = {k: v * n2 for k, v in exp.counts.items()}
    if dict(pc) != want:
        return ChainVerdict(
            spec.name, opt_level, "transformed", cause="hoisted",
            detail=f"on-path counts [{_fmt(pc)}] != expected "
                   f"[{_fmt(want)}] at len {n2}")
    return ChainVerdict(spec.name, opt_level, "ok", cause=exp.transform)


def _audit_spec_eager(spec: OpSpec, lens: tuple[int, int]) -> ChainVerdict:
    """O0 check: eager dispatch executes the jaxpr as-is, so integrity is
    verified at the jaxpr level — the chain's primitive delta must be exactly
    ``(n2-n1)`` x the one-step primitives."""
    n1, n2 = lens
    with measure._x64_ctx(spec):
        args = (spec.carry(), *spec.operand_arrays())
        c1 = prim_counts(chain_fn(spec, n1), *args)
        c2 = prim_counts(chain_fn(spec, n2), *args)
    step = step_prim_counts(spec)
    dn = n2 - n1
    observed = _delta(c2, c1)
    expected = {k: v * dn for k, v in step.items()}
    if observed != expected:
        from repro.audit.transforms import classify

        cause = classify(Counter(expected),
                         Counter({k: v for k, v in observed.items() if v > 0}))
        return ChainVerdict(
            spec.name, "O0", "transformed", cause=cause,
            detail=f"jaxpr delta over lens {n1}->{n2}: expected "
                   f"[{_fmt(expected)}], got [{_fmt(observed)}]")
    return ChainVerdict(spec.name, "O0", "ok")


# ----------------------------------------------- non-instruction artifacts
def audit_clock_overhead(opt_level: str) -> ChainVerdict:
    """The null timed region must contain zero countable ops."""
    import jax
    import jax.numpy as jnp

    if opt_level == "O0":
        c = prim_counts(lambda v: v, jnp.asarray(1.0, jnp.float32))
        if c:
            return ChainVerdict("clock_overhead", "O0", "transformed",
                                cause="non-empty-null-region",
                                detail=f"jaxpr primitives: {_fmt(c)}")
        return ChainVerdict("clock_overhead", "O0", "ok")
    x = jnp.asarray(1.0, jnp.float32)
    text = jax.jit(lambda v: v).lower(x).compile().as_text()
    countable, _ = hist_counts(text)
    if countable:
        return ChainVerdict("clock_overhead", opt_level, "transformed",
                            cause="non-empty-null-region",
                            detail=f"countable ops: {_fmt(countable)}")
    return ChainVerdict("clock_overhead", opt_level, "ok")


# memory-load opcodes a compiled chase may legitimately use per step
_CHASE_LOAD_OPS = ("dynamic-slice", "gather")


def audit_chase(working_set_bytes: int, steps: tuple[int, int],
                line_bytes: int = 64, *, cache: Any = None,
                env: Mapping[str, str] | None = None,
                op: str | None = None) -> ChainVerdict:
    """Host pointer chase: the trip-weighted delta between the two step
    counts must contain exactly one dependent load per step."""
    import jax

    from repro.core import membench

    op = op or f"mem.chase.ws{working_set_bytes}"
    texts = {}
    for n in steps:
        text = None
        if cache is not None and env is not None:
            text = cache.peek_extra(
                membench.chase_cache_key(working_set_bytes, n, line_bytes, env))
        if not text:
            ring, _ = membench.build_ring(working_set_bytes, line_bytes)
            import jax.numpy as jnp

            start = jnp.asarray(0, jnp.int32)
            text = (jax.jit(membench.chase_fn(n)).lower(ring, start)
                    .compile().as_text())
        texts[n] = text
    s1, s2 = steps
    d1 = dynamic_op_histogram(texts[s1])
    d2 = dynamic_op_histogram(texts[s2])
    loads1 = sum(v for (opc, _e), v in d1.items() if opc in _CHASE_LOAD_OPS)
    loads2 = sum(v for (opc, _e), v in d2.items() if opc in _CHASE_LOAD_OPS)
    per_step = (loads2 - loads1) / (s2 - s1)
    if per_step != 1.0:
        cause = "hoisted" if per_step < 1.0 else "duplicated-load"
        return ChainVerdict(
            op, "O3", "transformed", cause=cause,
            detail=f"dependent loads/step = {per_step:g} over steps "
                   f"{s1}->{s2} (expected exactly 1)")
    return ChainVerdict(op, "O3", "ok")


def audit_collective(kind: str, devices: int, payload_bytes: int,
                     lens: tuple[int, int] | None = None, *,
                     cache: Any = None,
                     env: Mapping[str, str] | None = None,
                     op: str | None = None) -> ChainVerdict:
    """Collective-ladder chain (``coll.<kind>.d<N>.<bytes>`` rows).

    Two checks, mirroring the instruction-chain auditor on the SPMD module:

    1. **histogram delta** — the collective opcodes of the optimized HLO at
       the two lens must differ by exactly ``(n2-n1)`` ops of the *expected*
       HLO kind and nothing else (an all-gather rewritten into an all-reduce,
       or a folded-away collective, breaks the slope's denominator);
    2. **serialized dependence** — every one of the ``n2`` collectives must
       sit ON the carry->root dependent path: right count but off the path
       means XLA parallelized the chain and the slope no longer measures a
       dependent collective.

    Success is ``audited`` (the SPMD artifact was opened and certified), a
    backend with too few devices is ``unaudited:insufficient-devices`` —
    never silently ok.
    """
    from repro.core.hlo_analysis import COLLECTIVE_KINDS, LADDER_TO_COLLECTIVE
    from repro.parallel import ladders

    op = op or f"coll.{kind}.d{devices}.{payload_bytes}"
    if kind not in LADDER_TO_COLLECTIVE:
        return ChainVerdict(op, "O3", "unaudited", cause="unknown-kind")
    if lens is None:
        lens = tuple(ladders.DEFAULT_LENS)
    import jax

    if devices > jax.device_count():
        return ChainVerdict(
            op, "O3", "unaudited", cause="insufficient-devices",
            detail=f"row needs {devices} devices, backend has "
                   f"{jax.device_count()}")
    hlo_kind = LADDER_TO_COLLECTIVE[kind]
    n1, n2 = lens
    try:
        texts = {n: ladders.chain_hlo_text(kind, payload_bytes, devices, n,
                                           op=op, cache=cache, env=env)
                 for n in (n1, n2)}
    except Exception as e:  # noqa: BLE001 - uncompilable artifact
        return ChainVerdict(op, "O3", "opaque", cause="rebuild-failed",
                            detail=str(e)[:200])

    def coll_hist(text: str) -> Counter:
        c: Counter = Counter()
        for (opcode, _e), cnt in op_histogram(text).items():
            if opcode.endswith("-done"):
                continue            # async pair: count the -start only
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in COLLECTIVE_KINDS:
                c[base] += cnt
        return c

    dn = n2 - n1
    observed = _delta(coll_hist(texts[n2]), coll_hist(texts[n1]))
    if observed != {hlo_kind: dn}:
        return ChainVerdict(
            op, "O3", "transformed", cause="rewritten-collective",
            detail=f"lens {n1}->{n2}: expected delta [{hlo_kind}:{dn}], "
                   f"got [{_fmt(observed)}]")
    # dependence walk: the carry (entry param 0) must thread through every
    # collective to the root — a collective with the right count but off the
    # path was hoisted/parallelized and is not serialized by the slope
    pc = path_counts(texts[n2])
    on_path = sum(v for k, v in pc.items()
                  if k == hlo_kind or k == f"{hlo_kind}-start")
    if on_path != n2:
        return ChainVerdict(
            op, "O3", "transformed", cause="hoisted",
            detail=f"{on_path} of {n2} {hlo_kind} ops on the carry->root "
                   f"dependent path")
    return ChainVerdict(op, "O3", "audited",
                        detail=f"{dn} serialized {hlo_kind} steps/len over "
                               f"d{devices}")


# per-step opcode expectation of the Pallas alu_chain kernel body
KERNEL_STEP_OPS: dict[str, dict[str, int]] = {
    "fma": {"multiply": 1, "add": 1},
    "add": {"add": 1},
    "mul": {"multiply": 1},
    "rsqrt": {"rsqrt": 1, "add": 1},
    "exp": {"exponential": 1, "add": 1, "negate": 1},
}


def audit_kernel(kernel_op: str, lens: tuple[int, int],
                 shape: tuple[int, int] = (8, 128), *,
                 op: str | None = None) -> ChainVerdict:
    """In-kernel (Pallas) ALU chain. In interpret mode (CPU) the kernel
    inlines into plain HLO and gets the full delta check; a real-hardware
    lowering is an opaque custom-call and is reported as such rather than
    silently passed."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import alu_chain

    op = op or f"kernel.alu_chain.{kernel_op}"
    step = KERNEL_STEP_OPS.get(kernel_op)
    if step is None:
        return ChainVerdict(op, "O3", "unaudited", cause="unknown-kernel-op")
    x = jnp.full(shape, 1.0, jnp.float32)
    a = jnp.full(shape, 0.5, jnp.float32)
    counts = {}
    for n in lens:
        fn = lambda x, a, n=n: alu_chain(x, a, n=n, op=kernel_op)  # noqa: E731
        text = jax.jit(fn).lower(x, a).compile().as_text()
        c, _ = hist_counts(text)
        if c.get("custom-call"):
            return ChainVerdict(op, "O3", "opaque", cause="custom-call",
                                detail="real (non-interpret) Pallas lowering")
        counts[n] = c
    n1, n2 = lens
    dn = n2 - n1
    observed = _delta(counts[n2], counts[n1])
    expected = {k: v * dn for k, v in step.items()}
    if observed != expected:
        from repro.audit.transforms import classify

        cause = classify(Counter(expected),
                         Counter({k: v for k, v in observed.items() if v > 0}))
        return ChainVerdict(
            op, "O3", "transformed", cause=cause,
            detail=f"lens {n1}->{n2}: expected delta [{_fmt(expected)}], "
                   f"got [{_fmt(observed)}]")
    return ChainVerdict(op, "O3", "ok")


# ------------------------------------------------------------ dispatching
_MEM_RE = re.compile(r"^mem\.chase\.ws(\d+)(?:\.s(\d+)-(\d+))?(?:\.line(\d+))?$")
_KERNEL_RE = re.compile(
    r"^kernel\.alu_chain\.([a-z0-9]+)(?:\.l(\d+)-(\d+))?(?:\.t(\d+)x(\d+))?$")
# Pallas-row grammars (see api/probes.py op construction): fused rows,
# in-kernel memory chase rows, then the generic in-kernel chain rows whose
# base is a registry spec name (may itself contain dots)
_COLL_RE = re.compile(
    r"^coll\.(psum|all_gather|reduce_scatter|ppermute)\.d(\d+)\.(\d+)"
    r"(?:\.l(\d+)-(\d+))?$")
_FUSED_RE = re.compile(r"^inkernel\.fused\.([a-z0-9_]+)(?:\.l(\d+)-(\d+))?$")
_INKERNEL_MEM_RE = re.compile(
    r"^inkernel\.mem\.(\d+)(?:\.l(\d+)-(\d+))?(?:\.line(\d+))?"
    r"(?:\.(vmem|any))?$")
_INKERNEL_OP_RE = re.compile(
    r"^inkernel\.(.+?)(?:\.l(\d+)-(\d+))?(?:\.t(\d+)x(\d+))?$")


def _audit_pallas_row(op: str, opt_level: str,
                      registry: Iterable[OpSpec] | None) -> ChainVerdict:
    """Route an ``inkernel.*`` row to the dataflow auditor: the kernel jaxpr
    is opened and certified (serialization/residency/signature) instead of
    the old blanket ``unaudited: pallas-fori-loop`` answer."""
    from repro.audit import dataflow

    m = _FUSED_RE.match(op)
    if m:
        lens = ((int(m.group(2)), int(m.group(3))) if m.group(2) else None)
        return dataflow.audit_fused(m.group(1), opt_level, op=op, lens=lens)
    m = _INKERNEL_MEM_RE.match(op)
    if m:
        lens = ((int(m.group(2)), int(m.group(3))) if m.group(2) else None)
        line = int(m.group(4)) if m.group(4) else 64
        return dataflow.audit_inkernel_mem(
            int(m.group(1)), opt_level, op=op, space=m.group(5),
            line_bytes=line, lens=lens)
    m = _INKERNEL_OP_RE.match(op)
    if m:
        specs = list(registry) if registry is not None else default_registry()
        spec = next((s for s in specs if s.name == m.group(1)), None)
        if spec is not None:
            lens = ((int(m.group(2)), int(m.group(3))) if m.group(2)
                    else None)
            shape = ((int(m.group(4)), int(m.group(5))) if m.group(4)
                     else None)
            return dataflow.audit_inkernel_op(spec, opt_level, op=op,
                                              lens=lens, shape=shape)
    return ChainVerdict(op, opt_level, "unaudited", cause="unknown-kernel",
                        detail="no registry spec or builder for this row")


def audit_target(op: str, opt_level: str, *, cache: Any = None,
                 env: Mapping[str, str] | None = None,
                 registry: Iterable[OpSpec] | None = None) -> ChainVerdict:
    """Audit whatever artifact the record row ``op@opt_level`` was measured
    from. Rows no static checker covers come back ``unaudited`` with a
    reason, never silently ``ok``."""
    if op == "clock_overhead":
        return audit_clock_overhead(opt_level)
    m = _MEM_RE.match(op)
    if m:
        ws = int(m.group(1))
        steps = ((int(m.group(2)), int(m.group(3))) if m.group(2)
                 else (2048, 6144))
        line = int(m.group(4)) if m.group(4) else 64
        return audit_chase(ws, steps, line, cache=cache, env=env, op=op)
    m = _KERNEL_RE.match(op)
    if m:
        from repro.audit import dataflow

        lens = ((int(m.group(2)), int(m.group(3))) if m.group(2) else (8, 64))
        shape = ((int(m.group(4)), int(m.group(5))) if m.group(4)
                 else (8, 128))
        return dataflow.audit_alu_kernel(m.group(1), opt_level, op=op,
                                         lens=lens, tile=shape)
    m = _COLL_RE.match(op)
    if m:
        lens = ((int(m.group(4)), int(m.group(5))) if m.group(4) else None)
        return audit_collective(m.group(1), int(m.group(2)), int(m.group(3)),
                                lens, cache=cache, env=env, op=op)
    if op.startswith(("serving.", "slo.")):
        return ChainVerdict(op, opt_level, "unaudited", cause="consumer-row",
                            detail="predicted-vs-measured consumer record; "
                                   "integrity rides on the rows it prices")
    if op.startswith("inkernel."):
        return _audit_pallas_row(op, opt_level, registry)
    specs = list(registry) if registry is not None else default_registry()
    spec = next((s for s in specs if s.name == op), None)
    if spec is not None:
        return audit_spec(spec, opt_level, cache=cache, env=env)
    return ChainVerdict(op, opt_level, "unaudited", cause="unknown-family")
