"""Dataflow certificates for Pallas kernels: look *inside* the custom call.

Every other auditor in ``repro.audit`` stops at the custom-call boundary:
the compiled HLO shows one opaque ``custom-call`` and the chain audit can
only answer ``opaque: custom-call``. But the artifact Pallas lowers — the
kernel's closed jaxpr — is sitting right there in the traced program, and
it is exactly the def-use graph the paper's validity argument needs. This
module traces a kernel builder with :func:`jax.make_jaxpr`, finds each
``pallas_call`` equation, and derives three certificates from the kernel
jaxpr + grid mapping:

**serialization** (:class:`ChainCert`)
    The measured carry really is one dependent chain: every countable op
    that depends on the carry lies on a single def-use path from the
    carry-in to the carry-out (scan-carried chains) or from the input refs
    to the stored value (unrolled chains). A parallel shortcut — two
    independent sub-chains recombined — shows up as ``count > depth`` and
    is rejected, as is a body that never reads the carry. Ref-mediated
    dependence (DMA into a scratch ref that is then read) is tracked by
    propagating depth through written refs, so the HBM pointer chase's
    ``dma_start -> dma_wait -> get`` step counts as a dependent load.

**residency** (:class:`RefCert`)
    Each operand/output ref's block ``memory_space`` (VMEM by default,
    ANY for HBM-streamed refs) read from the grid mapping — the PR 4
    VMEM-vs-ANY contract, now checked for every kernel from its lowering
    artifact instead of trusted from ``chase_in_specs``.

**signature** (:attr:`KernelCert.ops` + :attr:`KernelCert.hbm_bytes`)
    The per-invocation op multiset (scan-trip- and grid-weighted, mapped
    through :data:`~repro.audit.chain_check.PRIM_TO_HLO`) and the HBM
    traffic implied by the block mappings (distinct blocks per ref, found
    by evaluating each index map over the grid, x block bytes). Two
    signatures at two chain lengths give the *unit* signature — the exact
    denominator :meth:`Timer.slope` divides by — via the linearity check
    in :func:`audit_fused`.

Chain-family audits (:func:`audit_inkernel_op`, :func:`audit_inkernel_mem`,
:func:`audit_alu_kernel`) certify at two lengths, exactly mirroring the
two-length slope measurement; fused kernels (:func:`audit_fused`) certify
signature linearity instead, since their "length" is a workload size, not
a carry chain. Successful verdicts carry the new ``audited`` status:
stronger than ``ok`` (the artifact was opened, not just matched) and
round-tripping through record notes as ``audit=audited``.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from collections import Counter
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
from jax import core as jax_core

from repro.audit.chain_check import PLUMBING_OPS, PRIM_TO_HLO, ChainVerdict

# ---------------------------------------------------------------- op classes
# jax primitives that move data through refs/memory; never counted as
# arithmetic but counted as loads when they sit on the dependent path
MEMORY_PRIMS = frozenset({
    "get", "swap", "masked_load", "masked_swap", "load", "store",
    "dma_start", "dma_wait", "copy", "addupdate", "broadcast_to",
})

# shape/index plumbing and grid bookkeeping: zero-cost in the certificate
STRUCTURAL_PRIMS = frozenset({
    "broadcast_in_dim", "squeeze", "slice", "dynamic_slice",
    "dynamic_update_slice", "reshape", "transpose", "concatenate", "pad",
    "iota", "program_id", "num_programs", "rev", "stop_gradient",
    "reduce_precision", "expand_dims",
})

# primitives that appear inside fused kernels but not in PRIM_TO_HLO (the
# instruction-table mapping only covers registry ops); mapped here so the
# signature multiset stays in HLO vocabulary
EXTRA_PRIM_TO_HLO: dict[str, tuple[str, ...]] = {
    "dot_general": ("dot",),
    "reduce_sum": ("reduce",), "reduce_max": ("reduce",),
    "reduce_min": ("reduce",), "reduce_and": ("reduce",),
    "reduce_or": ("reduce",), "argmax": ("reduce",), "argmin": ("reduce",),
    "cumsum": ("reduce",), "cumlogsumexp": ("reduce",),
    "log1p": ("log-plus-one",), "expm1": ("exponential-minus-one",),
    "erf": ("erf",), "erfc": ("erfc",), "atan2": ("atan2",),
    "pow": ("power",), "nextafter": ("next-after",),
}


def _hlo_ops(prim: str) -> tuple[str, ...]:
    """HLO opcodes a countable primitive lowers to ('' family-unknown ->
    kept under ``prim:<name>`` so nothing silently vanishes)."""
    if prim in PRIM_TO_HLO:
        return tuple(o for o in PRIM_TO_HLO[prim] if o not in PLUMBING_OPS)
    if prim in EXTRA_PRIM_TO_HLO:
        return EXTRA_PRIM_TO_HLO[prim]
    return (f"prim:{prim}",)


def _weight(prim: str) -> int:
    """Countable-op weight of one primitive application (0 = plumbing)."""
    if prim in MEMORY_PRIMS or prim in STRUCTURAL_PRIMS:
        return 0
    return len(_hlo_ops(prim))


def _is_ref(v: Any) -> bool:
    aval = getattr(v, "aval", None)
    return aval is not None and "Ref" in type(aval).__name__


def _as_jaxpr(v: Any):
    """Unwrap a Jaxpr/ClosedJaxpr param value, else None."""
    inner = getattr(v, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(v, "eqns") and hasattr(v, "invars"):
        return v
    return None


class DataflowError(ValueError):
    """A builder did not trace to exactly one auditable pallas_call."""


# -------------------------------------------------------------- certificates
@dataclasses.dataclass(frozen=True)
class RefCert:
    """Residency + traffic certificate for one kernel ref."""
    index: int
    kind: str                 # "in" | "out"
    space: str                # "vmem" | "any"
    block_shape: tuple[int, ...]
    block_bytes: int
    distinct_blocks: int

    @property
    def hbm_bytes(self) -> int:
        return self.block_bytes * self.distinct_blocks


@dataclasses.dataclass(frozen=True)
class ChainCert:
    """Serialization certificate for the measured dependence chain."""
    kind: str                 # "scan" | "straightline" | "none"
    serialized: bool
    length: int               # scan trip count / straightline path depth
    depth: int                # countable ops on the carry path per iteration
    loads: int                # memory ops on the carry path per iteration
    body_ops: Counter         # per-iteration countable multiset on the path
    cause: str = ""


@dataclasses.dataclass(frozen=True)
class KernelCert:
    """Full dataflow certificate for one pallas_call."""
    name: str
    grid: tuple[int, ...]
    ops: Counter              # per-invocation HLO-mapped countable multiset
    mem_ops: Counter          # per-invocation memory-primitive multiset
    refs: tuple[RefCert, ...]
    chain: ChainCert

    @property
    def hbm_bytes(self) -> int:
        return sum(r.hbm_bytes for r in self.refs)

    def signature(self) -> str:
        """Canonical one-line signature: sorted op multiset + HBM bytes."""
        ops = " ".join(f"{k}={v}" for k, v in sorted(self.ops.items()))
        return f"{ops or 'none'} bytes={self.hbm_bytes}"


# ------------------------------------------------------- primitive counting
def _count_ops(jaxpr, weight: int, ops: Counter, mem: Counter) -> None:
    """Weighted recursive op count: scan bodies x trip count, cond branches
    by elementwise max (the taken work branch), calls inlined."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            body = _as_jaxpr(eqn.params["jaxpr"])
            _count_ops(body, weight * int(eqn.params["length"]), ops, mem)
        elif prim == "cond":
            best_ops: Counter = Counter()
            best_mem: Counter = Counter()
            for br in eqn.params["branches"]:
                b_ops: Counter = Counter()
                b_mem: Counter = Counter()
                _count_ops(_as_jaxpr(br), 1, b_ops, b_mem)
                for k in set(best_ops) | set(b_ops):
                    best_ops[k] = max(best_ops[k], b_ops[k])
                for k in set(best_mem) | set(b_mem):
                    best_mem[k] = max(best_mem[k], b_mem[k])
            for k, v in best_ops.items():
                ops[k] += weight * v
            for k, v in best_mem.items():
                mem[k] += weight * v
        elif prim == "while":
            _count_ops(_as_jaxpr(eqn.params["body_jaxpr"]), weight, ops, mem)
        else:
            sub = None
            for key in ("jaxpr", "call_jaxpr"):
                if key in eqn.params:
                    sub = _as_jaxpr(eqn.params[key])
                    break
            if sub is not None:
                _count_ops(sub, weight, ops, mem)
            elif prim in MEMORY_PRIMS:
                mem[prim] += weight
            elif _weight(prim):
                for hlo in _hlo_ops(prim):
                    ops[hlo] += weight
            # structural / plumbing: dropped


# ------------------------------------------------------ dependence analysis
def _trace_path(eqns, seeds: dict[Any, int], ref_seeds: Iterable[Any] = ()
                ) -> tuple[dict[Any, int], int, int, Counter]:
    """Walk ``eqns`` in program order propagating dependence depth from
    ``seeds`` (var -> starting depth). Returns (depth-by-var, countable op
    count on the dependent subgraph, dependent memory-op count, countable
    multiset). Ref-typed vars written by a dependent eqn carry the depth to
    later reads (DMA-through-scratch serialization)."""
    depth = dict(seeds)
    ref_depth: dict[Any, int] = {r: 0 for r in ref_seeds}
    count = 0
    loads = 0
    ops: Counter = Counter()
    for eqn in eqns:
        prim = eqn.primitive.name
        ins = [v for v in eqn.invars
               if not isinstance(v, jax_core.Literal)]
        dep = [depth[v] for v in ins if v in depth]
        dep += [ref_depth[v] for v in ins if v in ref_depth]
        if not dep:
            continue
        w = _weight(prim)
        d = max(dep) + w
        count += w
        if w:
            for hlo in _hlo_ops(prim):
                ops[hlo] += 1
        if prim in MEMORY_PRIMS:
            loads += 1
        for v in ins:
            if _is_ref(v):
                ref_depth[v] = max(ref_depth.get(v, 0), d)
        for ov in eqn.outvars:
            depth[ov] = d
    return depth, count, loads, ops


def _is_counter_carry(invar, outvar, eqns) -> bool:
    """True for the fori_loop induction variable: a scalar int carry whose
    only dependent op is one literal add."""
    aval = getattr(invar, "aval", None)
    if aval is None or getattr(aval, "shape", None) not in ((), None):
        return False
    if not jnp.issubdtype(getattr(aval, "dtype", jnp.float32), jnp.integer):
        return False
    depth, count, loads, ops = _trace_path(eqns, {invar: 0})
    return (count == 1 and loads == 0 and ops == Counter({"add": 1})
            and depth.get(outvar) == 1)


def _scan_chain_cert(eqn) -> ChainCert:
    """Serialization certificate for a scan-carried chain: exactly one
    measured (non-induction) carry, dependent in -> out each iteration,
    every dependent countable op on one serial path."""
    body = _as_jaxpr(eqn.params["jaxpr"])
    length = int(eqn.params["length"])
    num_consts = int(eqn.params["num_consts"])
    num_carry = int(eqn.params["num_carry"])
    carries = [(body.invars[num_consts + i], body.outvars[i])
               for i in range(num_carry)]
    measured = [(iv, ov) for iv, ov in carries
                if not _is_counter_carry(iv, ov, body.eqns)]
    if not measured:
        return ChainCert("scan", False, length, 0, 0, Counter(),
                         cause="no-measured-carry")
    if len(measured) > 1:
        return ChainCert("scan", False, length, 0, 0, Counter(),
                         cause="multiple-carries")
    invar, outvar = measured[0]
    depth, count, loads, ops = _trace_path(body.eqns, {invar: 0})
    if outvar not in depth:
        return ChainCert("scan", False, length, count, loads, ops,
                         cause="no-dependence")
    if count != depth[outvar]:
        return ChainCert("scan", False, length, count, loads, ops,
                         cause="parallel-shortcut")
    return ChainCert("scan", True, length, count, loads, ops)


def _straightline_chain_cert(jaxpr, input_refs) -> ChainCert:
    """Serialization certificate for an unrolled chain: all countable ops
    that depend on the kernel inputs form one serial path."""
    depth, count, loads, ops = _trace_path(
        jaxpr.eqns, {}, ref_seeds=input_refs)
    if not depth:
        return ChainCert("straightline", False, 0, 0, loads, ops,
                         cause="no-dependence")
    longest = max(depth.values())
    if count != longest:
        return ChainCert("straightline", False, longest, count, loads, ops,
                         cause="parallel-shortcut")
    return ChainCert("straightline", True, longest, count, loads, ops)


# -------------------------------------------------------- block-map traffic
def _block_dims(block_shape) -> tuple[int, ...]:
    return tuple(int(d) if isinstance(d, int) else 1 for d in block_shape)


def _distinct_blocks(bm, grid: tuple[int, ...]) -> int:
    """How many distinct blocks the ref's index map selects over the grid —
    the HBM-traffic multiplier (a broadcast block map revisits one block)."""
    total = max(int(math.prod(grid)), 1)
    cj = getattr(bm, "index_map_jaxpr", None)
    if cj is None or total > 4096 or len(cj.jaxpr.invars) != len(grid):
        return total
    seen = set()
    for idx in itertools.product(*(range(max(g, 1)) for g in grid)):
        out = jax_core.eval_jaxpr(cj.jaxpr, cj.consts, *idx)
        seen.add(tuple(int(x) for x in out))
    return len(seen)


def _ref_certs(grid_mapping) -> tuple[RefCert, ...]:
    grid = tuple(int(g) for g in grid_mapping.grid)
    n_in = int(grid_mapping.num_inputs)
    certs = []
    for i, bm in enumerate(grid_mapping.block_mappings):
        space = "any" if "any" in str(
            getattr(bm.block_aval, "memory_space", "")).lower() else "vmem"
        dims = _block_dims(bm.block_shape)
        itemsize = jnp.dtype(bm.array_shape_dtype.dtype).itemsize
        certs.append(RefCert(
            index=i, kind="in" if i < n_in else "out", space=space,
            block_shape=dims,
            block_bytes=int(math.prod(dims)) * int(itemsize),
            distinct_blocks=_distinct_blocks(bm, grid)))
    return tuple(certs)


# ------------------------------------------------------------ kernel certs
def _find_pallas_eqns(jaxpr, out: list) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
            continue
        for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr"):
            if key in eqn.params:
                sub = _as_jaxpr(eqn.params[key])
                if sub is not None:
                    _find_pallas_eqns(sub, out)
        if eqn.primitive.name == "cond":
            for br in eqn.params.get("branches", ()):
                _find_pallas_eqns(_as_jaxpr(br), out)


def _cert_from_eqn(eqn) -> KernelCert:
    kernel = _as_jaxpr(eqn.params["jaxpr"])
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid) or (1,)
    name = getattr(eqn.params.get("name_and_src_info"), "name", "kernel")

    refs = _ref_certs(gm)
    grid_size = max(int(math.prod(grid)), 1)
    ops: Counter = Counter()
    mem: Counter = Counter()
    _count_ops(kernel, grid_size, ops, mem)

    scans = [e for e in kernel.eqns if e.primitive.name == "scan"]
    if len(scans) == 1:
        chain = _scan_chain_cert(scans[0])
    elif scans:
        chain = ChainCert("scan", False, 0, 0, 0, Counter(),
                          cause="multiple-loops")
    else:
        n_idx = int(getattr(gm, "num_index_operands", 0))
        n_in = int(gm.num_inputs)
        input_refs = [v for v in kernel.invars[n_idx:n_idx + n_in]]
        chain = _straightline_chain_cert(kernel, input_refs)
    return KernelCert(name=name, grid=grid, ops=ops, mem_ops=mem,
                      refs=refs, chain=chain)


def kernel_certs(fn: Callable, *args) -> tuple[KernelCert, ...]:
    """Trace ``fn(*args)`` and certify every pallas_call it contains."""
    closed = jax.make_jaxpr(fn)(*args)
    eqns: list = []
    _find_pallas_eqns(closed.jaxpr, eqns)
    return tuple(_cert_from_eqn(e) for e in eqns)


def kernel_cert(fn: Callable, *args) -> KernelCert:
    """Certify the single pallas_call of a kernel builder."""
    certs = kernel_certs(fn, *args)
    if len(certs) != 1:
        raise DataflowError(
            f"expected exactly one pallas_call, traced {len(certs)}")
    return certs[0]


# ----------------------------------------------------------- verdict helpers
def _residency_cause(cert: KernelCert,
                     expect: dict[int, str] | None = None) -> str:
    """'' if every ref sits in its declared space (vmem unless overridden
    per-index by ``expect``)."""
    expect = expect or {}
    for r in cert.refs:
        want = expect.get(r.index, "vmem")
        if r.space != want:
            return f"residency-mismatch(ref{r.index}:{r.space}!={want})"
    return ""


def _audited(op: str, opt_level: str, detail: str) -> ChainVerdict:
    return ChainVerdict(op, opt_level, "audited", detail=detail)


def _transformed(op: str, opt_level: str, cause: str,
                 detail: str = "") -> ChainVerdict:
    return ChainVerdict(op, opt_level, "transformed", cause=cause,
                        detail=detail)


def _chain_pair_verdict(op: str, opt_level: str,
                        certs: Sequence[KernelCert],
                        lens: Sequence[int], *,
                        expect_spaces: dict[int, str] | None = None,
                        per_iter: bool, min_loads: int = 0) -> ChainVerdict:
    """The uniform two-length chain certificate: both lens serialized, both
    residency-clean, and the length delta exactly the slope's denominator.

    ``per_iter=True`` (scan chains): trip counts must equal the requested
    lens and the per-iteration path multiset must match between lens.
    ``per_iter=False`` (unrolled chains): the total path depth must scale
    as ``n x unit`` for an integer unit."""
    (n1, n2), (c1, c2) = tuple(lens), tuple(certs)
    for n, c in ((n1, c1), (n2, c2)):
        if not c.chain.serialized:
            return _transformed(op, opt_level, c.chain.cause or "not-serial",
                                f"len={n}")
        cause = _residency_cause(c, expect_spaces)
        if cause:
            return _transformed(op, opt_level, cause, f"len={n}")
        if c.chain.loads < min_loads:
            return _transformed(
                op, opt_level, "missing-dependent-load",
                f"len={n} loads={c.chain.loads}<{min_loads}")
    if per_iter:
        if (c1.chain.length, c2.chain.length) != (n1, n2):
            return _transformed(
                op, opt_level, "length-mismatch",
                f"trips={c1.chain.length},{c2.chain.length} want={n1},{n2}")
        if c1.chain.body_ops != c2.chain.body_ops:
            return _transformed(op, opt_level, "body-mismatch",
                                f"{dict(c1.chain.body_ops)} != "
                                f"{dict(c2.chain.body_ops)}")
        unit = dict(c1.chain.body_ops)
        detail = (f"trips={n1},{n2} depth/iter={c1.chain.depth} "
                  f"loads/iter={c1.chain.loads} step={unit or 'mem-only'}")
    else:
        d1, d2 = c1.chain.length, c2.chain.length
        if (d2 - d1) % (n2 - n1) or d1 * n2 != d2 * n1:
            return _transformed(op, opt_level, "length-mismatch",
                                f"depths={d1},{d2} lens={n1},{n2}")
        detail = f"depths={d1},{d2} unit={(d2 - d1) // (n2 - n1)}"
    return _audited(op, opt_level, detail)


# ------------------------------------------------------- chain-family audits
def audit_inkernel_op(spec, opt_level: str, *, op: str | None = None,
                      lens: Sequence[int] | None = None,
                      shape: tuple[int, int] | None = None) -> ChainVerdict:
    """Certify an ``inkernel.<spec>`` fori_loop chain from its jaxpr."""
    from repro.inkernel.factory import build_chain, supported, tiles
    from repro.inkernel.measure import INKERNEL_LENS

    op = op or f"inkernel.{spec.name}"
    if not supported(spec):
        return ChainVerdict(op, opt_level, "unaudited", cause="x64-dispatch")
    lens = tuple(lens or INKERNEL_LENS)
    carry, operands = tiles(spec, shape)
    certs = []
    for n in lens:
        fn = build_chain(spec, n, interpret=True)
        certs.append(kernel_cert(fn, carry, *operands))
    return _chain_pair_verdict(op, opt_level, certs, lens, per_iter=True)


def audit_inkernel_mem(ws_bytes: int, opt_level: str, *,
                       op: str | None = None, space: str | None = None,
                       line_bytes: int = 64,
                       lens: Sequence[int] | None = None) -> ChainVerdict:
    """Certify an ``inkernel.mem.<bytes>`` pointer chase: a serialized
    dependent load per step, ring resident in its selected space."""
    from repro.core.membench import build_ring
    from repro.inkernel.measure import CHASE_LENS
    from repro.kernels.chase import chase, select_memory_space

    op = op or f"inkernel.mem.{ws_bytes}"
    space = space or select_memory_space(ws_bytes)
    lens = tuple(lens or CHASE_LENS)
    ring, start = build_ring(ws_bytes, line_bytes)
    certs = []
    for n in lens:
        fn = functools.partial(chase, steps=int(n), memory_space=space,
                               interpret=True)
        certs.append(kernel_cert(fn, ring, start))
    # ref0 is the ring (the working set under test); everything else VMEM
    expect = {0: space}
    return _chain_pair_verdict(op, opt_level, certs, lens,
                               expect_spaces=expect, per_iter=True,
                               min_loads=1)


def audit_alu_kernel(alu_op: str, opt_level: str, *, op: str | None = None,
                     lens: Sequence[int] = (8, 64),
                     tile: tuple[int, int] = (8, 128)) -> ChainVerdict:
    """Certify a ``kernel.alu_chain.<op>`` unrolled chain: the n-times
    unrolled body is one straight dependent path of ``n x unit`` ops."""
    from repro.kernels.alu_chain import alu_chain

    op = op or f"kernel.alu_chain.{alu_op}"
    x = jnp.full(tile, 1.5, jnp.float32)
    a = jnp.full(tile, 0.5, jnp.float32)
    certs = []
    try:
        for n in lens:
            fn = functools.partial(alu_chain, n=int(n), op=alu_op,
                                   interpret=True)
            certs.append(kernel_cert(fn, x, a))
    except ValueError:
        return ChainVerdict(op, opt_level, "unaudited",
                            cause="unknown-kernel-op")
    return _chain_pair_verdict(op, opt_level, certs, lens, per_iter=False)


# ------------------------------------------------------------- fused kernels
def audit_fused(name: str, opt_level: str = "O3", *, op: str | None = None,
                lens: Sequence[int] | None = None) -> ChainVerdict:
    """Certify an ``inkernel.fused.<name>`` row: residency-clean at both
    workload sizes and signature *linear* in the size — the exact property
    ``Timer.slope`` needs to net the launch/DMA overhead out of a fused
    kernel the way it does for a chain."""
    from repro.inkernel.fused import FUSED_LENS, build_fused

    op = op or f"inkernel.fused.{name}"
    lens = tuple(lens or FUSED_LENS)
    try:
        unit = fused_unit(name, lens)
    except ValueError:
        return ChainVerdict(op, opt_level, "unaudited",
                            cause="unknown-kernel-op")
    except DataflowError as e:
        return ChainVerdict(op, opt_level, "opaque", cause="untraceable",
                            detail=str(e))
    except _NonlinearSignature as e:
        return _transformed(op, opt_level, e.cause, e.detail)
    for n in lens:
        fn, args = build_fused(name, n, interpret=True)
        cause = _residency_cause(kernel_cert(fn, *args))
        if cause:
            return _transformed(op, opt_level, cause, f"len={n}")
    ops = " ".join(f"{k}={v}" for k, v in sorted(unit["ops"].items()))
    return _audited(op, opt_level,
                    f"unit_bytes={unit['bytes']} unit_ops=[{ops}]")


class _NonlinearSignature(Exception):
    def __init__(self, cause: str, detail: str):
        super().__init__(f"{cause}: {detail}")
        self.cause, self.detail = cause, detail


@functools.lru_cache(maxsize=None)
def fused_unit(name: str, lens: tuple[int, int]) -> dict:
    """Unit signature of a fused kernel: the per-workload-unit op multiset
    and HBM bytes, from the signature delta between two workload sizes.
    Raises :class:`_NonlinearSignature` if the delta is not divisible —
    i.e. the kernel does not scale the way the slope assumes."""
    from repro.inkernel.fused import build_fused

    n1, n2 = lens
    certs = []
    for n in lens:
        fn, args = build_fused(name, n, interpret=True)
        certs.append(kernel_cert(fn, *args))
    c1, c2 = certs
    dn = n2 - n1
    delta = Counter(c2.ops)
    delta.subtract(c1.ops)
    unit_ops: dict[str, int] = {}
    for k, v in delta.items():
        if v < 0 or v % dn:
            raise _NonlinearSignature(
                "nonlinear-signature", f"{k}: delta={v} over dn={dn}")
        if v:
            unit_ops[k] = v // dn
    dbytes = c2.hbm_bytes - c1.hbm_bytes
    if dbytes <= 0 or dbytes % dn:
        raise _NonlinearSignature(
            "nonlinear-traffic", f"bytes delta={dbytes} over dn={dn}")
    return {"ops": unit_ops, "bytes": dbytes // dn,
            "grid": c2.grid, "total_bytes": {n1: c1.hbm_bytes,
                                             n2: c2.hbm_bytes}}


def fused_registry(lens: tuple[int, int] | None = None) -> dict[str, dict]:
    """name -> unit signature for every in-repo fused kernel. The dataflow
    side of ``CUSTOM_CALL_TARGETS``: a custom-call target resolves to a
    priced row only if its kernel certifies here."""
    from repro.inkernel.fused import FUSED_KERNELS, FUSED_LENS

    lens = tuple(lens or FUSED_LENS)
    return {name: fused_unit(name, lens) for name in FUSED_KERNELS}
