"""Device-free static lints over the repo's opcode plumbing.

Three closure properties keep the characterization -> estimator -> serving
pipeline honest, and all three are checkable without timing anything:

* **table mapping** — every ``HLO_TO_TABLE`` value must resolve to a registry
  spec (else the estimator prices HLO against a row no probe ever measures);
* **guard identity** — every registry spec's declared ``guard`` count must
  match the audit's declared guard *opcodes* and those opcodes must exist in
  the spec's own per-step multiset (else ``net_latency_ns`` subtracts
  baselines that are not actually in the chain);
* **zoo coverage** — every opcode appearing in the model zoo's optimized HLO
  must be priced (``HLO_TO_TABLE``), structural (``STRUCTURAL_OPS``), or on
  the explicit :data:`ZOO_ALLOWLIST` (else a new model silently inflates the
  estimator's default-cost bucket). Custom-calls are resolved per call site
  through ``hlo_analysis.CUSTOM_CALL_TARGETS``: a target mapped to a
  dataflow-certified fused kernel passes, a documented XLA library target
  (:data:`KNOWN_LIBRARY_CALLS`) passes, an unknown target fails — never
  the old blanket "custom-call is exempt" escape.

``lint_dataflow`` certifies every in-repo Pallas kernel family through
:mod:`repro.audit.dataflow` (serialization / residency / signature).

``lint_registry_lowering`` additionally compiles one short chain per spec and
asserts the expected target opcodes actually appear — the cheap
presence-only cousin of the full :func:`repro.audit.chain_check.audit_spec`.
Run everything via :func:`run_lints` or ``python -m repro audit --lint``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

# Opcodes the zoo's optimized HLO may contain that are *deliberately* not in
# HLO_TO_TABLE. Every entry needs a reason — this list is the documented
# boundary of the estimator's default-cost bucket, kept by the zoo lint.
ZOO_ALLOWLIST: dict[str, str] = {
    # special-cased by HloLatencyEstimator's matmul term, never table-priced
    "dot": "priced by the estimator's dedicated matmul/FLOP term",
    # data-dependent reshuffles: cost is memory traffic (byte rollup), and
    # no dispatch-level chain can serialize them into a latency row
    "gather": "memory-bound data movement; priced by the byte rollup",
    "scatter": "memory-bound data movement; priced by the byte rollup",
    "select-and-scatter": "memory-bound data movement; byte rollup",
    # lane-local ALU ops with no PTX-table analog in the paper's ISA set;
    # each is ~1 simple-op latency and is dominated by mapped neighbors
    "select": "predication; folded into the comparison it consumes",
    "compare": "sets predicates; no standalone PTX table row",
    "convert": "dtype plumbing; audited as linear, not priced",
    "bitcast-convert": "dtype plumbing; audited as linear, not priced",
    "clamp": "min+max macro of two mapped rows",
    "sign": "compare/select macro",
    "floor": "rounding mode of a mapped convert-class op",
    "ceil": "rounding mode of a mapped convert-class op",
    "round-nearest-even": "rounding mode of a mapped convert-class op",
    "round-nearest-afz": "rounding mode of a mapped convert-class op",
    "is-finite": "exponent-field compare; predicate producer",
    "expm1": "libm composite of mapped ex2/add",
    "atan2": "libm composite; no PTX table row in the paper",
    "erf": "libm composite; no PTX table row in the paper",
    "cbrt": "libm composite; no PTX table row in the paper",
    # reductions/laid-out loops: trip-weighted by dynamic_op_histogram; the
    # body ops are counted individually there
    "reduce": "loop skeleton; body ops are counted individually",
    "reduce-window": "loop skeleton; body ops are counted individually",
    "sort": "comparator loop skeleton; body ops counted individually",
    # RNG: counter-based generator, priced as its component ALU ops
    "rng-bit-generator": "counter-based RNG; components are mapped ALU ops",
    "rng": "legacy RNG op; components are mapped ALU ops",
    # NOTE: custom-call is deliberately NOT allowlistable — each call site
    # must resolve through hlo_analysis.CUSTOM_CALL_TARGETS to a measured
    # fused-kernel row, or be a KNOWN_LIBRARY_CALLS target, or lint_zoo
    # reports it per target.
}

# Custom-call targets XLA itself emits when lowering builtin ops on some
# backends — library code, not in-repo Pallas kernels, so there is no fused
# row to price them from and no jaxpr to certify. The lint accepts exactly
# these targets (reason required per entry); the estimator still reports
# every one as ``custom-call:<target>`` unpriced, so they keep counting
# against coverage. An unlisted, unresolved target remains a lint failure.
KNOWN_LIBRARY_CALLS: dict[str, str] = {
    "TopK": "XLA CPU lowering of lax.top_k (MoE router); comparator-network "
            "library code with no serializable dependence chain to measure",
}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    lint: str       # which lint fired
    subject: str    # op / spec / arch the finding is about
    message: str

    def __str__(self) -> str:  # pragma: no cover - display helper
        return f"[{self.lint}] {self.subject}: {self.message}"


def lint_table_mapping() -> list[LintFinding]:
    """Every ``HLO_TO_TABLE`` value must name a measurable registry spec."""
    from repro.core.chains import default_registry
    from repro.core.hlo_analysis import HLO_TO_TABLE, STRUCTURAL_OPS

    spec_names = {s.name for s in default_registry()}
    findings = []
    for opcode, table_op in sorted(HLO_TO_TABLE.items()):
        if table_op not in spec_names:
            findings.append(LintFinding(
                "table-mapping", opcode,
                f"maps to '{table_op}' which is not a registry spec — the "
                f"estimator would price it with a row no probe measures"))
        if opcode in STRUCTURAL_OPS:
            findings.append(LintFinding(
                "table-mapping", opcode,
                "is both priced (HLO_TO_TABLE) and structural "
                "(STRUCTURAL_OPS); the estimator would double-classify it"))
    return findings


def lint_guard_identity() -> list[LintFinding]:
    """Declared guard counts vs declared guard opcodes vs per-step multiset.

    Pure tracing (``jax.make_jaxpr``) — no XLA compile, no timing.
    """
    from repro.audit.chain_check import GUARDS, _lookup, expected_step
    from repro.core.chains import default_registry

    findings = []
    for spec in default_registry():
        try:
            exp = expected_step(spec, "O3")
        except Exception as e:  # noqa: BLE001 - a spec that won't trace is a finding
            findings.append(LintFinding(
                "guard-identity", spec.name, f"step fn does not trace: {e}"))
            continue
        if exp.unknown:
            findings.append(LintFinding(
                "guard-identity", spec.name,
                f"jaxpr primitives with no HLO mapping: {list(exp.unknown)}"))
            continue
        if spec.guard == 0:
            continue
        if _lookup(GUARDS, spec.name) is None:
            findings.append(LintFinding(
                "guard-identity", spec.name,
                f"spec.guard={spec.guard} but no guard opcodes declared in "
                f"audit GUARDS"))
            continue
        if sum(exp.guards.values()) != spec.guard:
            findings.append(LintFinding(
                "guard-identity", spec.name,
                f"spec.guard={spec.guard} != declared guard opcodes "
                f"{dict(exp.guards)}"))
        if exp.guards - exp.counts:
            findings.append(LintFinding(
                "guard-identity", spec.name,
                f"declared guard opcodes {dict(exp.guards)} not contained "
                f"in the expected per-step multiset {dict(exp.counts)}"))
    return findings


def lint_registry_lowering(opt_levels: tuple[str, ...] = ("O1", "O3"),
                           chain_len: int = 4) -> list[LintFinding]:
    """Presence check: each spec's expected target opcodes appear in one
    short compiled chain at each opt level (CPU compile, no timing)."""
    from repro.audit.chain_check import (chain_hlo_text, expected_step,
                                         hist_counts)
    from repro.core.chains import default_registry

    findings = []
    for spec in default_registry():
        for level in opt_levels:
            try:
                exp = expected_step(spec, level)
                if exp.unknown:
                    continue  # already reported by lint_guard_identity
                n = chain_len
                if spec.max_chain is not None:
                    n = min(n, spec.max_chain)
                counts, _ = hist_counts(chain_hlo_text(spec, n, level))
            except Exception as e:  # noqa: BLE001 - non-lowering spec is a finding
                findings.append(LintFinding(
                    "registry-lowering", f"{spec.name}@{level}",
                    f"chain does not compile: {e}"))
                continue
            missing = {opc: k for opc, k in exp.targets.items()
                       if counts.get(opc, 0) < k}
            if missing:
                findings.append(LintFinding(
                    "registry-lowering", f"{spec.name}@{level}",
                    f"expected target opcodes {missing} absent from the "
                    f"compiled chain (got {dict(counts)})"))
    return findings


def _zoo_hlo(arch: str) -> str:
    """Optimized train-step HLO for one zoo arch (the smoke-test recipe)."""
    import jax

    from repro.configs.registry import get
    from repro.models import encdec, transformer
    from repro.models.config import Runtime

    rt = Runtime(moe_groups=2, mamba_chunk=8, mlstm_chunk=8, xent_chunk=16,
                 remat=False)
    key = jax.random.PRNGKey(0)
    b, s = 2, 32
    cfg = get(arch).smoke
    import jax.numpy as jnp

    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_encoder_layers:
        batch["frames"] = jax.random.normal(key, (b, s // 4, cfg.d_model))
        params = encdec.init_encdec(key, cfg)
        fn = lambda p, bt: encdec.train_loss(p, bt, cfg, rt)  # noqa: E731
    else:
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
        params = transformer.init_lm(key, cfg)
        fn = lambda p, bt: transformer.train_loss(p, bt, cfg, rt)  # noqa: E731
    return jax.jit(fn).lower(params, batch).compile().as_text()


def lint_zoo(archs: Iterable[str] | None = None) -> list[LintFinding]:
    """Every opcode in the model zoo's optimized HLO must be priced,
    structural, or explicitly allowlisted. Compiles each arch's train step
    on the host backend (slow: seconds per arch) but times nothing."""
    from repro.configs.registry import all_arch_ids
    from repro.core.hlo_analysis import (HLO_TO_TABLE, STRUCTURAL_OPS,
                                         ModuleCost, op_histogram,
                                         resolve_custom_call)
    from repro.inkernel.fused import FUSED_KERNELS

    findings = []
    for arch in (archs if archs is not None else all_arch_ids()):
        try:
            text = _zoo_hlo(arch)
        except Exception as e:  # noqa: BLE001 - an uncompilable arch is a finding
            findings.append(LintFinding(
                "zoo-coverage", arch, f"train step does not compile: {e}"))
            continue
        opcodes = {opc for (opc, _e) in op_histogram(text)}
        unmapped = sorted(
            opc for opc in opcodes
            if opc not in HLO_TO_TABLE and opc not in STRUCTURAL_OPS
            and opc not in ZOO_ALLOWLIST and opc != "custom-call")
        for opc in unmapped:
            findings.append(LintFinding(
                "zoo-coverage", arch,
                f"opcode '{opc}' is neither priced (HLO_TO_TABLE), "
                f"structural, nor allowlisted"))
        # custom-call is never allowlistable wholesale: each call site must
        # resolve through CUSTOM_CALL_TARGETS to a measured fused-kernel row
        # (the dataflow-certified registry) or be a documented XLA library
        # target (KNOWN_LIBRARY_CALLS) — anything else fails the lint.
        seen_targets: set[str] = set()
        for target, _b, execs, rest in ModuleCost(text).dynamic_custom_calls():
            if execs <= 0:
                continue
            name = resolve_custom_call(target, rest)
            if name in FUSED_KERNELS or target in KNOWN_LIBRARY_CALLS:
                continue
            if target in seen_targets:
                continue
            seen_targets.add(target)
            findings.append(LintFinding(
                "zoo-coverage", arch,
                f"custom-call target '{target or '?'}' resolves to neither "
                f"a measured fused-kernel row (CUSTOM_CALL_TARGETS) nor a "
                f"documented library call (KNOWN_LIBRARY_CALLS) — the "
                f"estimator would default-price an opaque kernel"))
    return findings


def lint_dataflow() -> list[LintFinding]:
    """Open every in-repo Pallas kernel family's jaxpr and certify it.

    The compile-free (interpret-mode tracing only) closure property behind
    the ``audited`` verdicts: the four fused production kernels, the five
    unrolled ALU chains, one representative fori-loop op chain, and both
    chase residencies must all certify serialization + residency +
    signature through :mod:`repro.audit.dataflow` — no family-specific
    escape hatches. A kernel edit that parallelizes a chain or moves a ref
    out of its declared space fails here before any number is measured.
    """
    from repro.audit import dataflow
    from repro.core.chains import default_registry
    from repro.inkernel.fused import FUSED_KERNELS

    findings = []

    def check(v) -> None:
        if not v.ok:
            findings.append(LintFinding(
                "dataflow", f"{v.op}@{v.opt_level}",
                f"{v.status}:{v.cause}"
                + (f" — {v.detail}" if v.detail else "")))

    for name in FUSED_KERNELS:
        check(dataflow.audit_fused(name))
    for alu_op in ("fma", "add", "mul", "rsqrt", "exp"):
        check(dataflow.audit_alu_kernel(alu_op, "O3"))
    spec = next(s for s in default_registry() if s.name == "add.float32")
    check(dataflow.audit_inkernel_op(spec, "O3"))
    check(dataflow.audit_inkernel_mem(8192, "O3", space="vmem"))
    check(dataflow.audit_inkernel_mem(8192, "O3", space="any"))
    return findings


def run_lints(lowering: bool = False, zoo: bool = False,
              archs: Iterable[str] | None = None,
              dataflow: bool = False) -> list[LintFinding]:
    """All static lints. The trace-only set always runs; ``lowering``,
    ``zoo`` and ``dataflow`` opt into the slower (still device-free) sets."""
    findings = lint_table_mapping() + lint_guard_identity()
    if lowering:
        findings += lint_registry_lowering()
    if zoo:
        findings += lint_zoo(archs)
    if dataflow:
        findings += lint_dataflow()
    return findings
