"""Batched generation engine: prefill + greedy/temperature decode.

Continuous-batching-lite: requests are padded into one batch; per-request
``kv_len`` tracks ragged prompts; finished rows keep decoding into a waste
slot (masked at the end) — the standard static-batch serving pattern, and the
program that ``decode_32k`` / ``long_500k`` cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig, Runtime


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # [B, max_new]
    prompt_lens: np.ndarray
    steps: int


class Engine:
    def __init__(self, params: Any, cfg: ModelConfig, rt: Runtime,
                 *, max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, cfg, rt, tokens=t))
        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg, rt),
            donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> GenerateResult:
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        plen = int(lens.max())
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p        # right-align not needed: causal + same len
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        cache = transformer.pad_cache(cache, self.cfg, plen + max_new)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new), np.int32)
        tok = _sample(logits, temperature, key)
        for step in range(max_new):
            out[:, step] = np.asarray(tok)[:, 0]
            if step == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok),
                                         plen + step)
            key = jax.random.fold_in(key, step)
            tok = _sample(logits, temperature, key)
        return GenerateResult(tokens=out, prompt_lens=lens, steps=max_new)


def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)
