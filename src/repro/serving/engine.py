"""Batched generation engine: prefill + greedy/temperature decode.

Continuous-batching-lite: requests are padded into one batch; ragged prompts
are **right-padded** and each row's first token is sampled from its own last
real prompt token (causal attention makes that gather exact — see
``transformer.prefill``'s ``last_positions``); rows that emit ``eos_id`` keep
decoding into a waste slot (the static-batch pattern: the lockstep batch
cannot shrink) and their waste tokens are masked out of the result. This is
the program the serving-path characterization prices: ``ServingCostProbe``
lowers :meth:`Engine.lower_prefill` / :meth:`Engine.lower_decode` HLO and
pairs the estimator's prediction with the measured wall clock
(docs/serving.md).

Known approximation: after prefill, decode steps use one shared position
counter for the whole batch, so a short row's later tokens sit at the padded
batch's positions (standard static-batch behavior), and its KV slots between
``len(prompt)`` and the batch's ``max_len`` hold pad-token entries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig, Runtime


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # [B, max_new]; waste slots masked to eos_id
    prompt_lens: np.ndarray
    steps: int                  # decode steps actually run (early-exit aware)
    finished_steps: np.ndarray | None = None  # per-row eos step, -1 = never


class Engine:
    def __init__(self, params: Any, cfg: ModelConfig, rt: Runtime,
                 *, max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t, last: transformer.prefill(p, cfg, rt, tokens=t,
                                                   last_positions=last))
        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg, rt),
            donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: int | None = None) -> GenerateResult:
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        plen = int(lens.max())
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p    # right-padded; per-row gather below
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lens - 1))
        cache = transformer.pad_cache(cache, self.cfg, plen + max_new)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new), np.int32)
        finished = np.full((b,), -1, np.int32)
        tok = _sample(logits, temperature, key)
        steps = 0
        for step in range(max_new):
            t = np.asarray(tok)[:, 0]
            out[:, step] = t
            steps = step + 1
            if eos_id is not None:
                finished = np.where((t == eos_id) & (finished < 0),
                                    step, finished)
            if step == max_new - 1:
                break
            if eos_id is not None and (finished >= 0).all():
                break               # every row done: stop burning waste slots
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok),
                                         plen + step)
            key = jax.random.fold_in(key, step)
            tok = _sample(logits, temperature, key)
        if eos_id is not None:
            # waste-slot masking: a finished row keeps decoding in the static
            # batch; everything after its eos is noise, not output
            col = np.arange(max_new)[None, :]
            done = finished[:, None]
            out = np.where((done >= 0) & (col > done), eos_id, out)
        return GenerateResult(tokens=out, prompt_lens=lens, steps=steps,
                              finished_steps=finished if eos_id is not None
                              else None)

    # ---------------------------------------------------- characterization
    def lower_prefill(self, batch: int, prompt_len: int):
        """Lower the prefill computation at one ``(batch, prompt_len)`` cell.

        Returns ``(lowered, args)``: the jit-lowered prefill (``.compile()``
        yields the executable and its optimized HLO text) plus the concrete
        arrays to run it with — what ``ServingCostProbe`` prices and times.
        """
        toks = jnp.reshape(
            jnp.arange(batch * prompt_len, dtype=jnp.int32)
            % max(self.cfg.vocab_size, 1), (batch, prompt_len))
        last = jnp.full((batch,), prompt_len - 1, jnp.int32)
        args = (self.params, toks, last)
        return self._prefill.lower(*args), args

    def lower_decode(self, batch: int, prompt_len: int,
                     max_len: int | None = None):
        """Lower one decode step at a cell (cache sized ``max_len``, position
        ``prompt_len`` — the first generated token's step).

        Uses a *non-donating* jit so the probe can execute the compiled step
        repeatedly against the same cache buffer while timing.
        """
        max_len = max_len if max_len is not None else prompt_len + 32
        cache = transformer.init_cache(self.cfg, batch, max_len,
                                       self.cfg.cdtype)
        toks = jnp.zeros((batch, 1), jnp.int32)
        cfg, rt = self.cfg, self.rt
        fn = jax.jit(lambda p, c, t: transformer.decode_step(
            p, c, t, prompt_len, cfg, rt))
        args = (self.params, cache, toks)
        return fn.lower(*args), args


def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)
