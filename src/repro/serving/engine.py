"""Batched generation engine: prefill + greedy/temperature decode.

Two batching disciplines share one model and one decode computation:

* :meth:`Engine.generate` — the **static batch**: requests are padded into
  one lockstep batch; ragged prompts are right-padded and each row's first
  token is sampled from its own last real prompt token (see
  ``transformer.prefill``'s ``last_positions``); rows that emit ``eos_id``
  keep decoding into a waste slot and their waste tokens are masked out.
* :meth:`Engine.slots` — **continuous batching**: a fixed pool of slots over
  one persistent batched KV cache with *per-slot positions*.
  :meth:`SlotPool.admit` prefills one prompt into a free slot (batch-1
  prefill, cache rows written in place), :meth:`SlotPool.step` decodes every
  slot at its own depth in one lockstep step, and :meth:`SlotPool.evict`
  frees a slot the moment its row finishes — a late request takes over the
  freed row mid-stream while the other slots keep decoding. This is the
  substrate ``repro.traffic``'s scheduler drives (docs/traffic.md).

This is also the program the serving-path characterization prices:
``ServingCostProbe`` lowers :meth:`Engine.lower_prefill` /
:meth:`Engine.lower_decode` HLO and pairs the estimator's prediction with
the measured wall clock (docs/serving.md).

Known approximation (static batch only): after prefill, decode steps use one
shared position counter for the whole batch, so a short row's later tokens
sit at the padded batch's positions, and its KV slots between
``len(prompt)`` and the batch's ``max_len`` hold pad-token entries. The slot
pool does not share this: every slot carries its own position.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.models import transformer
from repro.models.config import ModelConfig, Runtime


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # [B, max_new]; waste slots masked to eos_id
    prompt_lens: np.ndarray
    steps: int                  # decode steps actually run (early-exit aware)
    finished_steps: np.ndarray | None = None  # per-row eos step, -1 = never


class Engine:
    def __init__(self, params: Any, cfg: ModelConfig, rt: Runtime,
                 *, max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t, last: transformer.prefill(p, cfg, rt, tokens=t,
                                                   last_positions=last))
        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg, rt),
            donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: int | None = None) -> GenerateResult:
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        plen = int(lens.max())
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p    # right-padded; per-row gather below
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lens - 1))
        cache = transformer.pad_cache(cache, self.cfg, plen + max_new)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new), np.int32)
        finished = np.full((b,), -1, np.int32)
        tok = _sample(logits, temperature, key)
        steps = 0
        for step in range(max_new):
            t = np.asarray(tok)[:, 0]
            out[:, step] = t
            steps = step + 1
            if eos_id is not None:
                finished = np.where((t == eos_id) & (finished < 0),
                                    step, finished)
            if step == max_new - 1:
                break
            if eos_id is not None and (finished >= 0).all():
                break               # every row done: stop burning waste slots
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok),
                                         plen + step)
            key = jax.random.fold_in(key, step)
            tok = _sample(logits, temperature, key)
        if eos_id is not None:
            # waste-slot masking: a finished row keeps decoding in the static
            # batch; everything after its eos is noise, not output
            col = np.arange(max_new)[None, :]
            done = finished[:, None]
            out = np.where((done >= 0) & (col > done), eos_id, out)
        return GenerateResult(tokens=out, prompt_lens=lens, steps=steps,
                              finished_steps=finished if eos_id is not None
                              else None)

    # ---------------------------------------------------- characterization
    def lower_prefill(self, batch: int, prompt_len: int):
        """Lower the prefill computation at one ``(batch, prompt_len)`` cell.

        Returns ``(lowered, args)``: the jit-lowered prefill (``.compile()``
        yields the executable and its optimized HLO text) plus the concrete
        arrays to run it with — what ``ServingCostProbe`` prices and times.
        """
        toks = jnp.reshape(
            jnp.arange(batch * prompt_len, dtype=jnp.int32)
            % max(self.cfg.vocab_size, 1), (batch, prompt_len))
        last = jnp.full((batch,), prompt_len - 1, jnp.int32)
        args = (self.params, toks, last)
        return self._prefill.lower(*args), args

    def lower_decode(self, batch: int, prompt_len: int,
                     max_len: int | None = None):
        """Lower one decode step at a cell (cache sized ``max_len``, position
        ``prompt_len`` — the first generated token's step).

        ``max_len`` defaults to the engine's configured capacity
        (``Engine.max_len``) — the cache the serving loop actually decodes
        against — not a prompt-derived size: a cell priced at
        ``prompt_len + 32`` would measure a different (smaller) KV scan than
        the one production steps pay for. Callers needing the old footprint
        pass it explicitly; the priced cache size is recorded in the cell's
        notes either way.

        Uses a *non-donating* jit so the probe can execute the compiled step
        repeatedly against the same cache buffer while timing.
        """
        max_len = max_len if max_len is not None else self.max_len
        cache = transformer.init_cache(self.cfg, batch, max_len,
                                       self.cfg.cdtype)
        toks = jnp.zeros((batch, 1), jnp.int32)
        cfg, rt = self.cfg, self.rt
        fn = jax.jit(lambda p, c, t: transformer.decode_step(
            p, c, t, prompt_len, cfg, rt))
        args = (self.params, cache, toks)
        return fn.lower(*args), args

    # ------------------------------------------------------- slot-level API
    def slots(self, n_slots: int, *, max_len: int | None = None) -> "SlotPool":
        """A continuous-batching slot pool over this engine's model."""
        return SlotPool(self, n_slots,
                        max_len=max_len if max_len is not None else self.max_len)


def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    """Book-keeping for one row of the pool's persistent batch."""

    uid: int = -1                 # caller-supplied request id, -1 = free
    pos: int = 0                  # next KV write index == current kv_len
    n_generated: int = 0
    active: bool = False


class SlotPool:
    """Continuous batching over one persistent batched KV cache.

    The pool owns a ``[periods, n_slots, max_len, ...]`` cache and a per-slot
    position vector. :meth:`admit` runs a batch-1 prefill for one prompt and
    writes its cache rows into the slot in place (``dynamic_update_slice`` on
    the batch axis — the other slots' rows are untouched, so in-flight
    requests never notice an admission); :meth:`step` runs **one** lockstep
    decode step for the whole pool with per-slot positions (the
    ``attn_decode`` per-row scatter path); :meth:`evict` frees the slot
    immediately — its stale KV rows are invisible to attention (masked by the
    per-slot ``kv_len``) and are overwritten by the next admission.

    Free slots still occupy their row of the static batch (the decode step's
    shape never changes — that is what makes it one compiled executable);
    their garbage tokens are simply never surfaced. Greedy decoding is
    deterministic per slot regardless of what the other slots hold;
    ``temperature > 0`` sampling derives each slot's PRNG stream from
    ``(seed, uid, n_generated)`` so a request's sample path is independent of
    which slot it landed in and what was co-batched with it.
    """

    def __init__(self, engine: Engine, n_slots: int, *, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.engine = engine
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.cache = transformer.init_cache(engine.cfg, self.n_slots,
                                            self.max_len, engine.cfg.cdtype)
        self._slots = [_Slot() for _ in range(self.n_slots)]
        self._tok = np.zeros((self.n_slots, 1), np.int32)  # last sampled token
        # admit writes the batch-1 prefill cache into one slot's rows; the
        # pool cache is donated (replaced wholesale every admit/step)
        self._write = jax.jit(
            lambda cache, pc, slot: jax.tree_util.tree_map(
                lambda big, small: lax.dynamic_update_slice(
                    big, small.astype(big.dtype),
                    (0, slot) + (0,) * (big.ndim - 2)),
                cache, pc),
            donate_argnums=(0,))

    # ------------------------------------------------------------- queries
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if not s.active]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s.active]

    def position(self, slot: int) -> int:
        return self._slots[slot].pos

    # ------------------------------------------------------------ lifecycle
    def admit(self, slot: int, prompt: list[int], *, uid: int = 0,
              max_new: int = 1) -> int:
        """Prefill ``prompt`` into a free ``slot``; returns the first token.

        The first generated token is sampled from the prefill logits — by the
        time admit returns, the request's TTFT is complete. ``max_new`` is
        only validated here (the scheduler enforces the budget); the prompt
        plus budget must fit the pool's ``max_len``.
        """
        st = self._slots[slot]
        if st.active:
            raise ValueError(f"slot {slot} is occupied (uid={st.uid})")
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds the "
                f"pool's max_len ({self.max_len})")
        eng = self.engine
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        last = jnp.asarray([len(prompt) - 1], jnp.int32)
        logits, pc = eng._prefill(eng.params, toks, last)
        self.cache = self._write(self.cache, pc, slot)
        st.uid, st.pos, st.n_generated, st.active = uid, len(prompt), 0, True
        tok = int(np.asarray(self._sample_slot(logits, st))[0, 0])
        self._tok[slot, 0] = tok
        # pos stays at len(prompt): the first generated token's KV is written
        # by the *next* decode step, at exactly that position
        st.n_generated = 1
        return tok

    def evict(self, slot: int) -> None:
        """Free ``slot`` immediately; its KV rows stay as invisible garbage
        (masked by per-slot kv_len) until the next admission overwrites them."""
        self._slots[slot] = _Slot()

    def step(self) -> np.ndarray:
        """One lockstep decode step for the whole pool; returns ``[n_slots]``
        tokens. Only the active slots' tokens are meaningful — free slots keep
        decoding garbage into their own (unread) rows, exactly the static
        batch's waste-slot behavior, because the compiled step's shape is
        fixed at ``n_slots``."""
        if not any(s.active for s in self._slots):
            raise ValueError("step() with no active slot")
        eng = self.engine
        pos = jnp.asarray([s.pos for s in self._slots], jnp.int32)
        logits, self.cache = eng._decode(eng.params, self.cache,
                                         jnp.asarray(self._tok), pos)
        out = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32)).copy()
        if self.temperature > 0.0:
            # sample only the occupied rows: free slots keep their greedy
            # garbage (never surfaced), and their sentinel uid must not
            # consume — or crash — a PRNG stream
            for i, st in enumerate(self._slots):
                if st.active:
                    row = _sample(logits[i:i + 1], self.temperature,
                                  self._slot_key(st))
                    out[i] = int(np.asarray(row)[0, 0])
        for i, st in enumerate(self._slots):
            self._tok[i, 0] = out[i]
            if st.active:
                st.pos += 1
                st.n_generated += 1
        return out

    # ------------------------------------------------------------- sampling
    def _slot_key(self, st: _Slot):
        # uid folded mod 2^32: callers may use negative sentinel uids
        # (EngineExecutor.warm admits uid=-1) and fold_in takes uint32 data
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 st.uid % (1 << 32))
        return jax.random.fold_in(key, st.n_generated)

    def _sample_slot(self, logits: jax.Array, st: _Slot) -> jax.Array:
        return _sample(logits, self.temperature,
                       self._slot_key(st) if self.temperature > 0 else None)
