from repro.serving.engine import Engine, GenerateResult, SlotPool

__all__ = ["Engine", "GenerateResult", "SlotPool"]
