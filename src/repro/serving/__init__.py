from repro.serving.engine import Engine, GenerateResult

__all__ = ["Engine", "GenerateResult"]
