from repro.optim.adamw import (AdamWConfig, apply_update, cosine_lr,
                               dequantize_i8, global_norm, init_state,
                               quantize_i8)

__all__ = ["AdamWConfig", "apply_update", "cosine_lr", "dequantize_i8",
           "global_norm", "init_state", "quantize_i8"]
