"""AdamW with optional int8-quantized moments (blockwise scales).

The int8 state is the distributed-optimization trick that lets llama3-405b
train on 16 GiB/chip HBM: m and v are stored as int8 with one f32 scale per
128-element block (dynamic quantization, re-quantized each step). Error is
bounded by the block max; tests check the quantized optimizer tracks the f32
one within tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Param, is_param

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "float32" | "int8"


# ------------------------------------------------------------- quantization
# Shape-preserving: q keeps the param's shape (so it inherits the param's
# sharding); scales are per 128-block along the last axis. 1-D params (norms,
# biases) stay f32 — they are negligible memory.
def quantize_i8(x: jax.Array):
    if x.ndim < 2:
        return x.astype(jnp.float32)
    last = x.shape[-1]
    if last % BLOCK == 0:
        nb = last // BLOCK
        blocks = x.reshape(x.shape[:-1] + (nb, BLOCK))
        scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0        # [..., nb]
        denom = jnp.repeat(jnp.maximum(scale, 1e-20), BLOCK, axis=-1)
    else:
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0  # [..., 1]
        denom = jnp.broadcast_to(jnp.maximum(scale, 1e-20), x.shape)
    q = jnp.round(x / denom.reshape(x.shape)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_i8(qs, shape: tuple[int, ...]) -> jax.Array:
    if not isinstance(qs, dict):
        return qs
    q, scale = qs["q"].astype(jnp.float32), qs["scale"]
    last = shape[-1]
    if last % BLOCK == 0 and scale.shape[-1] == last // BLOCK:
        mult = jnp.repeat(scale, BLOCK, axis=-1)
    else:
        mult = jnp.broadcast_to(scale, shape)
    return q * mult.reshape(shape)


# ------------------------------------------------------------------- optimizer
def init_state(params: Any, cfg: AdamWConfig) -> dict[str, Any]:
    def zeros_like_leaf(p):
        v = p.value if is_param(p) else p
        z = jnp.zeros(v.shape, jnp.float32)
        if cfg.state_dtype == "int8":
            return quantize_i8(z)
        return z

    leaf = lambda x: is_param(x)
    return {
        "m": jax.tree_util.tree_map(zeros_like_leaf, params, is_leaf=leaf),
        "v": jax.tree_util.tree_map(zeros_like_leaf, params, is_leaf=leaf),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(grads: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def apply_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0) -> tuple[Any, dict]:
    """One AdamW step on a (possibly boxed) param tree."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        pv = p.value if is_param(p) else p
        gf = g.value.astype(jnp.float32) if is_param(g) else g.astype(jnp.float32)
        gf = gf * clip
        mf = dequantize_i8(m, pv.shape)
        # v is stored in sqrt domain when quantized: halves the dynamic range
        # an int8 block must span, which is what keeps the quantized optimizer
        # tracking f32 (tested).
        vf = dequantize_i8(v, pv.shape)
        if isinstance(v, dict):
            vf = vf * vf
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        mh = mf / b1c
        vh = vf / b2c
        wd = cfg.weight_decay if pv.ndim >= 2 else 0.0   # no decay on norms/biases
        step = mh / (jnp.sqrt(vh) + cfg.eps) + wd * pv.astype(jnp.float32)
        new_p = (pv.astype(jnp.float32) - lr * step).astype(pv.dtype)
        if cfg.state_dtype == "int8" and pv.ndim >= 2:
            m_out, v_out = quantize_i8(mf), quantize_i8(jnp.sqrt(vf))
        else:
            m_out, v_out = mf, vf
        boxed = Param(new_p, p.axes) if is_param(p) else new_p
        return boxed, m_out, v_out

    leaf = lambda x: is_param(x)
    flat_p, tdef = jax.tree_util.tree_flatten(params, is_leaf=leaf)
    flat_g = jax.tree_util.tree_leaves(grads, is_leaf=leaf)
    m_leaves = _state_leaves(state["m"])
    v_leaves = _state_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, m_leaves, v_leaves)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_state = {
        "m": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state


def _state_leaves(tree: Any) -> list:
    """Leaves of an optimizer-state tree, keeping int8 {q,scale} dicts whole."""
    def is_qs(x):
        return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}
    return jax.tree_util.tree_leaves(tree, is_leaf=is_qs) if _has_qs(tree) else \
        jax.tree_util.tree_leaves(tree)


def _has_qs(tree: Any) -> bool:
    found = []

    def walk(x):
        if isinstance(x, dict) and set(x.keys()) == {"q", "scale"}:
            found.append(True)
            return None
        return None
    jax.tree_util.tree_map(
        walk, tree,
        is_leaf=lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "scale"})
    return bool(found)


def cosine_lr(step: jax.Array, *, base: float = 1.0, warmup: int = 100,
              total: int = 10_000, min_frac: float = 0.1) -> jax.Array:
    """LR multiplier: linear warmup then cosine decay."""
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base * jnp.where(s < warmup, warm, cos)
