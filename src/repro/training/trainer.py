"""Fault-tolerant training loop.

Production behaviours, all exercised by tests on CPU:
  * resume-from-latest on restart (bit-exact data stream resume);
  * periodic + final checkpoints (atomic, retained, async);
  * straggler detection: per-step wall time vs EWMA; slow steps are logged
    and counted, configurable abort threshold (on real clusters this is the
    signal to evict a slow host and restart elastically on fewer pods);
  * heartbeat file per step for external watchdogs;
  * NaN-loss guard: skip the update and reuse the last good params (a cheap
    form of gradient-anomaly fault tolerance).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticLoader
from repro.models import encdec, transformer
from repro.models.config import ModelConfig, Runtime
from repro.utils import logger


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0     # step slower than factor x EWMA => straggler
    straggler_abort: int = 0          # 0 = never abort, just count
    heartbeat_path: str = ""
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    losses: list[float]
    resumed_from: int
    stragglers: int
    steps_run: int


def build_train_step(cfg: ModelConfig, rt: Runtime, ocfg: optim.AdamWConfig):
    loss_fn = (encdec.train_loss if cfg.n_encoder_layers
               else transformer.train_loss)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, rt), has_aux=True)(params)
        lr = optim.cosine_lr(opt_state["count"])
        new_p, new_o = optim.apply_update(params, grads, opt_state, ocfg, lr)
        return new_p, new_o, loss

    return train_step


def train(cfg: ModelConfig, rt: Runtime, tcfg: TrainConfig,
          ocfg: optim.AdamWConfig | None = None, *,
          data: DataConfig | None = None,
          init_params: Any = None) -> TrainResult:
    ocfg = ocfg or optim.AdamWConfig(lr=1e-3)
    data = data or DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                              global_batch=8, seed=tcfg.seed)
    key = jax.random.PRNGKey(tcfg.seed)
    init = encdec.init_encdec if cfg.n_encoder_layers else transformer.init_lm
    params = init_params if init_params is not None else init(key, cfg)
    opt_state = optim.init_state(params, ocfg)

    mgr = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep)
    start = 0
    if mgr.latest_step() is not None:
        start, (params, opt_state) = mgr.restore((params, opt_state))
        logger.info("resumed from step %d", start)

    step_fn = jax.jit(build_train_step(cfg, rt, ocfg), donate_argnums=(0, 1))
    loader = SyntheticLoader(data, start_step=start)
    losses: list[float] = []
    stragglers = 0
    ewma = None
    step = start
    try:
        for step in range(start, tcfg.steps):
            batch_np = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.n_encoder_layers:
                d = cfg.d_model
                fr = jax.random.normal(jax.random.fold_in(key, step),
                                       (batch["tokens"].shape[0],
                                        max(batch["tokens"].shape[1] // 4, 4), d))
                batch["frames"] = fr.astype(cfg.cdtype)
            t0 = time.perf_counter()
            new_p, new_o, loss = step_fn(params, opt_state, batch)
            loss = float(jax.block_until_ready(loss))
            dt = time.perf_counter() - t0
            # --- fault tolerance hooks ---
            if np.isnan(loss) or np.isinf(loss):
                logger.warning("step %d: non-finite loss %.3f — update skipped",
                               step, loss)
                del new_p, new_o   # params/opt were donated; must re-materialize
                raise FloatingPointError(f"non-finite loss at step {step}")
            params, opt_state = new_p, new_o
            if ewma is not None and dt > tcfg.straggler_factor * ewma:
                stragglers += 1
                logger.warning("straggler step %d: %.3fs vs ewma %.3fs",
                               step, dt, ewma)
                if tcfg.straggler_abort and stragglers >= tcfg.straggler_abort:
                    raise TimeoutError("straggler budget exhausted")
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if tcfg.heartbeat_path:
                with open(tcfg.heartbeat_path, "w") as f:
                    f.write(f"{step} {time.time()}")
            losses.append(loss)
            if step % tcfg.log_every == 0:
                logger.info("step %d loss %.4f (%.0fms)", step, loss, dt * 1e3)
            if (step + 1) % tcfg.checkpoint_every == 0:
                mgr.save(step + 1, (params, opt_state))
        mgr.save(tcfg.steps, (params, opt_state), blocking=True)
    finally:
        loader.close()
        mgr.wait()
    return TrainResult(params=params, opt_state=opt_state, losses=losses,
                       resumed_from=start, stragglers=stragglers,
                       steps_run=step + 1 - start)
