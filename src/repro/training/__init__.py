from repro.training.trainer import TrainConfig, TrainResult, build_train_step, train

__all__ = ["TrainConfig", "TrainResult", "build_train_step", "train"]
