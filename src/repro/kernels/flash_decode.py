"""Flash-decoding: single-token attention against a long KV cache.

The KV sequence is walked in blocks by the minor (sequential) grid dimension
with running max/sum/acc scratch — the same online softmax as prefill but with
a 1-row query. This kernel is what makes ``decode_32k``/``long_500k`` cells
latency-sane: per-step HBM traffic is exactly one pass over the KV cache, and
when the cache is sequence-sharded across chips the per-chip partials combine
with one tiny LSE all-reduce (see models/common.sharded_decode_attention).

``kv_len`` masking supports ragged caches (continuous batching).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEG_INF, cdiv, pick_block, use_interpret
from repro.kernels.flash_attention import pl_scratch


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, num_k: int, g: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [g, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [g, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == num_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array,
                 *, block_k: int | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """q: [B,H,D]; k,v: [B,S,KH,D]; kv_len: [B] int32. Returns [B,H,D]."""
    b, h, d = q.shape
    _, s, kh, _ = k.shape
    assert h % kh == 0
    g = h // kh
    scale = float(d ** -0.5)
    interpret = use_interpret() if interpret is None else interpret
    bk = pick_block(s, block_k or 512)
    num_k = cdiv(s, bk)

    # Group queries by their kv head: [B, KH, G, D]
    qt = q.reshape(b, kh, g, d)
    kt = k.transpose(0, 2, 1, 3)   # [B, KH, S, D]
    vt = v.transpose(0, 2, 1, 3)
    kv_len = kv_len.astype(jnp.int32).reshape(b)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=bk,
                               num_k=num_k, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, num_k),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki: (bi,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        scratch_shapes=[
            pl_scratch((g, d), jnp.float32),
            pl_scratch((g, 1), jnp.float32),
            pl_scratch((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qt, kt, vt)
    return out.reshape(b, h, d)
