"""In-kernel dependent ALU chain: the paper's Fig. 3 timed block as a TPU kernel.

The paper's PTX body is: load operands -> read clock -> one dependent op ->
read clock -> store. The TPU analog puts an *unrolled dependent chain* inside
a Pallas kernel body on a VMEM-resident tile, so the timed region (the whole
kernel) contains only the chain plus one DMA in/out; latency is extracted with
the same two-length slope as the host-level chains (core/measure.py), which
cancels the DMA/launch overhead exactly like the paper's clock-overhead
subtraction. On this container it runs in interpret mode for correctness
validation; on TPU the same code lowers to a real kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import use_interpret


def _chain_kernel(x_ref, a_ref, o_ref, *, n: int, op: str):
    x = x_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    for _ in range(n):
        if op == "fma":
            x = x * a + a
        elif op == "add":
            x = x + a
        elif op == "mul":
            x = x * a
        elif op == "rsqrt":
            x = jax.lax.rsqrt(x) + a
        elif op == "exp":
            x = jnp.exp(-x) + a
        else:
            raise ValueError(op)
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "op", "interpret"))
def alu_chain(x: jax.Array, a: jax.Array, *, n: int, op: str = "fma",
              interpret: bool | None = None) -> jax.Array:
    """x, a: [R, C] tiles (use (8, 128) for one VPU vreg on TPU)."""
    interpret = use_interpret() if interpret is None else interpret
    r, c = x.shape
    return pl.pallas_call(
        functools.partial(_chain_kernel, n=n, op=op),
        grid=(1,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (0, 0)),
                  pl.BlockSpec((r, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((r, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x, a)
