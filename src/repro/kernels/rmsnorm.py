"""Fused RMSNorm Pallas kernel (row-blocked, f32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, pick_block, use_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool | None = None) -> jax.Array:
    """x: [..., D]; w: [D]."""
    interpret = use_interpret() if interpret is None else interpret
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = pick_block(rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
