"""Public jit'd kernel surface.

Every kernel is exposed here with a uniform ``interpret`` policy (interpret on
CPU — this container — compiled on TPU) so models and benchmarks import from
one place. Pure-jnp oracles live in ref.py; tests sweep shapes/dtypes and
assert allclose between the two.
"""
from repro.kernels.alu_chain import alu_chain
from repro.kernels.chase import chase
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.opchain import op_chain
from repro.kernels.rmsnorm import rmsnorm

__all__ = ["alu_chain", "chase", "flash_attention", "flash_decode",
           "mamba_scan", "op_chain", "rmsnorm"]
