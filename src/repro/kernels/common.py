"""Shared Pallas kernel helpers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite mask value: -inf breaks max-subtraction on empty rows


@functools.cache
def use_interpret() -> bool:
    """Pallas interpret mode: True off-TPU (this container), False on TPU."""
    return jax.devices()[0].platform != "tpu"


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (hardware-aligned when
    possible: preferred sizes are multiples of 128 for MXU/VPU lanes)."""
    b = min(preferred, dim)
    while dim % b:
        b -= 1
    return b


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
