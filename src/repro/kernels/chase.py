"""In-kernel pointer chase: the memory-hierarchy probe as a TPU kernel.

The host-level chase (core/membench.py) measures the *host* hierarchy; this
kernel measures HBM->VMEM behaviour on TPU: the ring table is DMA'd into VMEM
by the BlockSpec (resident probe, the paper's shared-memory/Table IV analog),
and each step's address depends on the previous step's loaded value, so the
chase cannot be pipelined — pure dependent-load latency. Rings larger than
VMEM use memory_space=ANY so loads stream from HBM (the Fig. 6 analog).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.common import use_interpret


def _chase_kernel(ring_ref, start_ref, o_ref, *, steps: int):
    def body(_, p):
        return pl.load(ring_ref, (pl.dslice(p, 1),))[0]

    p0 = start_ref[0]
    o_ref[0] = lax.fori_loop(0, steps, body, p0)


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def chase(ring: jax.Array, start: jax.Array, *, steps: int,
          interpret: bool | None = None) -> jax.Array:
    """ring: [N] int32 single-cycle permutation; start: [1] int32."""
    interpret = use_interpret() if interpret is None else interpret
    (n,) = ring.shape
    return pl.pallas_call(
        functools.partial(_chase_kernel, steps=steps),
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=interpret,
    )(ring.astype(jnp.int32), start.astype(jnp.int32))
