"""In-kernel pointer chase: the memory-hierarchy probe as a TPU kernel.

The host-level chase (core/membench.py) measures the *host* hierarchy; this
kernel measures HBM->VMEM behaviour on TPU. Each step's address depends on the
previous step's loaded value, so the chase cannot be pipelined — pure
dependent-load latency — and the ring's residency selects which level is
probed:

* **VMEM path** (ring fits in :data:`VMEM_BUDGET_BYTES`): the ring table is
  DMA'd into VMEM once by its BlockSpec, so every chase step is a VMEM hit —
  the resident probe, the paper's shared-memory / Table IV analog.
* **ANY path** (ring exceeds the budget): the ring is handed to the kernel
  with ``memory_space=ANY`` so it *stays in HBM*; each step issues an async
  copy of the dependent word into a VMEM scratch cell and waits on it, so
  every load streams from HBM — the paper's global-memory / Fig. 6 analog.
  (Like the paper's chase, one word is loaded per step; the ring's *line
  padding* is what guarantees each step lands on a distinct line. The old
  code BlockSpec-pinned the ring unconditionally, so over-VMEM rings
  silently measured VMEM; ``tests/test_memchase.py`` keeps that bug fixed.)

:func:`select_memory_space` picks the path by ring footprint;
``memory_space=`` forces one explicitly. Both kernel bodies run under the
Pallas interpreter off-TPU (``use_interpret`` fallback), including the
async-copy streaming body, so CI exercises the exact code that lowers on
hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import use_interpret

# Conservative per-core VMEM capacity used for path selection (v4/v5 cores
# have 16 MiB class VMEM; the compiler needs headroom for scratch + output,
# but the ring dominates). Rings at or below fit BlockSpec-resident.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

MEMORY_SPACES = ("vmem", "any")


def select_memory_space(ring_bytes: int,
                        vmem_budget: int | None = None) -> str:
    """Residency policy: ``"vmem"`` when the ring fits, ``"any"`` above.

    ``vmem_budget`` overrides :data:`VMEM_BUDGET_BYTES` (tests shrink it to
    exercise the streaming path on small rings).
    """
    budget = VMEM_BUDGET_BYTES if vmem_budget is None else int(vmem_budget)
    return "vmem" if int(ring_bytes) <= budget else "any"


def chase_in_specs(n: int, memory_space: str) -> list:
    """The ``in_specs`` for an ``n``-slot ring chase under ``memory_space``.

    Split out so tests can assert the residency contract directly: the
    ``"any"`` spec must *not* carry a block shape (a shaped BlockSpec is what
    DMA-pins the ring into VMEM — the original bug).
    """
    if memory_space == "vmem":
        ring_spec = pl.BlockSpec((n,), lambda i: (0,))
    elif memory_space == "any":
        ring_spec = pl.BlockSpec(memory_space=pl.ANY)
    else:
        raise ValueError(
            f"memory_space must be one of {MEMORY_SPACES}, got {memory_space!r}")
    return [ring_spec, pl.BlockSpec((1,), lambda i: (0,))]


def _chase_kernel_vmem(ring_ref, start_ref, o_ref, *, steps: int):
    """Resident chase: the whole ring is a VMEM block, loads are VMEM hits."""
    def body(_, p):
        return pl.load(ring_ref, (pl.dslice(p, 1),))[0]

    o_ref[0] = lax.fori_loop(0, steps, body, start_ref[0])


def _chase_kernel_any(ring_ref, start_ref, o_ref, line_ref, sem, *,
                      steps: int):
    """Streaming chase: the ring stays in HBM (``memory_space=ANY``); each
    step copies the dependent word into the VMEM scratch cell and waits for
    it — a dependent HBM load per step, nothing resident."""
    def body(_, p):
        cp = pltpu.make_async_copy(ring_ref.at[pl.dslice(p, 1)], line_ref, sem)
        cp.start()
        cp.wait()
        return line_ref[0]

    o_ref[0] = lax.fori_loop(0, steps, body, start_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("steps", "interpret", "memory_space"))
def _chase(ring: jax.Array, start: jax.Array, *, steps: int,
           interpret: bool, memory_space: str) -> jax.Array:
    (n,) = ring.shape
    if memory_space == "vmem":
        kernel = functools.partial(_chase_kernel_vmem, steps=steps)
        scratch = []
    else:
        kernel = functools.partial(_chase_kernel_any, steps=steps)
        scratch = [pltpu.VMEM((1,), jnp.int32), pltpu.SemaphoreType.DMA]
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=chase_in_specs(n, memory_space),
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(ring.astype(jnp.int32), start.astype(jnp.int32))


def chase(ring: jax.Array, start: jax.Array, *, steps: int,
          interpret: bool | None = None, memory_space: str | None = None,
          vmem_budget: int | None = None) -> jax.Array:
    """ring: [N] int32 single-cycle permutation; start: [1] int32.

    ``memory_space=None`` selects the residency by ring footprint
    (:func:`select_memory_space`); pass ``"vmem"`` / ``"any"`` to force a
    path. Off-TPU both paths run under the Pallas interpreter.
    """
    interpret = use_interpret() if interpret is None else interpret
    if memory_space is None:
        memory_space = select_memory_space(ring.size * 4, vmem_budget)
    return _chase(ring, start, steps=steps, interpret=interpret,
                  memory_space=memory_space)
