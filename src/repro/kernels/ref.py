"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each ``ref_*`` function is the mathematical definition, written with plain
jnp ops at f32 precision, with no tiling/blocking — tests sweep shapes and
dtypes and assert the Pallas kernels (interpret=True on CPU) match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------- attention
def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                  scale: float | None = None, logit_soft_cap: float | None = None
                  ) -> jax.Array:
    """Dense attention. q: [B,Sq,H,D]; k,v: [B,Sk,KH,D] (GQA: H % KH == 0)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0
    g = h // kh
    scale = (d ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, sq, kh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    if causal:
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def ref_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array | int) -> jax.Array:
    """One-token decode vs a cache. q: [B,H,D]; k,v: [B,S,KH,D]; kv_len mask."""
    b, h, d = q.shape
    _, s, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, kh, g, d) * (d ** -0.5)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    valid = jnp.arange(s)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ------------------------------------------------------------------ rmsnorm
def ref_rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------- mamba scan
def ref_selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                       C: jax.Array, D: jax.Array, h0: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Selective state-space scan (Mamba S6), sequential reference.

    x, dt: [B,S,Dm]; A: [Dm,N]; B,C: [B,S,N]; D: [Dm].
    Returns (y [B,S,Dm], h_final [B,Dm,N]).
    """
    bsz, s, dm = x.shape
    n = A.shape[1]
    xf, dtf = x.astype(jnp.float32), jax.nn.softplus(dt.astype(jnp.float32))
    Af = A.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * Af[None, None])            # [B,S,Dm,N]
    dBx = dtf[..., None] * Bf[:, :, None, :] * xf[..., None]  # [B,S,Dm,N]
    h = jnp.zeros((bsz, dm, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(s):
        h = dA[:, t] * h + dBx[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cf[:, t]))
    y = jnp.stack(ys, axis=1) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h


# -------------------------------------------------------------- alu chain
def ref_alu_chain(x: jax.Array, a: jax.Array, n: int) -> jax.Array:
    """Dependent fma chain oracle: x <- x*a + a, n times (f32 accumulate)."""
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)
    for _ in range(n):
        xf = xf * af + af
    return xf.astype(x.dtype)


# ------------------------------------------------------------------- chase
def ref_chase(ring: np.ndarray | jax.Array, start: int, steps: int) -> int:
    """Pointer-chase oracle: follow ring[p] ``steps`` times."""
    r = np.asarray(ring)
    p = int(start)
    for _ in range(steps):
        p = int(r[p])
    return p


# ------------------------------------------------------------------ matmul
def ref_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)
