"""Chunked selective-scan (Mamba S6) Pallas kernel.

TPU adaptation of the CUDA selective-scan: the sequence is chunked so each
chunk's x/dt/B/C tiles are DMA'd to VMEM once (grid walks chunks in the
sequential minor dimension), while the [Dm, N] state persists in f32 VMEM
scratch across chunks. Inside a chunk the recurrence runs as a fori_loop over
time steps on fully vectorized [Dm, N] state — VPU-friendly, no gather/scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, pick_block, use_interpret
from repro.kernels.flash_attention import pl_scratch


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                      # [Dm, N]

    def body(t, h):
        xt = x_ref[0, t].astype(jnp.float32)                # [Dm]
        dt = jax.nn.softplus(dt_ref[0, t].astype(jnp.float32))  # [Dm]
        bt = b_ref[0, t].astype(jnp.float32)                # [N]
        ct = c_ref[0, t].astype(jnp.float32)                # [N]
        da = jnp.exp(dt[:, None] * a)                       # [Dm, N]
        h = da * h + (dt * xt)[:, None] * bt[None, :]
        y_ref[0, t] = (h @ ct).astype(y_ref.dtype)          # [Dm]
        return h

    h_ref[...] = lax.fori_loop(0, chunk, body, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array, *, chunk: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """x, dt: [Bz,S,Dm]; A: [Dm,N]; B,C: [Bz,S,N]; D: [Dm] -> y: [Bz,S,Dm]."""
    interpret = use_interpret() if interpret is None else interpret
    bsz, s, dm = x.shape
    n = A.shape[1]
    ch = pick_block(s, chunk)
    num_c = cdiv(s, ch)

    y = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=ch),
        grid=(bsz, num_c),
        in_specs=[
            pl.BlockSpec((1, ch, dm), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, ch, dm), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((dm, n), lambda bi, ci: (0, 0)),
            pl.BlockSpec((1, ch, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, ch, n), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, ch, dm), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, dm), x.dtype),
        scratch_shapes=[pl_scratch((dm, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y + x * D[None, None].astype(x.dtype)
