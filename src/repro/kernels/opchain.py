"""Generalized in-kernel dependent-op chain: any registry step as a Pallas kernel.

``alu_chain`` hard-codes five ops; this factory lowers *any* ``OpSpec.step``
from the instruction table (core/chains.py) into a Pallas kernel whose body is
a ``lax.fori_loop`` carrying the chain value through ``n`` dependent
applications of the step. This is the paper's Fig. 3 timed block moved inside
the kernel: the carry tile and every operand tile are DMA'd into VMEM once by
their BlockSpecs (residency-pinned, like the paper's register-resident
operands), and the loop-carried dependence forbids the compiler from
pipelining, reordering or dead-coding the measured op — the same
dependent-dummy-op defence the host-level chains use, now enforced by the
loop carry instead of straight-line dataflow.

``fori_loop`` (not Python unrolling) keeps trace/compile time O(1) in ``n``,
so two chain lengths can be compiled cheaply and differenced with
``Timer.slope`` to cancel the DMA + launch overhead exactly (paper Fig. 5).
On this container the kernel runs in interpret mode (XLA emulation); on TPU
the identical code lowers to a real Mosaic kernel.
"""
from __future__ import annotations

import functools

import jax
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.common import use_interpret


def _opchain_kernel(x_ref, *rest, step, n: int):
    *op_refs, o_ref = rest
    ops = tuple(r[...] for r in op_refs)  # loaded once: VMEM-resident operands
    x = x_ref[...]
    o_ref[...] = lax.fori_loop(0, n, lambda _, c: step(c, *ops), x)


@functools.partial(jax.jit, static_argnames=("step", "n", "interpret"))
def op_chain(x: jax.Array, *operands: jax.Array, step, n: int,
             interpret: bool | None = None) -> jax.Array:
    """Apply ``step`` ``n`` times to the carry tile ``x`` inside one kernel.

    ``x`` and every operand must share one tile shape (use (8, 128) for a
    32-bit VPU vreg, (16, 128) for 16-bit dtypes). ``step`` must be a stable
    function object (registry steps are: ``default_registry`` is cached), as
    it keys the jit cache.
    """
    interpret = use_interpret() if interpret is None else interpret
    shape = x.shape
    bs = pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        functools.partial(_opchain_kernel, step=step, n=n),
        grid=(1,),
        in_specs=[bs] * (1 + len(operands)),
        out_specs=bs,
        out_shape=jax.ShapeDtypeStruct(shape, x.dtype),
        interpret=interpret,
    )(x, *operands)
