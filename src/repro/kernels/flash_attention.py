"""Blockwise (flash) causal attention as a Pallas TPU kernel.

TPU-native adaptation: online-softmax accumulation in f32 VMEM scratch while
the grid walks K/V blocks in the (sequential) minor grid dimension — the
standard Pallas TPU flash pattern. GQA is expressed with *index maps* (the
same K/V block is aliased for the ``g`` query heads that share it) instead of
materializing repeated K/V in HBM: on TPU that is a pure DMA aliasing win.

Block shapes are chosen so the working set (q, k, v, acc tiles) fits VMEM and
matmul dims stay multiples of 128 for the MXU (see ``default_blocks``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEG_INF, cdiv, pick_block, use_interpret


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, sq: int, sk: int,
                  block_q: int, block_k: int, num_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + (sk - sq)      # absolute pos of first q row in kv space
    k_start = ki * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                   # [bk, d]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
        m_ref[...] = m_new

    if causal:
        # Skip fully-masked blocks (saves ~2x on causal prefill).
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == num_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def default_blocks(sq: int, sk: int, d: int) -> tuple[int, int]:
    # VMEM budget (f32): bq*d (q) + 2*bk*d (kv) + bq*d (acc) + bq*bk (p).
    # 512x512 blocks at d=128 => ~1.5 MiB << 16 MiB VMEM; matmul dims 128-aligned.
    return pick_block(sq, 512), pick_block(sk, 512)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int | None = None, block_k: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B,Sq,H,D]; k,v: [B,Sk,KH,D] with H % KH == 0. Returns [B,Sq,H,D]."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    g = h // kh
    scale = float(d ** -0.5) if scale is None else scale
    interpret = use_interpret() if interpret is None else interpret

    bq, bk = default_blocks(sq, sk, d)
    if block_q:
        bq = pick_block(sq, block_q)
    if block_k:
        bk = pick_block(sk, block_k)
    num_q, num_k = cdiv(sq, bq), cdiv(sk, bk)

    # [B,H,S,D] layout inside the kernel: head-major so each grid cell streams
    # contiguous [block, d] tiles.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, sq=sq, sk=sk,
        block_q=bq, block_k=bk, num_k=num_k)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pl_scratch((bq, d), jnp.float32),
            pl_scratch((bq, 1), jnp.float32),
            pl_scratch((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def pl_scratch(shape: tuple[int, ...], dtype) -> object:
    """VMEM scratch allocation, portable between TPU lowering and interpret."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - TPU plugin unavailable
        return pl.MemorySpace.ANY.buffer(shape, dtype)
