"""In-pipeline vs dispatch sampling, the paper's defining contrast, end to end:
measure a handful of table rows both as host-dispatched chains and as Pallas
``fori_loop`` chains inside a kernel (repro.inkernel), then print the paired
comparison table. Cache-aware: re-running is free, --force re-measures.

  PYTHONPATH=src python examples/inkernel_compare.py [--ops add,fma.float32]
"""
import argparse

from repro.api import Plan, Session
from repro.core.timing import Timer

DEFAULT_OPS = ("add", "mul", "div.s.runtime", "fma.float32",
               "div.runtime.float32", "rsqrt")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", default=",".join(DEFAULT_OPS),
                    help="comma-separated registry op names")
    ap.add_argument("--db", default="/tmp/latency_db.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    session = Session(db=args.db, timer=Timer(warmup=1, reps=8))
    plan = Plan.inkernel(ops=[o.strip() for o in args.ops.split(",")])
    result = session.run(plan, force=args.force)
    print(f"plan '{plan.name}': {result.summary()}")
    for r in result.failed:
        print(f"  FAILED {r.failure.op}: {r.failure.error_type}: "
              f"{r.failure.message}")

    print("\n== dispatch vs in-kernel (paper's in-pipeline method) ==")
    print(session.db.compare_markdown())
    print("\nOn TPU the in-kernel column is the true in-pipeline latency; in "
          "interpret mode (CPU) it validates the kernels and the slope "
          "algebra. Same sweep: python -m repro characterize --plan inkernel")


if __name__ == "__main__":
    main()
