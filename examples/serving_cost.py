"""Serving-path characterization end to end: measure the instruction and
memory rows the estimator needs, lower the serving engine's prefill and
decode-step HLO at (batch, prompt_len) cells, and print predicted-vs-measured
— the paper's stated purpose (feeding performance models) closed into a loop
against a real program. Cache-aware: re-running is free, --force re-measures.

  PYTHONPATH=src python examples/serving_cost.py [--cells 1x16,2x64]
"""
import argparse

from repro.api import Plan, Session
from repro.core import perfmodel
from repro.core.timing import Timer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=None,
                    help="comma-separated BxP cells, e.g. 1x16,2x64 "
                         "(default: repro.api.SERVING_CELLS)")
    ap.add_argument("--db", default="/tmp/latency_db.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = None
    if args.cells:
        cells = [tuple(int(v) for v in c.split("x"))
                 for c in args.cells.split(",")]
    session = Session(db=args.db, timer=Timer(warmup=1, reps=5))
    plan = Plan.serving(cells=cells) if cells else Plan.serving()
    result = session.run(plan, force=args.force)
    print(f"plan 'serving': {result.summary()}")
    for r in result.failed:
        print(f"  FAILED {r.failure.op}: {r.failure.error_type}: "
              f"{r.failure.message}")

    print("\n== serving predicted vs measured (LatencyDB x perfmodel) ==")
    print(session.db.compare_markdown(prefix="serving."))
    points = [perfmodel.servingpoint_from_record(r) for r in result.records()
              if r.op.startswith("serving.")]
    for pt in sorted(points, key=lambda p: (p.phase, p.batch, p.prompt_len)):
        print(f"{pt.phase:>8} b{pt.batch}p{pt.prompt_len:<4} "
              f"predicted={pt.predicted_ns:12.0f}ns "
              f"measured={pt.measured_ns:12.0f}ns "
              f"ratio={pt.ratio:7.3f} coverage={pt.coverage:.2f}")
    print("\nOn CPU the measured side carries a per-call dispatch floor the "
          "instruction-sum lower bound excludes (docs/serving.md explains "
          "how to read the ratio). Same sweep: python -m repro characterize "
          "--plan serving --table")


if __name__ == "__main__":
    main()
