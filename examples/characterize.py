"""The paper's tool, end to end: characterize this machine's op latencies and
memory hierarchy, persist the LatencyDB, and price a model's HLO with it
(the PPT-GPU-style consumption the paper targets).

  PYTHONPATH=src python examples/characterize.py [--full]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import chains, measure, membench, perfmodel
from repro.core.latency_db import LatencyDB
from repro.core.timing import Timer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full registry sweep")
    ap.add_argument("--db", default="/tmp/latency_db.json")
    args = ap.parse_args()
    timer = Timer(warmup=2, reps=20)

    # 1. clock overhead (paper Fig. 5)
    ov = measure.clock_overhead(timer)
    print("clock overhead (ns):", {k: round(v, 1) for k, v in ov.items()})

    # 2. instruction table (paper Table II)
    reg = chains.default_registry()
    if not args.full:
        keep = {"add", "mul", "mad", "div.s.regular", "div.s.irregular",
                "div.s.runtime", "fma.float32", "div.runtime.float32",
                "sqrt", "rsqrt", "sin", "ex2", "popc", "clz", "add.bfloat16"}
        reg = tuple(o for o in reg if o.name in keep)
    db = LatencyDB(args.db)
    measure.run_suite(reg, opt_levels=("O0", "O3"), db=db, timer=timer)
    db.save()
    print("\n== Table II analog ==")
    print(db.table_markdown())

    # 3. memory hierarchy (paper Fig. 6)
    pts = membench.sweep([1 << k for k in range(13, 24, 2)], timer=timer)
    print("\n== Fig. 6 analog: hierarchy levels ==")
    for lv in membench.detect_levels(pts):
        print(f"  level {lv['level']}: hit {lv['hit_latency_ns']:.2f} ns, "
              f"capacity >= {lv['capacity_bytes_lower_bound']} B")

    # 4. feed a performance model (the paper's use case)
    def mlp(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32)
              for s in ((64, 256), (256, 1024), (1024, 256))]
    hlo = jax.jit(mlp).lower(*shapes).compile().as_text()
    est = perfmodel.HloLatencyEstimator(db)
    print(f"\nHLO-priced mlp latency estimate: {est.estimate_ns(hlo):.0f} ns "
          f"(from {len(db)} measured records)")


if __name__ == "__main__":
    main()
