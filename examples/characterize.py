"""The paper's tool, end to end, through the ``repro.api`` front door:
characterize this machine's op latencies and memory hierarchy into a
LatencyDB, then price a model's HLO with the measured table (the
PPT-GPU-style consumption the paper targets).

  PYTHONPATH=src python examples/characterize.py [--full] [--force] [--shard]

The session is cache-aware: re-running this script is free (every probe is a
cache hit against the DB), an interrupted run resumes where it stopped, and
``--force`` re-measures. ``--shard`` fans the plan out across every local
device — one device-pinned session per shard, merged into the same DB (see
docs/fanout.md). The same pipeline is available as
``python -m repro characterize --plan quick|table2|memory|inkernel|memory-inkernel|full
[--shard auto|N]``.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import Session, named_plan
from repro.core import membench, perfmodel
from repro.core.timing import Timer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full registry sweep")
    ap.add_argument("--force", action="store_true", help="re-measure cache hits")
    ap.add_argument("--shard", action="store_true",
                    help="fan the plan out across all local devices")
    ap.add_argument("--db", default="/tmp/latency_db.json")
    args = ap.parse_args()

    # One Session owns the timer, the environment fingerprint, and the
    # DB-backed cache; one Plan declares the whole sweep.
    session = Session(db=args.db, timer=Timer(warmup=2, reps=20))
    plan = named_plan("full") if args.full else named_plan("quick")
    if args.shard:
        print(f"fan-out over {len(jax.local_devices())} device(s)")
        result = session.fan_out(plan, force=args.force)
    else:
        result = session.run(plan, force=args.force)
    print(f"\nplan '{plan.name}': {result.summary()}")
    for r in result.failed:
        print(f"  FAILED {r.failure.op}@{r.failure.opt_level}: "
              f"{r.failure.error_type}: {r.failure.message}")

    # 1. clock overhead (paper Fig. 5) — measured by the plan's probes
    db = session.db
    ov = {lv: db.lookup_ns("clock_overhead", lv)
          for lv in ("O0", "O3") if db.lookup_ns("clock_overhead", lv)}
    print("clock overhead (ns):", {k: round(v, 1) for k, v in ov.items()})

    # 2. instruction table (paper Table II)
    print("\n== Table II analog ==")
    print(result.table_markdown())

    # 3. memory hierarchy (paper Fig. 6) — rebuilt from the same DB
    pts = [membench.mempoint_from_record(r) for r in db.records()
           if r.category == "memory"]
    if pts:
        pts.sort(key=lambda p: p.working_set_bytes)
        print("\n== Fig. 6 analog: hierarchy levels ==")
        for lv in membench.detect_levels(pts):
            print(f"  level {lv['level']}: hit {lv['hit_latency_ns']:.2f} ns, "
                  f"capacity >= {lv['capacity_bytes_lower_bound']} B")

    # 4. feed a performance model (the paper's use case)
    def mlp(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32)
              for s in ((64, 256), (256, 1024), (1024, 256))]
    hlo = jax.jit(mlp).lower(*shapes).compile().as_text()
    est = perfmodel.HloLatencyEstimator(db)
    ns = est.estimate_ns(hlo)
    print(f"\nHLO-priced mlp latency estimate: {ns:.0f} ns "
          f"(from {len(db)} measured records)")
    print(f"  {ns.report.summary()}")   # coverage + compute/memory split


if __name__ == "__main__":
    main()
