"""Serving-SLO loop end to end: generate a seeded arrival trace, replay it
through the continuous-batching scheduler twice — once against the real
Engine (wall-clocked), once against a simulator whose step costs come from
the measured `LatencyDB` — and print predicted-vs-measured TTFT/TPOT/e2e
percentiles per arrival rate. The sweep path is cache-aware (re-running is
free); --trace replays one saved trace without touching the DB cache.

  PYTHONPATH=src python examples/serve_slo.py [--rates 20,50,100]
"""
import argparse

from repro.api import SLO_RATES, Plan, Session
from repro.core import perfmodel
from repro.core.timing import Timer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default=None,
                    help="comma-separated arrival rates in req/s "
                         "(default: repro.api.SLO_RATES)")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--db", default="/tmp/latency_db.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    rates = (tuple(float(r) for r in args.rates.split(","))
             if args.rates else SLO_RATES)
    session = Session(db=args.db, timer=Timer(warmup=1, reps=5))
    plan = Plan.slo(rates=rates, n_requests=args.n_requests,
                    n_slots=args.slots, seed=args.seed)
    result = session.run(plan, force=args.force)
    print(f"plan 'slo': {result.summary()}")
    for r in result.failed:
        print(f"  FAILED {r.failure.op}: {r.failure.error_type}: "
              f"{r.failure.message}")

    print("\n== serving SLO predicted vs measured (scheduler x perfmodel) ==")
    points = [perfmodel.slopoint_from_record(r) for r in result.records()
              if r.op.startswith("slo.")]
    print(perfmodel.slo_markdown(sorted(points, key=lambda p: p.rate_rps)))
    for pt in sorted(points, key=lambda p: p.rate_rps):
        errs = ", ".join(
            f"{m.split('_ns')[0]}={pt.abs_log10_error(m):.2f}"
            for m in ("ttft_p50_ns", "tpot_p50_ns"))
        print(f"rate {pt.rate_rps:g} req/s: |log10(pred/meas)| {errs} "
              f"(coverage {pt.coverage:.2f})")
    print("\nOn CPU the measured TTFT carries the per-call dispatch floor "
          "the instruction-sum prediction excludes; TPOT (steady decode) "
          "tracks far tighter — docs/traffic.md explains how to read the "
          "gap. Same sweep: python -m repro serve-slo")


if __name__ == "__main__":
    main()
