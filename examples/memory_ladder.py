"""The memory-hierarchy ladder, host-level and in-kernel, end to end:
walk the dependent pointer chase across working-set sizes spanning the
VMEM/HBM boundary — BlockSpec-resident below ``chase.VMEM_BUDGET_BYTES``,
``memory_space=ANY`` streaming above — and print the paired Table IV /
Fig. 6 analog. Cache-aware: re-running is free, --force re-measures.

  PYTHONPATH=src python examples/memory_ladder.py [--sizes 65536,33554432]
"""
import argparse

from repro.api import Plan, Session
from repro.core import membench
from repro.core.timing import Timer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=None,
                    help="comma-separated working-set bytes (default: a "
                         "ladder bracketing the VMEM budget)")
    ap.add_argument("--db", default="/tmp/latency_db.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    sizes = ([int(s) for s in args.sizes.split(",")] if args.sizes else None)
    session = Session(db=args.db, timer=Timer(warmup=1, reps=8))
    result = session.run(Plan.memory_inkernel(sizes), force=args.force)
    print(f"plan 'memory-inkernel': {result.summary()}")
    for r in result.failed:
        print(f"  FAILED {r.failure.op}: {r.failure.error_type}: "
              f"{r.failure.message}")

    print("\n== host vs in-kernel chase (Table IV / Fig. 6 analog) ==")
    print(session.db.compare_markdown())
    points = [membench.chasepoint_from_record(r) for r in result.records()
              if r.op.startswith("inkernel.mem.")]
    for pt in sorted(points, key=lambda p: p.working_set_bytes):
        print(f"ws={pt.working_set_bytes:>10}B  space={pt.memory_space:<4} "
              f"per-load={pt.latency_ns:8.2f}ns")
    print("\nOn TPU the over-budget rungs stream from HBM; in interpret mode "
          "(CPU) the ladder validates the residency selection and the "
          "cache/resume plumbing. Same sweep: python -m repro characterize "
          "--plan memory-inkernel")


if __name__ == "__main__":
    main()
