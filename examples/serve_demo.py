"""Serve a small model with batched requests: prefill + decode engine, ragged
prompts, greedy and sampled decoding.

  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig, Runtime
from repro.serving import Engine


def main() -> None:
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
                      vocab_size=1024, param_dtype="float32",
                      compute_dtype="float32")
    rt = Runtime(remat=False, moe_groups=1)
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, rt)

    rng = np.random.RandomState(0)
    batch = [rng.randint(1, 1024, size=rng.randint(4, 12)).tolist()
             for _ in range(8)]
    t0 = time.perf_counter()
    out = eng.generate(batch, max_new=24)
    dt = time.perf_counter() - t0
    toks = out.tokens.size
    print(f"batched 8 ragged requests, {toks} new tokens in {dt*1e3:.0f} ms "
          f"({toks/dt:.0f} tok/s on host CPU)")
    for i, row in enumerate(out.tokens[:4]):
        print(f"  req{i} (prompt {out.prompt_lens[i]} toks):", row.tolist())
    sampled = eng.generate(batch[:2], max_new=8, temperature=0.8, seed=1)
    print("sampled:", sampled.tokens.tolist())


if __name__ == "__main__":
    main()
