"""Serve a small model with batched requests: prefill + decode engine, ragged
prompts, greedy and sampled decoding. Throughput is reported through the
measurement core (``Timer``: warmup + median-of-reps), not a one-shot
stopwatch, so the number is comparable to ``python -m repro characterize``
output.

  PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.core.timing import Timer
from repro.models import transformer
from repro.models.config import ModelConfig, Runtime
from repro.serving import Engine


def main() -> None:
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
                      vocab_size=1024, param_dtype="float32",
                      compute_dtype="float32")
    rt = Runtime(remat=False, moe_groups=1)
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, rt)

    rng = np.random.RandomState(0)
    batch = [rng.randint(1, 1024, size=rng.randint(4, 12)).tolist()
             for _ in range(8)]
    out = eng.generate(batch, max_new=24)  # warms compile; tokens printed below
    # median-of-3 (compile excluded by the call above), like every other
    # measurement in this repo
    m = Timer(warmup=0, reps=3).time_callable(
        lambda: eng.generate(batch, max_new=24))
    toks = out.tokens.size
    dt = m.median_ns / 1e9
    print(f"batched 8 ragged requests, {toks} new tokens in {dt*1e3:.0f} ms "
          f"median (±{m.mad_ns/1e6:.1f} ms MAD; {toks/dt:.0f} tok/s on host CPU)")
    for i, row in enumerate(out.tokens[:4]):
        print(f"  req{i} (prompt {out.prompt_lens[i]} toks):", row.tolist())
    sampled = eng.generate(batch[:2], max_new=8, temperature=0.8, seed=1)
    print("sampled:", sampled.tokens.tolist())


if __name__ == "__main__":
    main()
