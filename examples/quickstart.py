"""Quickstart: train a small LM end-to-end on CPU with the full production
path (data pipeline -> train step -> fault-tolerant trainer -> checkpoints),
generate from it, then characterize the ALU ops the decode step leans on
through the ``repro.api`` front door (cached, resumable — the same pipeline
as ``python -m repro characterize``).

  PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse
import tempfile

import numpy as np

from repro import optim
from repro.api import Plan, Session
from repro.core.timing import Timer
from repro.models.config import ModelConfig, Runtime
from repro.serving import Engine
from repro.training import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=64)
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart-8m", family="dense", n_layers=4,
                      d_model=args.d_model, n_heads=4, n_kv_heads=2,
                      d_ff=4 * args.d_model, vocab_size=512,
                      param_dtype="float32", compute_dtype="float32")
    rt = Runtime(remat=False, xent_chunk=32, moe_groups=1)
    ckpt = tempfile.mkdtemp(prefix="repro_quickstart_")
    res = train(cfg, rt, TrainConfig(steps=args.steps, checkpoint_every=50,
                                     checkpoint_dir=ckpt, log_every=20),
                optim.AdamWConfig(lr=3e-3))
    print(f"\nloss: {np.mean(res.losses[:10]):.3f} -> "
          f"{np.mean(res.losses[-10:]):.3f} over {len(res.losses)} steps "
          f"(ckpts in {ckpt})")

    eng = Engine(res.params, cfg, rt)
    out = eng.generate([[1, 2, 3, 4], [10, 11, 12, 13]], max_new=12)
    print("greedy continuations:", out.tokens.tolist())

    # What does one step of this model cost at the instruction level? Measure
    # the dominant ALU ops with the characterization Session (in-memory DB;
    # point db= at a path to cache across runs).
    session = Session(timer=Timer(warmup=1, reps=5))
    result = session.run(Plan.instructions(
        ops=("fma.float32", "add.float32", "mul.float32"), opt_levels=("O3",)))
    print("\nmeasured ALU latencies (paper Table II rows):")
    for rec in result.records():
        print(f"  {rec.op}@O3: {rec.latency_ns:.2f} ns/op (±{rec.mad_ns:.2f})")


if __name__ == "__main__":
    main()
