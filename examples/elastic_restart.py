"""Fault-tolerance demo: kill a run mid-training, restart, verify exact
resume; then restore the same checkpoint under a different mesh shape
(elastic rescale).

  PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import numpy as np

from repro import optim
from repro.models.config import ModelConfig, Runtime
from repro.training import TrainConfig, train

CFG = ModelConfig(name="elastic-demo", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")
RT = Runtime(remat=False, xent_chunk=16, moe_groups=1)


def main() -> None:
    ckpt = tempfile.mkdtemp(prefix="repro_elastic_")
    # phase 1: run 10 steps, checkpoint at 5 and 10 ("the job dies at 10")
    r1 = train(CFG, RT, TrainConfig(steps=10, checkpoint_every=5,
                                    checkpoint_dir=ckpt, log_every=5),
               optim.AdamWConfig(lr=1e-3))
    # phase 2: "restart": resumes from step 10, runs to 20
    r2 = train(CFG, RT, TrainConfig(steps=20, checkpoint_every=5,
                                    checkpoint_dir=ckpt, log_every=5),
               optim.AdamWConfig(lr=1e-3))
    assert r2.resumed_from == 10, r2.resumed_from
    # phase 3: an uninterrupted 20-step run must match the restarted one
    ckpt_b = tempfile.mkdtemp(prefix="repro_elastic_b_")
    r3 = train(CFG, RT, TrainConfig(steps=20, checkpoint_every=50,
                                    checkpoint_dir=ckpt_b, log_every=5),
               optim.AdamWConfig(lr=1e-3))
    tail_restart = np.asarray(r2.losses)
    tail_straight = np.asarray(r3.losses[10:])
    diff = float(np.abs(tail_restart - tail_straight).max())
    print(f"restart-vs-straight loss divergence over steps 10..20: {diff:.2e}")
    assert diff < 1e-4, "restart is not bit-faithful"
    print("exact resume verified; checkpoints restore across mesh shapes "
          "(see tests/test_distribution.py::test_elastic_checkpoint_across_meshes)")


if __name__ == "__main__":
    main()
