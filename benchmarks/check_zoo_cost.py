"""CI gate: per-model zoo pricing coverage must not regress below the floor.

Compares the coverage metrics JSON emitted by ``benchmarks.zoo_cost`` against
the checked-in floor (``benchmarks/zoo_cost_floor.json``). Two invariants per
model row:

* **custom-call coverage** — every synthesized TPU-form fused call site must
  price from a measured ``inkernel.fused.*`` row (floor 1.0 everywhere: an
  in-repo kernel priced at ``default_ns`` is a regression, full stop);
* **opcode coverage** — the fraction of the row's real HLO priced from
  measured table rows must stay at or above the recorded floor (a mapping
  or registry regression silently inflates the default-cost bucket).

Usage::

    PYTHONPATH=src python -m benchmarks.check_zoo_cost \
        --metrics /tmp/zoo_cost.json --floor benchmarks/zoo_cost_floor.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Sequence


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", required=True,
                    help="coverage JSON from benchmarks.zoo_cost --json")
    ap.add_argument("--floor",
                    default=os.path.join(os.path.dirname(__file__),
                                         "zoo_cost_floor.json"),
                    help="checked-in per-model coverage floor")
    args = ap.parse_args(argv)

    for path in (args.metrics, args.floor):
        if not os.path.exists(path):
            print(f"error: no file at {path}", file=sys.stderr)
            return 2
    with open(args.metrics) as f:
        metrics = json.load(f)
    with open(args.floor) as f:
        floor = json.load(f)

    violations = []
    for model, bounds in sorted(floor.items()):
        row = metrics.get(model)
        if row is None:
            violations.append(f"{model}: missing from the metrics — the "
                              "zoo run dropped a model row")
            continue
        cc = row.get("custom_call_coverage", 0.0)
        if cc < bounds["custom_call_coverage"]:
            unpriced = ", ".join(row.get("unpriced_custom_calls", [])) or "?"
            violations.append(
                f"{model}: custom-call coverage {cc:.1%} < floor "
                f"{bounds['custom_call_coverage']:.1%} (unpriced: {unpriced})")
        oc = row.get("opcode_coverage", 0.0)
        if oc < bounds["opcode_coverage"]:
            violations.append(
                f"{model}: opcode coverage {oc:.1%} < floor "
                f"{bounds['opcode_coverage']:.1%}")
    extra = sorted(set(metrics) - set(floor))
    for model in extra:
        print(f"note: {model} has no floor entry yet — add it to "
              f"{args.floor}")

    print(f"checked {len(floor)} model row(s) against the floor")
    for v in violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    if not violations:
        print("zoo pricing coverage at or above the floor everywhere")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
