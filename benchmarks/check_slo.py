"""CI gate: serving-SLO prediction error within the checked-in tolerance.

Reads the ``slo.*`` rows of a LatencyDB (written by ``python -m repro
serve-slo`` or ``--plan slo``), recomputes ``|log10(predicted/measured)|``
for the headline SLO metrics — p50 TTFT and p50 TPOT — and fails if any
point violates ``benchmarks/slo_tolerance.json``. The serving-cell gate
(``check_serving.py``) bounds one executable's cost model; this one bounds
the *composition*: costs threaded through queueing, batching and slot
recycling must still land inside the recorded band.

Usage::

    PYTHONPATH=src python -m benchmarks.check_slo --db /tmp/slo_db.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Sequence

from repro.core import perfmodel
from repro.core.latency_db import LatencyDB

DEFAULT_TOLERANCE = os.path.join(os.path.dirname(__file__),
                                 "slo_tolerance.json")


def check_points(points: Sequence[perfmodel.SloPoint],
                 tolerance: dict) -> list[str]:
    """Violation messages for ``points`` against a tolerance baseline."""
    max_err = float(tolerance["max_abs_log10_ratio"])
    min_cov = float(tolerance.get("min_coverage", 0.0))
    metrics = tuple(tolerance.get("metrics", ("ttft_p50_ns", "tpot_p50_ns")))
    violations = []
    for pt in points:
        name = f"slo.r{pt.rate_rps:g}"
        for metric in metrics:
            err = pt.abs_log10_error(metric)
            if err > max_err:
                violations.append(
                    f"{name}.{metric}: |log10(pred/meas)| = {err:.2f} > "
                    f"{max_err:.2f} (predicted "
                    f"{pt.predicted.get(metric, float('nan')):.0f}ns, "
                    f"measured {pt.measured.get(metric, float('nan')):.0f}ns)")
        if pt.coverage < min_cov:
            violations.append(
                f"{name}: coverage {pt.coverage:.2f} < {min_cov:.2f} "
                "(estimator priced too little of the engine from the DB)")
    return violations


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--db", required=True, help="LatencyDB JSON path")
    ap.add_argument("--tolerance", default=DEFAULT_TOLERANCE,
                    help="tolerance baseline JSON (default: checked-in)")
    args = ap.parse_args(argv)

    with open(args.tolerance) as f:
        tolerance = json.load(f)
    db = LatencyDB(args.db)
    points = [perfmodel.slopoint_from_record(r) for r in db.records()
              if r.op.startswith("slo.")]
    if not points:
        print(f"error: no slo.* rows in {args.db} — "
              "run `python -m repro serve-slo` first", file=sys.stderr)
        return 2
    for pt in sorted(points, key=lambda p: p.rate_rps):
        print(f"slo.r{pt.rate_rps:g}: "
              f"ttft_p50 pred={pt.predicted.get('ttft_p50_ns', 0):.0f}ns "
              f"meas={pt.measured.get('ttft_p50_ns', 0):.0f}ns "
              f"(|log10 err| {pt.abs_log10_error('ttft_p50_ns'):.2f}), "
              f"tpot_p50 pred={pt.predicted.get('tpot_p50_ns', 0):.0f}ns "
              f"meas={pt.measured.get('tpot_p50_ns', 0):.0f}ns "
              f"(|log10 err| {pt.abs_log10_error('tpot_p50_ns'):.2f}), "
              f"coverage={pt.coverage:.2f}")
    violations = check_points(points, tolerance)
    for v in violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    if not violations:
        print(f"{len(points)} SLO point(s) within tolerance "
              f"(max |log10 err| {tolerance['max_abs_log10_ratio']}, "
              f"min coverage {tolerance.get('min_coverage', 0.0)})")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
