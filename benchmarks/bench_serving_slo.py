"""Serving-SLO bench: the throughput-vs-latency curve, predicted vs measured.

For each arrival rate, one seeded trace is replayed through both sides of
``repro.traffic`` — the LatencyDB-priced simulator and the engine's
continuous-batching slot pool — and aggregated into exact-rank TTFT/TPOT/e2e
percentiles. Emits ``results/serving_slo.json`` (per-rate summaries **plus
raw per-request samples**, so downstream reports can recompute any
percentile) and ``results/serving_slo.md`` (the predicted-vs-measured
table). Registered as ``serving_slo`` in ``python -m benchmarks.run``; also
runnable standalone::

    PYTHONPATH=src:. python -m benchmarks.bench_serving_slo [--quick]
"""
from __future__ import annotations

import argparse
import math
import os
import sys

from repro.api import Plan, Session, serving_tiny_config
from repro.api.plan import SLO_RATES
from repro.core.timing import Timer
from repro.traffic import (ContinuousBatchingScheduler, EngineExecutor,
                           PredictedCostModel, TraceConfig, generate_trace,
                           simulate, slo_table, summarize)
from repro.traffic.metrics import request_metrics
from repro.utils import dump_json

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _samples(sched_result) -> list[dict]:
    """Raw per-request rows (ns): what the percentiles were computed from."""
    out = []
    for rr in sched_result.requests:
        m = request_metrics(rr)
        out.append({"uid": m.uid, "arrival_ns": rr.request.arrival_ns,
                    "prompt_len": rr.request.prompt_len,
                    "max_new": rr.request.max_new, "slot": rr.slot,
                    "ttft_ns": m.ttft_ns,
                    "tpot_ns": None if math.isnan(m.tpot_ns) else m.tpot_ns,
                    "e2e_ns": m.e2e_ns, "queue_ns": m.queue_ns,
                    "n_tokens": m.n_tokens})
    return out


def run_bench(timer: Timer, quick: bool = False,
              rates=SLO_RATES, n_requests: int = 12, n_slots: int = 4,
              seed: int = 0) -> list[tuple[str, float, str]]:
    """One predicted + measured schedule per rate; CSV rows for run.py."""
    import jax

    from repro.models import transformer
    from repro.serving import Engine

    if quick:
        rates, n_requests = rates[:2], max(6, n_requests // 2)
    # fill the estimator's pricing inputs through the Session cache (the
    # rate sweep itself runs live below so the bench always re-measures)
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    session.run(Plan.slo(rates=()))
    cfg, rt = serving_tiny_config()
    eng = Engine(transformer.init_lm(jax.random.PRNGKey(0), cfg), cfg, rt)
    costs = PredictedCostModel(eng, session.db, n_slots,
                               filters=dict(session.env))
    ex = EngineExecutor(eng, n_slots)
    sched = ContinuousBatchingScheduler(ex, eos_id=None)

    rows, table_rows, out_rates = [], [], []
    for rate in rates:
        tcfg = TraceConfig(n_requests=n_requests, rate_rps=rate, seed=seed,
                           vocab_size=cfg.vocab_size)
        trace = generate_trace(tcfg)
        ex.warm(sorted({r.prompt_len for r in trace}))
        pred_sched = simulate(trace, costs)
        meas_sched = sched.run(trace)
        pred, meas = summarize(pred_sched), summarize(meas_sched)
        table_rows.append({"rate_rps": rate, "predicted": pred,
                           "measured": meas})
        out_rates.append({
            "rate_rps": rate, "n_requests": n_requests, "n_slots": n_slots,
            "seed": seed, "coverage": costs.min_coverage,
            "predicted": pred.as_record(), "measured": meas.as_record(),
            "predicted_samples": _samples(pred_sched),
            "measured_samples": _samples(meas_sched)})
        rows.append((f"serving_slo.r{rate:g}.ttft_p50",
                     meas.ttft_ns[50.0] / 1e3,
                     f"predicted={pred.ttft_ns[50.0] / 1e3:.1f}us "
                     f"goodput={meas.goodput_tok_s:.1f}tok/s "
                     f"coverage={costs.min_coverage:.2f}"))
        rows.append((f"serving_slo.r{rate:g}.tpot_p50",
                     meas.tpot_ns[50.0] / 1e3,
                     f"predicted={pred.tpot_ns[50.0] / 1e3:.1f}us "
                     f"n={n_requests} slots={n_slots}"))

    md = slo_table(table_rows)
    dump_json({"model": cfg.name, "rates": out_rates},
              f"{RESULTS}/serving_slo.json")
    with open(f"{RESULTS}/serving_slo.md", "w") as f:
        f.write(md + "\n")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run_bench(Timer(warmup=2, reps=10 if args.quick else 20),
                     quick=args.quick)
    for name, us, derived in rows:
        print(f"{name},{us:.4f},{derived}")
    with open(f"{RESULTS}/serving_slo.md") as f:
        print(f.read())
    return 0


if __name__ == "__main__":
    sys.exit(main())
