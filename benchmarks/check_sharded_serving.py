"""CI gate: sharded-serving prediction error within the checked-in baseline.

Reads the ``serving.tp*`` rows of a LatencyDB (written by ``python -m repro
characterize --plan serving-sharded`` or ``benchmarks.bench_collectives``),
recomputes each cell's ``|log10(predicted/measured)|`` and coverage, and
fails if any cell violates ``benchmarks/sharded_serving_tolerance.json``.
On top of the unsharded gate's checks this one enforces the collective-term
invariant: ``coll_unpriced`` must not exceed the baseline's
``max_coll_unpriced`` (0 — a collective op the estimator could not price
from a measured ``coll.*`` ladder rung is a hard failure, never a silently
default-priced term).

Usage::

    PYTHONPATH=src python -m benchmarks.check_sharded_serving --db /tmp/db.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Sequence

from repro.core import perfmodel
from repro.core.latency_db import LatencyDB

DEFAULT_TOLERANCE = os.path.join(os.path.dirname(__file__),
                                 "sharded_serving_tolerance.json")


def check_points(points: Sequence[perfmodel.ServingPoint],
                 tolerance: dict) -> list[str]:
    """Violation messages for sharded ``points`` against a baseline."""
    max_err = float(tolerance["max_abs_log10_ratio"])
    min_cov = float(tolerance.get("min_coverage", 0.0))
    max_unpriced = float(tolerance.get("max_coll_unpriced", 0))
    violations = []
    for pt in points:
        cell = f"serving.tp{pt.tp}.{pt.phase}.b{pt.batch}p{pt.prompt_len}"
        err = pt.abs_log10_error
        if err > max_err:
            violations.append(
                f"{cell}: |log10(pred/meas)| = {err:.2f} > {max_err:.2f} "
                f"(predicted {pt.predicted_ns:.0f}ns, "
                f"measured {pt.measured_ns:.0f}ns)")
        if pt.coverage < min_cov:
            violations.append(
                f"{cell}: coverage {pt.coverage:.2f} < {min_cov:.2f} "
                "(estimator priced too little of the module from the DB)")
        if pt.coll_unpriced > max_unpriced:
            violations.append(
                f"{cell}: {pt.coll_unpriced:g} collective op(s) had no "
                f"measured coll.* ladder rung to price from "
                f"(> {max_unpriced:g}); run --plan collectives at tp="
                f"{pt.tp} first")
    return violations


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--db", required=True, help="LatencyDB JSON path")
    ap.add_argument("--tolerance", default=DEFAULT_TOLERANCE,
                    help="tolerance baseline JSON (default: checked-in)")
    args = ap.parse_args(argv)

    with open(args.tolerance) as f:
        tolerance = json.load(f)
    db = LatencyDB(args.db)
    points = [perfmodel.servingpoint_from_record(r) for r in db.records()
              if r.op.startswith("serving.tp")]
    if not points:
        print(f"error: no serving.tp* rows in {args.db} — "
              "run --plan serving-sharded first", file=sys.stderr)
        return 2
    for pt in sorted(points, key=lambda p: (p.tp, p.phase, p.batch,
                                            p.prompt_len)):
        print(f"serving.tp{pt.tp}.{pt.phase}.b{pt.batch}p{pt.prompt_len}: "
              f"predicted={pt.predicted_ns:.0f}ns "
              f"(coll={pt.collective_ns:.0f}ns) "
              f"measured={pt.measured_ns:.0f}ns "
              f"|log10 err|={pt.abs_log10_error:.2f} "
              f"coverage={pt.coverage:.2f} coll_unpriced={pt.coll_unpriced:g}")
    violations = check_points(points, tolerance)
    for v in violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    if not violations:
        print(f"{len(points)} sharded cell(s) within tolerance "
              f"(max |log10 err| {tolerance['max_abs_log10_ratio']}, "
              f"min coverage {tolerance.get('min_coverage', 0.0)}, "
              f"max coll_unpriced {tolerance.get('max_coll_unpriced', 0)})")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
