"""CI gate: every auditable record of a LatencyDB must audit clean.

Runs the static chain audit (docs/audit.md) over a measured DB — reusing
the measurement run's compile cache so no XLA module is recompiled — and
fails on any ``transformed`` verdict. ``opaque``/``unaudited`` rows are
reported but only fail under ``--forbid-unaudited``.

Usage::

    PYTHONPATH=src python -m benchmarks.check_audit --db /tmp/db.json \
        --compile-cache /tmp/xc
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Sequence

from repro import audit
from repro.core.compile_cache import CompileCache
from repro.core.latency_db import LatencyDB


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--db", required=True, help="LatencyDB JSON path")
    ap.add_argument("--compile-cache", default=None,
                    help="compile cache dir from the measuring run "
                         "(audits become pure text analysis)")
    ap.add_argument("--forbid-unaudited", action="store_true",
                    help="also fail on opaque/unaudited verdicts")
    args = ap.parse_args(argv)

    if not os.path.exists(args.db):
        print(f"error: no DB at {args.db} — run characterize first",
              file=sys.stderr)
        return 2
    db = LatencyDB(args.db)
    cache = CompileCache(args.compile_cache) if args.compile_cache else None
    verdicts = audit.audit_db(db, cache=cache)
    db.save()

    counts: dict[str, int] = {}
    for v in verdicts:
        counts[v.status] = counts.get(v.status, 0) + 1
    summary = ", ".join(f"{k}={counts[k]}" for k in sorted(counts))
    print(f"audited {len(verdicts)} record(s): {summary or 'none'}")

    failed = [v for v in verdicts if v.failed]
    soft = [v for v in verdicts if v.status in ("opaque", "unaudited")]
    for v in failed:
        print(f"VIOLATION: {v.op}@{v.opt_level}: {v.note()} ({v.detail})",
              file=sys.stderr)
    if args.forbid_unaudited:
        for v in soft:
            print(f"VIOLATION: {v.op}@{v.opt_level}: {v.note()}",
                  file=sys.stderr)
    bad = failed + (soft if args.forbid_unaudited else [])
    if not bad:
        print("all auditable records clean")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
