"""Model-zoo cost table: price every config's custom-calls from measured rows.

Twelve rows — the ten registry architectures' smoke train steps plus the
serving-tiny prefill and decode cells — each priced two ways by
:class:`repro.core.perfmodel.HloLatencyEstimator`:

* the **real** optimized HLO of the row (compiled on this host), giving the
  opcode coverage the estimator has for the standard instruction mix;
* the row's **TPU-form fused custom-calls**: the CPU backend inlines Pallas
  kernels, so the ``tpu_custom_call`` sites a TPU lowering would carry are
  synthesized from the config (one ``flash_attention`` / ``flash_decode``
  per attention mixer, one ``mamba_scan`` per Mamba mixer, the rmsnorm
  sites per layer) with the config's real shapes, then priced through
  ``hlo_analysis.CUSTOM_CALL_TARGETS`` against the measured
  ``inkernel.fused.<name>`` rows — *never* at ``default_ns``.

The fused rows are measured in place if missing (``--plan fused`` via the
Session cache, so re-runs are hits). Output: ``results/model_zoo_cost.md``
plus a machine-readable coverage JSON for ``benchmarks.check_zoo_cost``.

Usage::

    PYTHONPATH=src python -m benchmarks.zoo_cost --db /tmp/db.json \
        --out results/model_zoo_cost.md --json /tmp/zoo_cost.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

ZOO_B, ZOO_S = 2, 32          # the smoke-recipe batch/seq (audit lint's zoo)


# ---------------------------------------------------------------- synthesis
def _head_dim(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.n_heads


def fused_sites(cfg, phase: str) -> list[tuple[str, list[tuple], tuple]]:
    """``(kernel, operand_shapes, result_shape)`` per TPU-form call site.

    One site per mixer the repo has a fused kernel for: ``attn`` mixers
    dispatch flash_attention (train/prefill) or flash_decode (decode-step),
    ``mamba`` mixers dispatch mamba_scan, and every layer carries its two
    rmsnorm sites plus the stack's final norm. mlstm/slstm mixers have no
    in-repo fused kernel — they lower to plain HLO and are priced by the
    opcode terms, so no site is synthesized for them.
    """
    b, s, d = ZOO_B, ZOO_S, cfg.d_model
    h, hd = cfg.n_heads, _head_dim(cfg)
    kvh = cfg.n_kv_heads or h
    sites: list[tuple[str, list[tuple], tuple]] = []
    period = cfg.period or ((("attn", "dense"),))
    for i in range(cfg.n_layers):
        mixer = period[i % len(period)][0]
        if mixer == "attn":
            if phase == "decode":
                sites.append(("flash_decode",
                              [(b, h, hd), (b, s, kvh, hd), (b, s, kvh, hd)],
                              (b, h, hd)))
            else:
                sites.append(("flash_attention",
                              [(b, s, h, hd), (b, s, kvh, hd),
                               (b, s, kvh, hd)],
                              (b, s, h, hd)))
        elif mixer == "mamba":
            di = int(cfg.d_model * cfg.ssm_expand)
            st = int(cfg.ssm_state)
            sites.append(("mamba_scan",
                          [(b, s, di), (b, s, di), (b, s, st), (b, s, st)],
                          (b, s, di)))
        rows = b if phase == "decode" else b * s
        sites.append(("rmsnorm", [(rows, d), (d,)], (rows, d)))
        sites.append(("rmsnorm", [(rows, d), (d,)], (rows, d)))
    rows = b if phase == "decode" else b * s
    sites.append(("rmsnorm", [(rows, d), (d,)], (rows, d)))
    return sites


def _shape(dims: tuple) -> str:
    return "f32[" + ",".join(str(int(d)) for d in dims) + "]"


def fused_hlo(model: str, sites: Sequence[tuple[str, list[tuple], tuple]]
              ) -> str:
    """TPU-form HLO module text holding exactly the synthesized call sites.

    The module never compiles or runs — it exists for the estimator's text
    analysis. Every site is a ``tpu_custom_call`` whose Mosaic-style config
    embeds the kernel name (the real TPU lowering's shape: the target alone
    is opaque, the payload names the kernel), so pricing exercises the same
    ``resolve_custom_call`` path a production module would.
    """
    lines = [f"HloModule zoo_fused_{model.replace('-', '_').replace('.', '_')}",
             "", "ENTRY %main () -> (f32[1]) {"]
    n = 0
    results = []
    for kernel, operands, result in sites:
        ops = []
        for shp in operands:
            lines.append(f"  %p{n} = {_shape(shp)} parameter({n})")
            ops.append(f"%p{n}")
            n += 1
        lines.append(
            f"  %site{len(results)} = {_shape(result)} "
            f"custom-call({', '.join(ops)}), "
            f'custom_call_target="tpu_custom_call", '
            f'backend_config="mosaic kernel={kernel}_kernel"')
        results.append(f"%site{len(results)}")
    lines.append(f"  ROOT %out = (f32[1]) tuple({results[0]})")
    lines.append("}")
    return "\n".join(lines)


# -------------------------------------------------------------------- rows
def zoo_rows(archs: Sequence[str] | None = None):
    """Yield ``(model, phase, real_hlo_text, cfg)`` for all twelve rows."""
    from repro.audit.lint import _zoo_hlo
    from repro.configs.registry import all_arch_ids, get

    for arch in (archs if archs is not None else all_arch_ids()):
        yield arch, "train", _zoo_hlo(arch), get(arch).smoke

    import jax

    from repro.api.probes import serving_tiny_config
    from repro.models import transformer
    from repro.serving import Engine

    cfg, rt = serving_tiny_config()
    eng = Engine(transformer.init_lm(jax.random.PRNGKey(0), cfg), cfg, rt)
    lowered, _ = eng.lower_prefill(ZOO_B, ZOO_S)
    yield "serving-tiny", "prefill", lowered.compile().as_text(), cfg
    lowered, _ = eng.lower_decode(ZOO_B, ZOO_S)
    yield "serving-tiny", "decode", lowered.compile().as_text(), cfg


def ensure_fused_rows(db_path: str, compile_cache: str | None = None) -> None:
    """Measure the rows the pricing needs into the DB (cache hits skip).

    Both plans: ``quick`` fills the instruction-table rows the opcode
    coverage column prices from, ``fused`` fills the ``inkernel.fused.*``
    slope rows the custom-call sites price from — so a fresh DB path yields
    the complete table in one command.
    """
    from repro.api.plan import named_plan
    from repro.api.session import Session

    session = Session(db=db_path, compile_cache=compile_cache)
    for plan in ("quick", "fused"):
        result = session.run(named_plan(plan))
        for r in result.failed:
            f = r.failure
            print(f"  FAILED {f.op}@{f.opt_level}: {f.error_type}: "
                  f"{f.message}", file=sys.stderr)
        if result.failed:
            raise SystemExit(f"{plan}-plan measurement failed; cannot "
                             "price the zoo")


def price_zoo(db, archs: Sequence[str] | None = None
              ) -> tuple[str, dict[str, dict]]:
    """``(markdown, metrics)``: the table and per-model coverage numbers."""
    from repro.core.latency_db import current_environment
    from repro.core.perfmodel import HloLatencyEstimator

    env = current_environment()
    filters = {k: env[k] for k in ("device_kind", "backend", "jax_version")}
    est = HloLatencyEstimator(db, filters=filters)

    header = ("| model | phase | opcode coverage | est total (us) "
              "| fused sites | fused priced | fused est (us) "
              "| unpriced custom-calls |")
    lines = [header, "|---" * 8 + "|"]
    metrics: dict[str, dict] = {}
    for model, phase, hlo_text, cfg in zoo_rows(archs):
        base = est.estimate(hlo_text)
        sites = fused_sites(cfg, phase)
        fused = est.estimate(fused_hlo(model, sites))
        cc_unpriced = [(op, c) for op, c in fused.unpriced_opcodes
                       if op.startswith("custom-call:")]
        n_unpriced = sum(c for _, c in cc_unpriced)
        cc_cov = (fused.priced_instances / len(sites)) if sites else 1.0
        fused_ns = sum(v.ns for k, v in fused.by_class.items()
                       if k.startswith("fused:"))
        key = f"{model}.{phase}" if model == "serving-tiny" else model
        metrics[key] = {
            "phase": phase,
            "opcode_coverage": round(base.coverage, 4),
            "custom_call_sites": len(sites),
            "custom_call_priced": fused.priced_instances,
            "custom_call_coverage": round(cc_cov, 4),
            "unpriced_custom_calls": [op for op, _ in cc_unpriced],
        }
        lines.append(
            f"| {model} | {phase} | {base.coverage:.1%} "
            f"| {base.total_ns / 1e3:.1f} | {len(sites)} "
            f"| {fused.priced_instances:g} | {fused_ns / 1e3:.1f} "
            f"| {', '.join(op for op, _ in cc_unpriced) or '-'} |")
        print(f"  {key}: opcode coverage {base.coverage:.1%}, "
              f"{fused.priced_instances:g}/{len(sites)} fused sites priced"
              + (f", UNPRICED: {n_unpriced:g}" if n_unpriced else ""))
    return "\n".join(lines), metrics


def write_report(md_table: str, db, out_path: str) -> None:
    from repro.core.latency_db import current_environment

    env = current_environment()
    rows = sorted(r.op for r in db.records()
                  if r.op.startswith("inkernel.fused."))
    with open(out_path, "w") as f:
        f.write("# Model zoo cost table\n\n")
        f.write(
            "Every registry architecture's smoke train step plus the "
            "serving-tiny prefill/decode cells, priced by the measured-row "
            "estimator (`repro.core.perfmodel`). Custom-calls are the "
            "TPU-form fused Pallas kernels, resolved through "
            "`CUSTOM_CALL_TARGETS` and priced from the measured "
            "`inkernel.fused.*` slope rows scaled by the dataflow-certified "
            "unit bytes — no in-repo kernel is priced at `default_ns`. "
            "See docs/audit.md (§Inside the custom-call) and "
            "docs/inkernel.md.\n\n")
        f.write(f"Environment: {env['device_kind']}/{env['backend']}, "
                f"jax {env['jax_version']}. Measured fused rows: "
                f"{', '.join(rows) or 'none'}.\n\n")
        f.write(md_table)
        f.write("\n\nRegenerate: `PYTHONPATH=src python -m benchmarks."
                "zoo_cost --db <db.json> --out results/model_zoo_cost.md`.\n")


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--db", default="/tmp/latency_db.json",
                    help="LatencyDB JSON path (fused rows measured into it "
                         "if missing)")
    ap.add_argument("--out", default="results/model_zoo_cost.md",
                    help="markdown table path")
    ap.add_argument("--json", default=None,
                    help="also write per-model coverage metrics JSON "
                         "(benchmarks.check_zoo_cost's input)")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch subset (default: all ten + "
                         "the serving rows)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR")
    ap.add_argument("--no-measure", action="store_true",
                    help="never measure; fail if the fused rows are absent")
    args = ap.parse_args(argv)

    if not args.no_measure:
        ensure_fused_rows(args.db, args.compile_cache)

    from repro.core.latency_db import LatencyDB

    db = LatencyDB(args.db)
    if not any(r.op.startswith("inkernel.fused.") for r in db.records()):
        print(f"error: no inkernel.fused.* rows in {args.db} — run "
              "`python -m repro characterize --plan fused` first",
              file=sys.stderr)
        return 2
    archs = [a.strip() for a in args.archs.split(",")] if args.archs else None
    md_table, metrics = price_zoo(db, archs)
    write_report(md_table, db, args.out)
    print(f"zoo cost table: {len(metrics)} row(s) -> {args.out}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"coverage metrics -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
