"""Benchmark orchestrator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` trims sweeps (used by CI);
the default run measures the full registry. All characterization benches route
through the ``repro.api`` Session/Plan pipeline (with ``force=True`` so perf
tracking re-measures), i.e. the exact code path
``python -m repro characterize`` users run.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.timing import Timer
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. clock,alu)")
    args = ap.parse_args()

    from benchmarks import (bench_characterize_speed, bench_collectives,
                            bench_serving_slo, paper_tables as pt)
    timer = Timer(warmup=2, reps=10 if args.quick else 20)
    benches = {
        "clock": lambda t: pt.bench_clock_overhead(t),
        "alu": lambda t: pt.bench_alu_latency(t, quick=args.quick),
        "optlevels": lambda t: pt.bench_optlevels(t),
        "memory": lambda t: pt.bench_memory_hierarchy(t, quick=args.quick),
        "onchip": lambda t: pt.bench_onchip_memory(t),
        "inkernel": lambda t: pt.bench_inkernel_vs_dispatch(t, quick=args.quick),
        "inkernel_memory": lambda t: pt.bench_inkernel_memory(t, quick=args.quick),
        "serving_cost": lambda t: pt.bench_serving_cost(t, quick=args.quick),
        "serving_slo": lambda t: bench_serving_slo.run_bench(t, quick=args.quick),
        "collectives": lambda t: bench_collectives.run_bench(t, quick=args.quick),
        "characterize_speed": lambda t: bench_characterize_speed.run_bench(
            t, quick=args.quick),
        "fanout": lambda t: pt.bench_fanout_scaling(t, quick=args.quick),
        "attention": lambda t: pt.bench_attention_impls(t),
        "roofline": lambda t: pt.bench_roofline(t),
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(timer)
            pt._emit(rows)
        except Exception as e:  # noqa: BLE001
            print(f"{name}.ERROR,0.0,{type(e).__name__}: {e}")
            logger.exception("bench %s failed", name)
        logger.info("bench %s done in %.1fs", name, time.time() - t0)


if __name__ == "__main__":
    main()
