"""Characterization-throughput bench: serial vs compile-ahead vs warm cache.

Times the same representative 20-probe plan (``Plan.representative()`` —
instructions, memory chases, clock overhead, one Pallas kernel) three ways:

1. ``serial_cold``     — no compile cache, pipeline off: the pre-optimization
   baseline, every probe compiles inline then times.
2. ``pipelined_cold``  — compile-ahead pipeline on, empty persistent compile
   cache: probe N+1's XLA compile overlaps probe N's timing.
3. ``pipelined_warm``  — same cache directory, fresh Session: every
   executable deserializes from disk, XLA is never invoked.

Each run lands in its own throwaway LatencyDB (``force=True`` besides), so
the result cache never short-circuits a measurement — only compile work
varies. Emits ``results/characterize_speed.json`` with wall-clocks, the
per-stage compile/time/flush attribution from ``ResultSet.stage_ns``, and
compile-cache hit counters. Registered as ``characterize_speed`` in
``python -m benchmarks.run``; also runnable standalone::

    PYTHONPATH=src:. python -m benchmarks.bench_characterize_speed [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

from repro.api import Plan, Session
from repro.core.timing import Timer
from repro.utils import dump_json

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _stage_summary(result) -> str:
    st = result.stage_ns
    parts = [f"{k}={st.get(k, 0) / 1e6:.0f}ms" for k in ("compile", "time", "flush")]
    if result.cache_stats is not None:
        cs = result.cache_stats
        parts.append(f"cache={cs.hits}h/{cs.misses}c")
    return " ".join(parts)


def _timed_run(plan, timer, db_path, **session_kw):
    session = Session(db=db_path, timer=timer, **session_kw)
    t0 = time.perf_counter()
    result = session.run(plan, force=True)
    return time.perf_counter() - t0, result


def run_bench(timer: Timer, quick: bool = False) -> list[tuple[str, float, str]]:
    """Three wall-clocks over one plan; CSV rows for run.py."""
    plan = Plan.representative()
    if quick:
        plan = Plan(tuple(plan)[:8], name="representative-quick")

    with tempfile.TemporaryDirectory(prefix="repro-xc-") as tmp:
        cache_dir = os.path.join(tmp, "xc")
        t_serial, r_serial = _timed_run(
            plan, timer, os.path.join(tmp, "db_serial.json"), pipeline=False)
        t_cold, r_cold = _timed_run(
            plan, timer, os.path.join(tmp, "db_cold.json"),
            compile_cache=cache_dir)
        t_warm, r_warm = _timed_run(
            plan, timer, os.path.join(tmp, "db_warm.json"),
            compile_cache=cache_dir)

    def stages(result):
        return {k: v / 1e9 for k, v in result.stage_ns.items()}

    dump_json({
        "probes": len(plan),
        "serial_cold_s": t_serial,
        "pipelined_cold_s": t_cold,
        "pipelined_warm_s": t_warm,
        "speedup_pipeline": t_serial / max(t_cold, 1e-9),
        "speedup_total": t_serial / max(t_warm, 1e-9),
        "stages_s": {"serial_cold": stages(r_serial),
                     "pipelined_cold": stages(r_cold),
                     "pipelined_warm": stages(r_warm)},
        "warm_cache": {"hits": r_warm.cache_stats.hits,
                       "misses": r_warm.cache_stats.misses},
    }, f"{RESULTS}/characterize_speed.json")

    return [
        ("characterize_speed.serial_cold", t_serial * 1e6,
         f"{len(plan)} probes, no cache, no pipeline; "
         + _stage_summary(r_serial)),
        ("characterize_speed.pipelined_cold", t_cold * 1e6,
         f"compile-ahead, cold cache, speedup="
         f"{t_serial / max(t_cold, 1e-9):.2f}x; " + _stage_summary(r_cold)),
        ("characterize_speed.pipelined_warm", t_warm * 1e6,
         f"compile-ahead, warm cache, speedup="
         f"{t_serial / max(t_warm, 1e-9):.2f}x; " + _stage_summary(r_warm)),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run_bench(Timer(warmup=2, reps=10 if args.quick else 20),
                     quick=args.quick)
    for name, us, derived in rows:
        print(f"{name},{us:.4f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
