"""Generate EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from results.

  PYTHONPATH=src:. python benchmarks/report.py > /tmp/report.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.utils import human_bytes, markdown_table, percentiles

RES = os.path.join(os.path.dirname(__file__), "results")

_RECO = {
    "compute": "compute-bound: raise MXU utilization (larger per-device tiles, "
               "fewer pad/transposes) or accept — this is the roofline target.",
    "memory": "memory-bound: cut HBM round-trips — bf16 attention probs, "
              "Pallas flash kernel keeps the prob tile in VMEM, larger fused "
              "blocks, fewer remat recomputes.",
    "collective": "collective-bound: reshard to kill the dominant gather "
                  "(inference sharding for decode, expert-combine reshard, "
                  "overlap via collective-matmul/async flags).",
}


def _load(pattern):
    out = []
    for f in sorted(glob.glob(pattern)):
        try:
            out.append(json.load(open(f)))
        except Exception:  # noqa: BLE001
            pass
    return out


def dryrun_table() -> str:
    rows = []
    for rec in _load(f"{RES}/dryrun/*.json"):
        if rec.get("status") == "skip":
            rows.append([rec["arch"], rec["shape"], rec["mesh"], "SKIP",
                         "-", "-", "-", rec["reason"][:60]])
            continue
        if rec.get("status") != "ok":
            rows.append([rec.get("arch"), rec.get("shape"), rec.get("mesh"),
                         "FAIL", "-", "-", "-", rec.get("error", "")[:60]])
            continue
        ma = rec["memory_analysis"]
        r = rec["roofline"]
        coll = ", ".join(f"{k}x{int(v['count'])}({human_bytes(v['wire_bytes'])})"
                         for k, v in sorted(r["collectives"].items()))
        rows.append([rec["arch"], rec["shape"], rec["mesh"], "ok",
                     human_bytes(ma["argument_size_in_bytes"]),
                     human_bytes(ma["temp_size_in_bytes"]),
                     f"{rec['compile_s']:.0f}s", coll[:90] or "none"])
    return markdown_table(
        ["arch", "shape", "mesh", "status", "args/dev", "temp/dev",
         "compile", "collective schedule (wire bytes/dev/step)"], rows)


def roofline_table() -> str:
    rows = []
    for rec in _load(f"{RES}/dryrun/*__16x16.json"):
        if rec.get("status") != "ok":
            if rec.get("status") == "skip":
                rows.append([rec["arch"], rec["shape"], "—", "—", "—", "—",
                             "—", "—", "—", "skipped: " + rec["reason"][:48]])
            continue
        r = rec["roofline"]
        rows.append([
            r["arch"], r["shape"],
            f"{r['t_compute']*1e3:.2f}", f"{r['t_memory']*1e3:.2f}",
            f"{r['t_collective']*1e3:.2f}", r["dominant"],
            f"{r['model_flops']:.2e}", f"{r['useful_ratio']:.1%}",
            f"{r['roofline_fraction']:.2%}", _RECO[r["dominant"]][:80]])
    return markdown_table(
        ["arch", "shape", "T_comp(ms)", "T_mem(ms)", "T_coll(ms)", "bound",
         "MODEL_FLOPS", "useful", "roofline", "to move the dominant term"],
        rows)


def perf_table() -> str:
    rows = []
    for rec in _load(f"{RES}/perf/*.json"):
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        rows.append([f"{rec['arch']}/{rec['shape']}", rec["tag"],
                     json.dumps(rec.get("overrides", {}))[:60],
                     f"{r['t_compute']*1e3:.2f}", f"{r['t_memory']*1e3:.2f}",
                     f"{r['t_collective']*1e3:.2f}", r["dominant"],
                     f"{r['roofline_fraction']:.2%}"])
    return markdown_table(
        ["cell", "variant", "knobs", "T_comp(ms)", "T_mem(ms)", "T_coll(ms)",
         "bound", "roofline"], rows)


def serving_slo_table() -> str:
    """Predicted-vs-measured SLO percentiles recomputed from the raw
    per-request samples ``bench_serving_slo`` persisted — exact-rank
    (``repro.utils.percentiles``), so the table can report any percentile
    the stored summaries didn't, and every value is an actual request."""
    rows = []
    for rec in _load(f"{RES}/serving_slo.json"):
        for rate in rec.get("rates", []):
            for side in ("predicted", "measured"):
                samples = rate.get(f"{side}_samples", [])
                ttfts = [s["ttft_ns"] for s in samples]
                tpots = [s["tpot_ns"] for s in samples
                         if s["tpot_ns"] is not None]
                if not ttfts:
                    continue
                tt = percentiles(ttfts, (50, 90, 99))
                tp = (percentiles(tpots, (50, 90, 99)) if tpots
                      else {50: 0.0, 90: 0.0, 99: 0.0})
                rows.append([
                    f"{rate['rate_rps']:g}", side, len(samples),
                    f"{tt[50] / 1e6:.3f}", f"{tt[90] / 1e6:.3f}",
                    f"{tt[99] / 1e6:.3f}", f"{tp[50] / 1e6:.3f}",
                    f"{tp[99] / 1e6:.3f}",
                    f"{rate[side]['goodput_tok_s']:.1f}"])
    return markdown_table(
        ["rate (req/s)", "side", "n", "TTFT p50 (ms)", "TTFT p90", "TTFT p99",
         "TPOT p50", "TPOT p99", "goodput (tok/s)"], rows)


if __name__ == "__main__":
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table())
    print("\n## Perf iterations\n")
    print(perf_table())
    print("\n## Serving SLO (predicted vs measured)\n")
    print(serving_slo_table())
