"""CI gate: serving prediction error within the checked-in tolerance baseline.

Reads the ``serving.*`` rows of a LatencyDB (written by ``python -m repro
characterize --plan serving``), recomputes each cell's
``|log10(predicted/measured)|`` and coverage, and fails if any cell violates
``benchmarks/serving_tolerance.json``. The paper's validation loop, made a
regression gate: the measured tables must keep predicting the real serving
program to within the recorded band.

Usage::

    PYTHONPATH=src python -m benchmarks.check_serving --db /tmp/serving_db.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Sequence

from repro.core import perfmodel
from repro.core.latency_db import LatencyDB

DEFAULT_TOLERANCE = os.path.join(os.path.dirname(__file__),
                                 "serving_tolerance.json")


def check_points(points: Sequence[perfmodel.ServingPoint],
                 tolerance: dict) -> list[str]:
    """Violation messages for ``points`` against a tolerance baseline."""
    max_err = float(tolerance["max_abs_log10_ratio"])
    min_cov = float(tolerance.get("min_coverage", 0.0))
    violations = []
    for pt in points:
        cell = f"serving.{pt.phase}.b{pt.batch}p{pt.prompt_len}"
        err = pt.abs_log10_error
        if err > max_err:
            violations.append(
                f"{cell}: |log10(pred/meas)| = {err:.2f} > {max_err:.2f} "
                f"(predicted {pt.predicted_ns:.0f}ns, "
                f"measured {pt.measured_ns:.0f}ns)")
        if pt.coverage < min_cov:
            violations.append(
                f"{cell}: coverage {pt.coverage:.2f} < {min_cov:.2f} "
                "(estimator priced too little of the module from the DB)")
    return violations


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--db", required=True, help="LatencyDB JSON path")
    ap.add_argument("--tolerance", default=DEFAULT_TOLERANCE,
                    help="tolerance baseline JSON (default: checked-in)")
    args = ap.parse_args(argv)

    with open(args.tolerance) as f:
        tolerance = json.load(f)
    db = LatencyDB(args.db)
    points = [perfmodel.servingpoint_from_record(r) for r in db.records()
              if r.op.startswith("serving.")]
    if not points:
        print(f"error: no serving.* rows in {args.db} — "
              "run --plan serving first", file=sys.stderr)
        return 2
    for pt in sorted(points, key=lambda p: (p.phase, p.batch, p.prompt_len)):
        print(f"serving.{pt.phase}.b{pt.batch}p{pt.prompt_len}: "
              f"predicted={pt.predicted_ns:.0f}ns measured={pt.measured_ns:.0f}ns "
              f"|log10 err|={pt.abs_log10_error:.2f} coverage={pt.coverage:.2f}")
    violations = check_points(points, tolerance)
    for v in violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    if not violations:
        print(f"{len(points)} cell(s) within tolerance "
              f"(max |log10 err| {tolerance['max_abs_log10_ratio']}, "
              f"min coverage {tolerance.get('min_coverage', 0.0)})")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
